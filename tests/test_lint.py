"""jengalint rule coverage: every rule has known-bad and known-good
fixtures, waiver hygiene is itself linted, and the real tree is clean."""
import pathlib
import subprocess
import sys

from repro.analysis import jengalint
from repro.analysis.jengalint import lint_source, lint_tree

FIXTURES = pathlib.Path(__file__).parent / "lint_fixtures"
REPO = pathlib.Path(__file__).resolve().parent.parent


def run_fixture(name, relpath):
    """Lint a fixture under a virtual in-package path (rule scoping keys
    on the relpath, not on where the fixture file actually lives)."""
    src = (FIXTURES / name).read_text()
    return lint_source(src, relpath)


def rules_of(violations):
    return [v.rule for v in violations]


# ------------------------------------------------------------- host-sync
def test_host_sync_bad_fixture_flags_every_sync():
    vs = run_fixture("host_sync_bad.py", "serving/sampler.py")
    assert rules_of(vs) == ["host-sync"] * 7, vs


def test_host_sync_good_fixture_is_clean():
    assert run_fixture("host_sync_good.py", "serving/sampler.py") == []


def test_host_sync_scoping_only_hot_path():
    # the same bad source outside the hot path is not host-sync's business
    vs = run_fixture("host_sync_bad.py", "serving/engine.py")
    assert "host-sync" not in rules_of(vs)
    # kernels/ prefix is in scope
    vs = run_fixture("host_sync_bad.py", "kernels/foo.py")
    assert "host-sync" in rules_of(vs)


# ---------------------------------------------------------------- nondet
def test_nondet_bad_fixture():
    vs = run_fixture("nondet_bad.py", "serving/scheduler.py")
    assert rules_of(vs) == ["nondet"] * 7, vs


def test_nondet_good_fixture_is_clean():
    assert run_fixture("nondet_good.py", "serving/scheduler.py") == []


def test_nondet_scoping():
    vs = run_fixture("nondet_bad.py", "serving/engine.py")
    assert "nondet" not in rules_of(vs)


# ---------------------------------------------------------- alloc-direct
def test_alloc_bad_fixture():
    vs = run_fixture("alloc_bad.py", "serving/engine.py")
    assert rules_of(vs) == ["alloc-direct"] * 6, vs


def test_alloc_good_fixture_is_clean():
    assert run_fixture("alloc_good.py", "serving/engine.py") == []


def test_alloc_core_modules_may_call_lifecycle():
    # manager.py IS allowed direct lifecycle calls — but a discarded
    # transactional result is flagged everywhere, core included
    vs = run_fixture("alloc_bad.py", "core/manager.py")
    assert rules_of(vs) == ["alloc-direct"] * 2, vs


# ----------------------------------------------------------- jit-hygiene
def test_jit_bad_fixture():
    vs = run_fixture("jit_bad.py", "kernels/step.py")
    assert rules_of(vs) == ["jit-hygiene"] * 3, vs


def test_jit_good_fixture_is_clean():
    assert run_fixture("jit_good.py", "kernels/step.py") == []


# -------------------------------------------------------- waiver hygiene
def test_waiver_without_reason_is_flagged():
    vs = run_fixture("waiver_noreason.py", "serving/sampler.py")
    assert "waiver-reason" in rules_of(vs), vs


def test_stale_waiver_is_flagged():
    vs = run_fixture("waiver_stale.py", "serving/sampler.py")
    assert rules_of(vs) == ["stale-waiver"], vs


def test_waiver_suppresses_only_named_rule():
    src = ("import numpy as np\n"
           "# jengalint: allow[nondet] wrong rule name for this line\n"
           "x = np.asarray(1)\n")
    vs = lint_source(src, "serving/sampler.py")
    # host-sync violation survives AND the nondet waiver is stale
    assert sorted(rules_of(vs)) == ["host-sync", "stale-waiver"], vs


# ------------------------------------------------------------- self-check
def test_tree_is_clean():
    """The enforced contract: zero unwaived violations on src/repro."""
    assert lint_tree() == []


def test_every_waiver_in_tree_has_reason():
    root = jengalint.find_package_root()
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        for w in jengalint.list_waivers(path.read_text(), rel):
            assert w.reason, f"{rel}:{w.line} waiver without reason"


def test_run_lint_script_exits_zero():
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "run_lint.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout
