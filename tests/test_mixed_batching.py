"""Token-budget mixed prefill/decode batching: packing, equivalence across
the three batching layouts, transactional batch allocation, and preemption.

The engine packs multiple concurrent prefill chunks plus all decodes into
ONE dispatch per step, as a token-packed stream (``"packed"``, default) or
as padded per-sequence rows (``"padded"``, the PR-1 layout; ``"mixed"`` is
a legacy alias); ``batching_mode="serial"`` reproduces the old
one-prefill-chunk-per-step engine. Greedy outputs must be identical token
for token across all three schedules for every model family.
"""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams


from conftest import assert_greedy_equiv, make_engine


def run_workload(eng, n_req=3, prompt=14, out=4):
    for i in range(n_req):
        eng.submit(Request(rid=f"r{i}", prompt=[(3 * i + j) % 50
                                                for j in range(prompt + i)],
                           sampling=SamplingParams(max_new_tokens=out)))
    eng.run_until_done(max_steps=2000)
    return {r.rid: list(r.output) for r in eng.finished}


# ------------------------------------------------------------------ packing
def test_multi_prefill_packing_respects_budget():
    budget = 20
    eng, _ = make_engine(chunk_size=8, max_num_batched_tokens=budget)
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=3)))
    eng.run_until_done(max_steps=500)
    assert len(eng.finished) == 4
    assert all(m.batched_tokens <= budget for m in eng.metrics), \
        [(m.step, m.batched_tokens) for m in eng.metrics]
    # the budget admits more than one prefill chunk per step
    assert max(m.num_prefills for m in eng.metrics) >= 2
    # and prefill chunks ride together with decodes in one plan
    assert any(m.num_prefills >= 1 and m.decode_batch >= 1
               for m in eng.metrics)


def test_serial_mode_schedules_one_prefill():
    eng, _ = make_engine(batching_mode="serial")
    for i in range(3):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done(max_steps=500)
    assert len(eng.finished) == 3
    assert all(m.num_prefills <= 1 for m in eng.metrics)


# -------------------------------------------------------------- determinism
@pytest.mark.parametrize("arch", ["granite-3-2b", "h2o-danube-3-4b",
                                  "qwen2-vl-2b", "zamba2-1.2b", "rwkv6-3b",
                                  "whisper-tiny", "dbrx-132b"])
def test_packed_padded_serial_greedy_equal(arch):
    """Greedy outputs are token-identical across all three batching
    layouts — packed stream, padded rows, and the legacy
    one-prefill-per-step schedule (ample memory: no preemption) — for
    every model family (attention, swa, vlm, hybrid-mamba2, rwkv6,
    encdec, moe), up to fork-checked ambiguous near-ties: the layouts
    reduce in different orders, so genuinely tied top-2 decisions may
    flip (conftest.assert_greedy_equiv bounds any divergence)."""
    engs = {}
    for mode in ("packed", "padded", "serial"):
        eng, _ = make_engine(arch, batching_mode=mode,
                             max_num_batched_tokens=64,
                             record_sample_logits=True)
        run_workload(eng)
        engs[mode] = eng
    assert_greedy_equiv(engs["packed"], engs["padded"],
                        label=f"{arch}/padded")
    assert_greedy_equiv(engs["packed"], engs["serial"],
                        label=f"{arch}/serial")


@pytest.mark.parametrize("arch", ["qwen2-vl-2b", "whisper-tiny"])
def test_batching_modes_match_multimodal(arch):
    """Determinism with actual mm/encoder items: packed/padded batches must
    route mm embeddings / encoder KV writes to the right tokens/rows."""
    from repro.core.request import MMItem
    outs = {}
    for mode in ("packed", "padded", "serial"):
        eng, cfg = make_engine(arch, batching_mode=mode,
                               max_num_batched_tokens=64)
        for i in range(2):
            kw = {}
            if arch == "whisper-tiny":
                kw["encoder_items"] = (MMItem(0, cfg.encoder_seq,
                                              mm_hash=7 + i),)
            else:
                kw["mm_items"] = (MMItem(2, 6, mm_hash=40 + i),)
            eng.submit(Request(rid=f"r{i}", prompt=list(range(12 + i)),
                               sampling=SamplingParams(max_new_tokens=3),
                               **kw))
        eng.run_until_done(max_steps=500)
        outs[mode] = {r.rid: list(r.output) for r in eng.finished}
    assert outs["packed"] == outs["padded"] == outs["serial"], (arch, outs)


def test_mixed_chunk_size_invariance():
    """Generations must not depend on how prefill is chunked/packed."""
    outs = []
    for chunk, budget in ((4, 16), (8, 64), (64, 256)):
        eng, _ = make_engine(chunk_size=chunk,
                             max_num_batched_tokens=budget)
        eng.submit(Request(rid="x", prompt=list(range(20)),
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run_until_done()
        outs.append(eng.finished[0].output)
    assert outs[0] == outs[1] == outs[2], outs


# ------------------------------------------------------- fewer engine steps
def test_mixed_needs_fewer_steps_than_serial():
    """The point of the refactor: identical workload + pool budget, fewer
    engine steps (more tokens per dispatch) than one-prefill-per-step."""
    steps = {}
    for mode in ("mixed", "serial"):
        eng, _ = make_engine(batching_mode=mode, max_running=8,
                             max_num_batched_tokens=256)
        for i in range(4):
            eng.submit(Request(rid=f"r{i}", prompt=list(range(64)),
                               sampling=SamplingParams(max_new_tokens=4)))
        eng.run_until_done(max_steps=2000)
        assert len(eng.finished) == 4
        steps[mode] = eng.step_count
    assert steps["mixed"] < steps["serial"], steps


# ------------------------------------------------------------- transactions
def test_allocate_for_batch_transactional():
    """A failing batch allocation must leave the manager untouched."""
    from repro.core.request import SequenceState
    eng, _ = make_engine(kv_pool_bytes=300_000)
    mgr = eng.mgr
    a = SequenceState(rid="a", tokens=list(range(8)))
    ok, _ = mgr.begin_request(a)
    assert ok
    assert mgr.allocate_for_tokens(a, 8)
    before = mgr.memory_stats().used_units
    b = SequenceState(rid="b", tokens=list(range(8)))
    ok, _ = mgr.begin_request(b)
    assert ok
    huge = SequenceState(rid="huge", tokens=[0] * 100_000)
    ok, _ = mgr.begin_request(huge)
    assert ok
    # second member's target is unsatisfiable -> the whole batch must roll
    # back, including b's pages allocated before the failure
    assert not mgr.allocate_for_batch([b, huge], [8, 100_000])
    assert mgr.memory_stats().used_units == before
    mgr.check_invariants()
    # and a feasible plan over the same sequences commits
    assert mgr.allocate_for_batch([b, a], [8, 8])
    assert mgr.memory_stats().used_units > before
    mgr.check_invariants()


# --------------------------------------------------------------- preemption
def test_oom_preemption_recovers_mixed():
    """Tiny pool forces preemption mid-plan; every request still completes
    and the batch-transactional allocator keeps invariants intact."""
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, single_device_dist())
    eng = Engine(model, EngineConfig(kv_pool_bytes=200_000, max_running=4,
                                     chunk_size=8,
                                     max_num_batched_tokens=64))
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done(max_steps=500)
    assert len(done) == 4, (len(done), eng.scheduler.preemption_count)
    eng.mgr.check_invariants()
