"""Prefix-caching behaviour tests (Jenga §5)."""
from repro.core import (
    BYTES_PER_UNIT,
    JengaKVCacheManager,
    MMItem,
    SequenceState,
    attention_spec,
    cross_attention_spec,
    vision_embed_spec,
)


def swa_mgr(n_large=64, tpp=2, window=4, **kw):
    specs = [
        attention_spec("full_attn", num_layers=2, kv_heads=1, head_dim=32,
                       tokens_per_page=tpp),
        attention_spec("swa", num_layers=2, kv_heads=1, head_dim=32,
                       tokens_per_page=tpp, kind="swa", sliding_window=window),
    ]
    large = 128 * tpp * 2 * 2  # LCM of two equal sizes = one small page size
    return JengaKVCacheManager(
        specs, total_memory_bytes=large * n_large * BYTES_PER_UNIT, **kw
    ), specs


def run_request(m, rid, tokens, *, decode=0, cache=True):
    r = SequenceState(rid=rid, tokens=list(tokens))
    ok, _ = m.begin_request(r)
    assert ok
    assert m.allocate_for_tokens(r, len(r.tokens))
    m.advance(r, len(r.tokens) - r.num_computed)
    m.touch(r)
    for d in range(decode):
        r.append_token(90000 + d)
        assert m.allocate_for_tokens(r, len(r.tokens))
        m.advance(r, 1)
        m.touch(r)
    m.free_request(r, cache=cache)
    return r


def test_full_prefix_hit():
    m, _ = swa_mgr()
    run_request(m, "a", range(16))
    r2 = SequenceState(rid="b", tokens=list(range(16)) + [777])
    ok, _ = m.begin_request(r2)
    assert ok
    assert r2.prefix_hit_tokens == 16
    m.free_request(r2)


def test_hit_capped_below_full_prompt():
    """A hit must leave >=1 token to compute."""
    m, _ = swa_mgr()
    run_request(m, "a", range(16))
    r2 = SequenceState(rid="b", tokens=list(range(16)))
    ok, _ = m.begin_request(r2)
    assert ok
    assert r2.prefix_hit_tokens <= 15


def test_swa_retires_out_of_window_pages_inflight():
    """Fig. 16: Jenga frees SWA KV outside the window mid-request."""
    m, _ = swa_mgr(window=4, tpp=2)
    r = SequenceState(rid="a", tokens=list(range(20)))
    ok, _ = m.begin_request(r)
    assert ok
    assert m.allocate_for_tokens(r, 20)
    m.advance(r, 20)
    table = r.page_tables["swa"]
    # window 4 over 20 tokens -> tokens [16, 20) live -> pages 8,9 live
    live = [i for i, e in enumerate(table) if e != SequenceState.FREED]
    assert live == [8, 9]
    # full-attn keeps everything
    assert all(e != SequenceState.FREED for e in r.page_tables["full_attn"])
    m.free_request(r)
    m.check_invariants()


def test_swa_prefix_hit_needs_only_window():
    """§5.2: sliding-window hit requires only the last window tokens cached."""
    m, _ = swa_mgr(window=4, tpp=2, n_large=256)
    run_request(m, "a", range(40))
    # evict some early SWA pages by filling with other requests? Instead,
    # check possible-prefix computation directly: early swa pages were
    # retired to cache too, so a full re-hit is possible.
    r2 = SequenceState(rid="b", tokens=list(range(40)) + [777])
    ok, _ = m.begin_request(r2)
    assert ok
    assert r2.prefix_hit_tokens == 40
    # the swa table of the hit should have FREED placeholders before window
    swa_table = r2.page_tables["swa"]
    assert swa_table[:17].count(SequenceState.FREED) >= 16
    m.free_request(r2)


def test_paper_5_1_example_balanced_eviction():
    """§5.1 Fig. 10: tokens exclusive to request 1 get older timestamps than
    request 2's, in BOTH layer types."""
    m, _ = swa_mgr(window=2, tpp=1, n_large=256)
    # Request 1: input [A B C D] output [E F]; Request 2: [A B C D G] -> H
    A, B, C, D, E, F, G = 1, 2, 3, 4, 5, 6, 7
    r1 = SequenceState(rid="r1", tokens=[A, B, C, D])
    ok, _ = m.begin_request(r1)
    assert m.allocate_for_tokens(r1, 4)
    m.advance(r1, 4)
    m.touch(r1)  # step 1: prefill
    r1.append_token(E)
    assert m.allocate_for_tokens(r1, 5)
    m.advance(r1, 1)
    m.touch(r1)  # step 2: decode E->F
    m.free_request(r1)

    r2 = SequenceState(rid="r2", tokens=[A, B, C, D, G])
    ok, _ = m.begin_request(r2)
    assert ok
    assert r2.prefix_hit_tokens == 4  # [A B C D] cached in both types
    assert m.allocate_for_tokens(r2, 5)
    m.advance(r2, 1)
    m.touch(r2)  # step 3
    m.free_request(r2)

    pool_full = m.pools["full_attn"]
    pool_swa = m.pools["swa"]
    # E's page (ts step2) older than D's (ts step3, shared w/ r2) in full attn
    def ts(pool, rid_table, idx):
        return pool.pages[rid_table[idx]].last_access

    full_table = [p for p in r2.page_tables.get("full_attn", [])]
    # tables were cleared on free; instead check via cached pages' timestamps:
    # all pages from r2's prefix got the latest touch; E-page (only r1) older.
    ev = [p for p in pool_full.iter_pages() if p.state.name == "EVICTABLE"]
    assert len(ev) >= 5
    ts_sorted = sorted(p.last_access for p in ev)
    # the E page must have strictly older ts than the max (r2-shared pages)
    assert ts_sorted[0] < ts_sorted[-1]
    # balanced: both layer types agree on which ts is oldest
    ev_swa = [p for p in pool_swa.iter_pages() if p.state.name == "EVICTABLE"]
    assert min(p.last_access for p in ev_swa) < max(p.last_access for p in ev_swa)


def test_vision_embed_whole_image_eviction_priority():
    """§5.3: all pages of one image share a randomized eviction priority."""
    specs = [
        attention_spec("full_attn", num_layers=2, kv_heads=1, head_dim=32,
                       tokens_per_page=2),
        vision_embed_spec("vision", hidden_units=128, tokens_per_page=2),
    ]
    m = JengaKVCacheManager(specs, total_memory_bytes=4_000_000)
    r = SequenceState(
        rid="v",
        tokens=list(range(16)),
        mm_items=(MMItem(0, 6, mm_hash=11), MMItem(8, 6, mm_hash=22)),
    )
    ok, _ = m.begin_request(r)
    assert m.allocate_for_tokens(r, 16)
    m.advance(r, 16)
    vis_pages = [e for e in r.page_tables["vision"] if e >= 0]
    assert len(vis_pages) == 6  # 12 storage tokens / tpp 2
    m.free_request(r, cache=True)
    pool = m.pools["vision"]
    pris = [pool.pages[e].prefix_length for e in vis_pages]
    # pages 0-2 belong to image 1, 3-5 to image 2 -> two distinct priorities
    assert len(set(pris[:3])) == 1 and len(set(pris[3:])) == 1
    assert pris[0] != pris[3]


def test_vision_consume_frees_embeddings():
    """§6.2: vision embeddings are freed once consumed by chunked prefill."""
    specs = [
        attention_spec("full_attn", num_layers=2, kv_heads=1, head_dim=32,
                       tokens_per_page=2),
        vision_embed_spec("vision", hidden_units=128, tokens_per_page=2),
    ]
    m = JengaKVCacheManager(specs, total_memory_bytes=4_000_000,
                            enable_prefix_caching=False)
    r = SequenceState(rid="v", tokens=list(range(12)),
                      mm_items=(MMItem(0, 8, mm_hash=1),))
    ok, _ = m.begin_request(r)
    assert m.allocate_for_tokens(r, 12)
    m.advance(r, 6)   # first chunk consumed tokens [0,6)
    n = m.consume_mm(r, 6)
    assert n == 3     # storage tokens 0..5 -> pages 0,1,2
    stats = m.memory_stats()
    assert stats.per_type["vision"].used == 1  # page 3 still pending
    m.free_request(r, cache=False)
    m.check_invariants()


def test_cross_attn_encoder_stream_all_or_nothing():
    specs = [
        attention_spec("full_attn", num_layers=2, kv_heads=1, head_dim=32,
                       tokens_per_page=2),
        cross_attention_spec("cross", num_layers=2, kv_heads=1, head_dim=32,
                             tokens_per_page=2),
    ]
    m = JengaKVCacheManager(specs, total_memory_bytes=8_000_000)
    r = SequenceState(rid="w", tokens=list(range(10)),
                      encoder_items=(MMItem(0, 8, mm_hash=99),))
    ok, _ = m.begin_request(r)
    assert m.allocate_for_tokens(r, 10)
    assert len(r.page_tables["cross"]) == 4  # 8 encoder frames / tpp 2
    m.advance(r, 10)
    m.free_request(r, cache=True)
    # same audio, different text -> decoder prefix 0 but encoder KV hit
    r2 = SequenceState(rid="w2", tokens=list(range(50, 58)),
                       encoder_items=(MMItem(0, 8, mm_hash=99),))
    ok, _ = m.begin_request(r2)
    assert ok
    # different text -> no token prefix hit; but after allocation the cross
    # pages come from cache via lookup during begin (hit=0 -> not acquired).
    # The valuable path: SAME text prefix + same audio hits everything.
    m.free_request(r2, cache=False)
    r3 = SequenceState(rid="w3", tokens=list(range(10)) + [333],
                       encoder_items=(MMItem(0, 8, mm_hash=99),))
    ok, _ = m.begin_request(r3)
    assert r3.prefix_hit_tokens == 10
    # all 4 encoder pages reacquired from cache
    assert sum(1 for e in r3.page_tables["cross"] if e >= 0) == 4
    m.free_request(r3)


def test_prefix_cache_eviction_prefers_older_requests():
    m, _ = swa_mgr(n_large=8, tpp=1, window=2)
    # two finished requests; capacity 16 full pages + 16 swa... large=512u
    run_request(m, "old", range(4))
    run_request(m, "new", range(100, 104))
    # force eviction pressure: a request needing everything
    r = SequenceState(rid="big", tokens=list(range(200, 212)))
    ok, _ = m.begin_request(r)
    assert m.allocate_for_tokens(r, 12)
    # "old"'s pages should be evicted before "new"'s
    pool = m.pools["full_attn"]
    ev_hashes = set(pool.cached.keys())
    # at least the newest request retains more cached pages than the oldest
    m.check_invariants()
    m.free_request(r, cache=False)


def test_paged_baseline_mode_no_retirement():
    """With retirement+typed policies off and a single merged type, the
    manager behaves like PagedAttention (used for baseline benches)."""
    spec = attention_spec("full_attn", num_layers=4, kv_heads=1, head_dim=32,
                          tokens_per_page=2)
    m = JengaKVCacheManager([spec], total_memory_bytes=2_000_000,
                            enable_inflight_retirement=False)
    r = SequenceState(rid="r", tokens=list(range(20)))
    ok, _ = m.begin_request(r)
    assert m.allocate_for_tokens(r, 20)
    m.advance(r, 20)
    assert all(e >= 0 for e in r.page_tables["full_attn"])
    m.free_request(r)
