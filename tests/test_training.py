"""Training substrate: loss decreases, checkpoint/restore exact resume,
NaN watchdog, ZeRO-1 state shardings, compressed psum."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import shard_map, single_device_dist
from repro.training import (AdamWConfig, SyntheticLM, Trainer, TrainerConfig,
                            compressed_psum)


def make_trainer(tmp, arch="granite-3-2b", **tkw):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    adamw = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=200)
    tcfg = TrainerConfig(ckpt_dir=str(tmp), ckpt_every=5, micro_batches=2,
                         **tkw)
    return model, Trainer(model, adamw, tcfg)


def test_loss_decreases(tmp_path):
    model, tr = make_trainer(tmp_path)
    params, state = tr.init_state(0)
    data = SyntheticLM(model.cfg.vocab_size, seq_len=32, global_batch=8,
                       mode="markov")
    params, state, hist = tr.run(params, state, data, num_steps=30)
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.3, hist[:5] + hist[-5:]


def test_checkpoint_exact_resume(tmp_path):
    model, tr = make_trainer(tmp_path)
    params, state = tr.init_state(0)
    data = SyntheticLM(model.cfg.vocab_size, seq_len=32, global_batch=8)
    params, state, hist = tr.run(params, state, data, num_steps=12)
    # fresh trainer restores step 10 and reproduces steps 10-11 exactly
    model2, tr2 = make_trainer(tmp_path)
    p2, s2, meta = tr2.restore(10)
    p2, s2, hist2 = tr2.run(p2, s2, data, num_steps=12, start_step=10)
    assert np.allclose(hist[-2:], hist2, rtol=1e-5), (hist[-2:], hist2)


def test_nan_watchdog_restores(tmp_path):
    model, tr = make_trainer(tmp_path)
    params, state = tr.init_state(0)
    data = SyntheticLM(model.cfg.vocab_size, seq_len=32, global_batch=8)
    params, state, _ = tr.run(params, state, data, num_steps=10)
    # poison params -> next step NaN -> watchdog must restore from step 10
    bad = jax.tree.map(lambda x: x * jnp.nan, params)
    p2, s2, hist = tr.run(bad, state, data, num_steps=12, start_step=10)
    assert all(np.isfinite(hist)), hist
    assert tr.restores >= 1


def test_zero1_shardings_cover_params(tmp_path):
    model, tr = make_trainer(tmp_path, zero1=True)
    flat = jax.tree.leaves(tr.opt_shardings.mu)
    assert len(flat) == len(jax.tree.leaves(model.struct()))


def test_compressed_psum_error_feedback():
    mesh = jax.make_mesh((1,), ("d",), devices=jax.devices()[:1])
    x = jnp.linspace(-3, 3, 64, dtype=jnp.float32)

    def body(x):
        total, err = compressed_psum(x, "d")
        return total, err

    total, err = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(jax.sharding.PartitionSpec("d"),),
        out_specs=(jax.sharding.PartitionSpec("d"),) * 2))(x)
    # quantization error is carried, not lost
    assert np.allclose(np.asarray(total) + np.asarray(err), np.asarray(x),
                       atol=1e-6)
