"""Property-based tests (hypothesis) for Jenga allocator invariants.

Invariants checked after every operation of a random serving trace:
  * every large page is owned by exactly one pool or free (no leaks/doubles);
  * pool state machines are consistent (free lists <-> EMPTY, heaps lazy-valid);
  * used+evictable+empty small pages exactly tile the owned large pages;
  * a request's live pages are always USED with ref_count >= 1;
  * freeing everything returns the pool to pristine state;
  * total allocated units never exceed the physical budget.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    BYTES_PER_UNIT,
    JengaKVCacheManager,
    MMItem,
    PageState,
    SequenceState,
    attention_spec,
    mamba_spec,
    vision_embed_spec,
)


def build_mgr(n_large, prefix_caching):
    specs = [
        attention_spec("full_attn", num_layers=3, kv_heads=1, head_dim=16,
                       tokens_per_page=2),
        attention_spec("swa", num_layers=1, kv_heads=1, head_dim=16,
                       tokens_per_page=2, kind="swa", sliding_window=4),
        mamba_spec("mamba", num_layers=2, conv_units=8, ssm_units=24,
                   checkpoint_interval=4),
        vision_embed_spec("vision", hidden_units=48, tokens_per_page=2),
    ]
    from repro.core import make_geometry
    geom = make_geometry(specs, total_memory_bytes=10**9)
    total = geom.large_page_units * n_large * BYTES_PER_UNIT
    return JengaKVCacheManager(
        specs, total_memory_bytes=total, enable_prefix_caching=prefix_caching
    )


def deep_check(m, live_reqs):
    m.check_invariants()
    stats = m.memory_stats()
    assert stats.used_units + stats.evictable_units + stats.empty_units + \
        stats.free_units == stats.total_units
    for r in live_reqs.values():
        for name, table in r.page_tables.items():
            pool = m.pools[name]
            for eid in table:
                if eid == SequenceState.FREED:
                    continue
                page = pool.pages[eid]
                assert page.state == PageState.USED, (name, eid, page)
                assert page.ref_count >= 1
        for name, eid in r.state_pages.items():
            assert m.pools[name].pages[eid].state == PageState.USED


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["new", "decode", "finish", "finish_nocache", "touch"]),
        st.integers(0, 5),       # which request slot
        st.integers(1, 19),      # prompt len / decode steps
        st.booleans(),           # with image?
    ),
    min_size=1,
    max_size=60,
)


@settings(max_examples=60, deadline=None)
@given(ops=ops_strategy, n_large=st.integers(2, 12), caching=st.booleans())
def test_random_trace_invariants(ops, n_large, caching):
    m = build_mgr(n_large, caching)
    live = {}
    uid = 0
    for op, slot, n, img in ops:
        if op == "new" and slot not in live:
            uid += 1
            mm = (MMItem(0, min(4, n), mm_hash=uid * 7),) if img and n >= 4 else ()
            r = SequenceState(rid=f"r{uid}", tokens=list(range(uid, uid + n)),
                              mm_items=mm)
            ok, _ = m.begin_request(r)
            assert ok or True
            if ok:
                if m.allocate_for_tokens(r, len(r.tokens)):
                    m.advance(r, len(r.tokens) - r.num_computed)
                    live[slot] = r
                else:
                    m.free_request(r, cache=False)
        elif op == "decode" and slot in live:
            r = live[slot]
            for i in range(min(n, 5)):
                r.append_token(40000 + uid * 100 + i)
                if not m.allocate_for_tokens(r, len(r.tokens)):
                    m.preempt_request(r)
                    del live[slot]
                    break
                m.advance(r, 1)
        elif op == "finish" and slot in live:
            m.free_request(live.pop(slot), cache=True)
        elif op == "finish_nocache" and slot in live:
            m.free_request(live.pop(slot), cache=False)
        elif op == "touch" and slot in live:
            m.touch(live[slot])
        deep_check(m, live)
    # drain
    for r in live.values():
        m.free_request(r, cache=False)
    deep_check(m, {})
    stats = m.memory_stats()
    assert stats.used_units == 0


@settings(max_examples=40, deadline=None)
@given(
    prompts=st.lists(
        st.lists(st.integers(0, 30), min_size=2, max_size=40), min_size=1,
        max_size=8,
    )
)
def test_prefix_hits_are_true_prefixes(prompts):
    """Any reported hit length must be consistent: re-running the same prompt
    twice in a row hits a prefix of it, and never the whole prompt."""
    m = build_mgr(64, True)
    for i, toks in enumerate(prompts):
        r = SequenceState(rid=f"a{i}", tokens=list(toks))
        ok, _ = m.begin_request(r)
        if not ok:
            continue
        if not m.allocate_for_tokens(r, len(toks)):
            m.free_request(r, cache=False)
            continue
        m.advance(r, len(toks) - r.num_computed)
        m.free_request(r, cache=True)
        r2 = SequenceState(rid=f"b{i}", tokens=list(toks))
        ok, _ = m.begin_request(r2)
        assert ok
        assert 0 <= r2.prefix_hit_tokens < len(toks)
        # hits are page-aligned for the full-attn type (tpp=2)
        assert r2.prefix_hit_tokens % 2 == 0
        m.free_request(r2, cache=False)
        m.check_invariants()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n_large=st.integers(1, 6))
def test_exhaustion_never_corrupts(seed, n_large):
    """Driving the pool to OOM repeatedly must keep accounting exact."""
    import random as _random
    rng = _random.Random(seed)
    m = build_mgr(n_large, True)
    live = []
    for i in range(30):
        n = rng.randint(1, 12)
        r = SequenceState(rid=f"r{i}", tokens=list(range(i * 50, i * 50 + n)))
        ok, _ = m.begin_request(r)
        if ok and m.allocate_for_tokens(r, n):
            m.advance(r, n - r.num_computed)
            live.append(r)
        else:
            if ok:
                m.free_request(r, cache=False)
            if live and rng.random() < 0.7:
                m.free_request(live.pop(0), cache=rng.random() < 0.5)
        m.check_invariants()
    for r in live:
        m.free_request(r, cache=False)
    assert m.memory_stats().used_units == 0
