"""Paged decode attention kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property (page permutation invariance)."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_attention_ref


def make_case(b, kvl, g, d, tpp, n_pages, vp, seed=0, dtype=jnp.float32,
              window=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, kvl, g, d)), dtype)
    kv = jnp.asarray(rng.standard_normal((vp, 2, tpp, kvl, d)), dtype)
    # each seq: pages drawn without replacement from the pool
    tables = np.stack([rng.choice(vp, n_pages, replace=False)
                       for _ in range(b)]).astype(np.int32)
    page_pos = (np.arange(n_pages, dtype=np.int32) * tpp)[None].repeat(b, 0)
    positions = rng.integers(1, n_pages * tpp, b).astype(np.int32)
    return q, kv, jnp.asarray(tables), jnp.asarray(page_pos), \
        jnp.asarray(positions)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,kvl,g,d,tpp,n_pages", [
    (2, 1, 4, 32, 8, 4),
    (3, 2, 2, 64, 16, 3),
    (1, 4, 1, 128, 8, 6),
])
def test_kernel_matches_ref_sweep(b, kvl, g, d, tpp, n_pages, dtype):
    case = make_case(b, kvl, g, d, tpp, n_pages, vp=n_pages * b + 3,
                     dtype=dtype)
    out_k = paged_decode_attention(*case, interpret=True)
    out_r = paged_decode_attention_ref(*case)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [4, 16])
def test_kernel_sliding_window(window):
    case = make_case(2, 1, 2, 32, 8, 5, vp=16, window=window)
    out_k = paged_decode_attention(*case, window=window, interpret=True)
    out_r = paged_decode_attention_ref(*case, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), tpp=st.sampled_from([8, 16]),
       n_pages=st.integers(2, 6))
def test_page_id_permutation_invariance(seed, tpp, n_pages):
    """Jenga invariant: exec page ids are arbitrary — permuting which
    physical pages hold the data must not change attention output."""
    b, kvl, g, d = 2, 1, 2, 32
    vp = 24
    q, kv, tables, page_pos, positions = make_case(
        b, kvl, g, d, tpp, n_pages, vp, seed=seed)
    out1 = paged_decode_attention_ref(q, kv, tables, page_pos, positions)
    # move every page's content to a permuted slot; update tables
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(vp)
    kv2 = jnp.asarray(np.asarray(kv)[np.argsort(perm)])
    tables2 = jnp.asarray(perm[np.asarray(tables)])
    out2k = paged_decode_attention(q, kv2, tables2, page_pos, positions,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2k),
                               atol=3e-5, rtol=3e-5)
