"""Shared test helpers: one reduced model (+ params) per arch for the whole
session. Engines are recreated freely across tests and A/B legs; sharing
the model instance also shares its serve-step jit cache (see
ModelRunner), which is most of the suite's wall-clock.

REPRO_ATTENTION_IMPL=kernel flips the default attention implementation so
the same suite exercises the Pallas varlen kernel path (the tier-1 CI
kernel leg); tests that pass attention_impl explicitly are unaffected.

``assert_greedy_equiv`` is the shared fork-aware cross-layout greedy
comparison (see the TIE_EPS note in ``repro.serving.engine``)."""
import os

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig

_MODELS = {}    # arch -> (model, cfg, params)


def get_model(arch):
    if arch not in _MODELS:
        cfg = reduced(ARCHS[arch])
        model = build_model(cfg, single_device_dist())
        _MODELS[arch] = (model, cfg, model.init(0))
    return _MODELS[arch]


def make_engine(arch="granite-3-2b", **cfg_kw):
    model, cfg, params = get_model(arch)
    kw = dict(kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
              attention_impl=os.environ.get("REPRO_ATTENTION_IMPL", "ref"))
    kw.update(cfg_kw)
    return Engine(model, EngineConfig(**kw), params=params), cfg


# Fork tolerance for cross-layout greedy comparisons: at a token
# divergence, BOTH modes' recorded fp32 logit rows must place BOTH chosen
# tokens within this gap of the row max — i.e. the decision was genuinely
# ambiguous under bf16 reduction-order noise (measured <= ~4e-3; real
# masking/leak bugs shift logits by >> 1e-1). Wider than TIE_EPS on
# purpose: the band makes near-ties deterministic per mode, the fork
# check bounds what may differ across modes.
TIE_FORK_TOL = 2.5e-2


def assert_greedy_equiv(ref_eng, other_eng, label=""):
    """Greedy outputs of two drained engines must be token-identical up
    to genuinely ambiguous forks. Exact equality is asserted until the
    first differing token of each request; that decision must be a
    near-tie in BOTH engines' recorded logit rows (TIE_FORK_TOL), after
    which the trajectories have legitimately forked and later tokens are
    incomparable. Requires ``record_sample_logits=True`` on both engines.
    Returns the set of forked request ids (empty == bitwise-exact)."""
    ref = {r.rid: list(r.output) for r in ref_eng.finished}
    other = {r.rid: list(r.output) for r in other_eng.finished}
    assert set(ref) == set(other), (label, set(ref) ^ set(other))
    forked = set()
    for rid in ref:
        a, b = ref[rid], other[rid]
        n = min(len(a), len(b))
        i = next((j for j in range(n) if a[j] != b[j]), None)
        if i is None:
            # identical prefix implies identical EOS decisions
            assert len(a) == len(b), (label, rid, a, b)
            continue
        la = ref_eng.sample_log[rid][i]
        lb = other_eng.sample_log[rid][i]
        ga = float(la.max() - la[b[i]])   # other's pick, scored by ref
        gb = float(lb.max() - lb[a[i]])   # ref's pick, scored by other
        assert ga <= TIE_FORK_TOL and gb <= TIE_FORK_TOL, (
            label, rid, i, a[i], b[i], ga, gb,
            "divergence beyond tie tolerance — not reduction-order noise")
        forked.add(rid)
    return forked
