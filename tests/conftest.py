"""Shared test helpers: one reduced model (+ params) per arch for the whole
session. Engines are recreated freely across tests and A/B legs; sharing
the model instance also shares its serve-step jit cache (see
ModelRunner), which is most of the suite's wall-clock."""
from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig

_MODELS = {}    # arch -> (model, cfg, params)


def get_model(arch):
    if arch not in _MODELS:
        cfg = reduced(ARCHS[arch])
        model = build_model(cfg, single_device_dist())
        _MODELS[arch] = (model, cfg, model.init(0))
    return _MODELS[arch]


def make_engine(arch="granite-3-2b", **cfg_kw):
    model, cfg, params = get_model(arch)
    kw = dict(kv_pool_bytes=8 << 20, max_running=4, chunk_size=8)
    kw.update(cfg_kw)
    return Engine(model, EngineConfig(**kw), params=params), cfg
