"""Router + data-parallel fleet tests.

Placement is a pure function of (config, arrival order, shard state), so
the unit tests drive ``Router.place`` against real engines with warmed /
loaded caches and assert the exact shard ids. The fleet tests then pin
the semantic contracts: a 1-shard fleet is BITWISE the solo engine, an
N-shard fleet is output-equivalent per request across all seven
archetypes (fork-aware — shard batch mixes differ from the solo batch
mix, so bf16 reduction orders legitimately differ), and any one shard's
execution replays BITWISE on a standalone engine given the same requests
at the same shard-local arrival steps. Drain/re-admission paths must
leak nothing and must never poison the prefix cache (the PR-3 rule)."""
import random

import pytest

from conftest import assert_greedy_equiv, get_model, make_engine
from repro.serving import (ROUTE_CACHE_AWARE, ROUTE_ROUND_ROBIN, DPEngine,
                           Engine, EngineConfig, Request, Router,
                           RouterConfig, SamplingParams, ShardHealth,
                           prefix_match_tokens)
from repro.serving.autotune import BudgetAutotuner, shard_pool_bytes

ARCHS7 = ["granite-3-2b", "h2o-danube-3-4b", "qwen2-vl-2b", "zamba2-1.2b",
          "rwkv6-3b", "whisper-tiny", "dbrx-132b"]


def _req(rid, prompt, out=4, eos=None):
    return Request(rid=rid, prompt=list(prompt),
                   sampling=SamplingParams(max_new_tokens=out,
                                           eos_token=eos))


def _dp(arch="granite-3-2b", n=2, policy=ROUTE_CACHE_AWARE, roles=None,
        **cfg_kw):
    model, cfg, params = get_model(arch)
    kw = dict(kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
              max_num_batched_tokens=64, record_sample_logits=True)
    kw.update(cfg_kw)
    return DPEngine(model, EngineConfig(**kw), params=params,
                    num_shards=n, policy=policy, split_pool=False,
                    roles=roles)


# ------------------------------------------------------------- placement
def test_place_longest_prefix_match_wins():
    """Warm shard 1's prefix cache with a long prompt; a request sharing
    that prefix must route to shard 1 even when shard 0 is emptier."""
    dp = _dp(n=3)
    warm = [(3 * j + 1) % 50 for j in range(24)]
    dp.shards[1].engine.submit(_req("warm", warm, out=2))
    dp.shards[1].engine.run_until_done()
    probe = _req("probe", warm + [7, 8, 9])
    hits = [prefix_match_tokens(probe, sh.engine.mgr) for sh in dp.shards]
    assert hits[1] > 0 and hits[0] == 0 and hits[2] == 0, hits
    assert dp.submit(probe) == 1
    # and a LONGER match elsewhere outbids a shorter one: extend shard 2's
    # cache past shard 1's
    dp.shards[2].engine.submit(_req("warm2", warm + [7, 8, 9, 10], out=2))
    dp.shards[2].engine.run_until_done()
    probe2 = _req("probe2", warm + [7, 8, 9, 10, 11])
    h1 = prefix_match_tokens(probe2, dp.shards[1].engine.mgr)
    h2 = prefix_match_tokens(probe2, dp.shards[2].engine.mgr)
    assert h2 > h1 > 0, (h1, h2)
    assert dp.submit(probe2) == 2


def test_place_least_loaded_tiebreak():
    """With no cache hits anywhere, placement falls to the shard with the
    fewest outstanding tokens, then to the lowest shard id."""
    dp = _dp(n=3)
    assert dp.submit(_req("a", [1, 2, 3], out=8)) == 0      # all empty
    assert dp.submit(_req("b", [4, 5, 6], out=8)) == 1      # 0 now loaded
    assert dp.submit(_req("c", [7, 8, 9], out=8)) == 2
    # loads now equal-ish; lowest id wins the residual tie only if loads
    # match exactly — just assert determinism of the recorded placements
    sids = [p.shard for p in dp.router.placements]
    assert sids == [0, 1, 2], sids


def test_place_deterministic_replay():
    """Same workload, same config => identical placement sequence."""
    def run():
        rng = random.Random(11)
        dp = _dp(n=3)
        for i in range(10):
            plen = rng.randint(3, 20)
            dp.submit(_req(f"r{i}", [rng.randint(0, 40)
                                     for _ in range(plen)], out=3))
            if rng.random() < 0.5:
                dp.step()
        dp.run_until_done()
        return [(p.rid, p.shard, p.hit_tokens) for p in dp.router.placements]
    assert run() == run()


def test_health_cost_steers_placement():
    """Defer/preempt deltas in a health poll bump a shard's routing cost
    and push traffic away; quiet polls decay it back."""
    dp = _dp(n=2)
    base = dp.shards[0].engine.health_snapshot()
    import dataclasses as dc
    # shard 0 reports 2 new defer events: cost 2 * 16 tokens
    dp.router.observe(0, dc.replace(base, defer_count=2))
    assert dp.router.costs[0] == pytest.approx(32.0)
    assert dp.submit(_req("a", [1, 2, 3])) == 1     # cost outweighs the tie
    # quiet polls decay the cost to zero -> lowest-id tiebreak returns.
    # (loads must be equal again: let shard 1 finish its request first)
    dp.run_until_done()
    for _ in range(40):
        dp.router.observe(0, dc.replace(base, defer_count=2))
    assert dp.router.costs[0] == 0.0
    assert dp.submit(_req("b", [4, 5, 6])) == 0


def test_round_robin_ignores_caches():
    dp = _dp(n=3, policy=ROUTE_ROUND_ROBIN)
    sids = [dp.submit(_req(f"r{i}", [i, i + 1])) for i in range(6)]
    assert sids == [0, 1, 2, 0, 1, 2], sids


def test_router_rejects_bad_config():
    with pytest.raises(AssertionError):
        Router(RouterConfig(policy="nope"))
    dp = _dp(n=2)
    for sh in dp.shards:
        sh.accepting = False
    with pytest.raises(RuntimeError):
        dp.router.place(_req("x", [1]), dp.shards)


# ------------------------------------------------- started-flag semantics
def test_started_flag_not_num_computed():
    """A prefix-cache hit at admission sets seq.num_computed WITHOUT any
    device work — ``started`` must still be False until the request is
    part of a dispatched plan, so a graceful drain can safely move it."""
    eng, _ = make_engine(max_num_batched_tokens=64,
                         enable_prefix_caching=True)
    warm = [(5 * j + 2) % 50 for j in range(16)]
    eng.submit(_req("warm", warm, out=2))
    eng.run_until_done()
    hot = _req("hot", warm + [1, 2, 3])
    eng.submit(hot)
    eng.scheduler.schedule()            # admits: prefix hit, no dispatch
    assert hot.seq is not None and hot.seq.num_computed > 0
    assert not hot.started              # scheduled != dispatched
    # drain pulls it (never dispatched), pages released back to cache
    drained = eng.drain_requests(unstarted_only=True)
    assert drained == [hot] and hot.seq is None and not hot.output
    eng.mgr.check_invariants()
    # once dispatched, started flips and a graceful drain skips it
    eng.submit(_req("late", [9, 8, 7], out=3))
    eng.step()
    assert eng.scheduler.running and all(
        r.started for r in eng.scheduler.running)
    assert eng.drain_requests(unstarted_only=True) == []
    eng.run_until_done()


def test_drain_unstarted_zero_leak_and_unpoisoned():
    """Graceful drain of admitted-but-unstarted requests releases their
    prefix-hit pages back to the cache UNCHANGED: a third engine admitting
    the same prompt afterwards gets the same hit, and the re-admitted
    request's own prefix-restart on another shard produces bit-identical
    output (the PR-3 poisoning regression, at the fleet level)."""
    dp = _dp(n=2, enable_prefix_caching=True)
    warm = [(3 * j + 4) % 50 for j in range(20)]
    dp.shards[0].engine.submit(_req("warm", warm, out=2))
    dp.shards[0].engine.run_until_done()
    ref_out = {r.rid: list(r.output) for r in dp.shards[0].engine.finished}

    hot = _req("hot", warm + [5, 6], out=4)
    assert dp.submit(hot) == 0          # follows its prefix
    dp.shards[0].engine.scheduler.schedule()    # admit (hit), no dispatch
    assert hot.seq is not None and not hot.started
    used_before = dp.shards[0].engine.mgr.memory_stats().used_units
    moved = dp.inject_stall(0, resume_after=2)
    assert moved == [hot] and hot.shard_history == [0, 1]
    # nothing leaked on the drained shard beyond the warm request's cache
    stats = dp.shards[0].engine.mgr.memory_stats()
    assert stats.used_units == 0 and used_before > 0, (stats, used_before)
    dp.check_invariants()
    dp.run_until_done()
    assert {r.rid for r in dp.finished} == {"warm", "hot"}

    # the same request cold on a solo engine: identical tokens
    solo, _ = make_engine(max_num_batched_tokens=64,
                          enable_prefix_caching=True)
    solo.submit(_req("hot", warm + [5, 6], out=4))
    solo.run_until_done()
    dp_out = {r.rid: list(r.output) for r in dp.finished}
    assert dp_out["hot"] == list(solo.finished[0].output)
    assert dp_out["warm"] == ref_out["warm"]
    # and shard 0's cache still serves the warm prefix (not poisoned)
    assert prefix_match_tokens(_req("p", warm + [9]),
                               dp.shards[0].engine.mgr) > 0


# ------------------------------------------------------ fleet equivalence
@pytest.mark.parametrize("arch", ARCHS7)
def test_fleet_outputs_match_solo(arch):
    """Every archetype: a 3-shard fleet finishes the same requests with
    the same greedy tokens as one solo engine (fork-aware: shard batch
    mixes differ from the solo mix)."""
    rng = random.Random(hash(arch) & 0xffff)
    model, cfg, params = get_model(arch)
    reqs = []
    for i in range(5):
        kw = {}
        prompt = [rng.randint(0, 49) for _ in range(rng.randint(4, 16))]
        if cfg.family == "vlm" and i % 2 == 0:
            from repro.core.request import MMItem
            kw["mm_items"] = (MMItem(0, min(3, len(prompt)), mm_hash=i),)
        if cfg.family == "encdec":
            from repro.core.request import MMItem
            kw["encoder_items"] = (MMItem(0, cfg.encoder_seq, mm_hash=i),)
        reqs.append(dict(rid=f"r{i}", prompt=prompt,
                         out=rng.randint(2, 5), kw=kw))

    def build(r):
        return Request(rid=r["rid"], prompt=list(r["prompt"]),
                       sampling=SamplingParams(max_new_tokens=r["out"]),
                       **r["kw"])

    ecfg = dict(kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
                max_num_batched_tokens=64, record_sample_logits=True)
    solo = Engine(model, EngineConfig(**ecfg), params=params)
    for r in reqs:
        solo.submit(build(r))
    solo.run_until_done()

    dp = DPEngine(model, EngineConfig(**ecfg), params=params,
                  num_shards=3, split_pool=False)
    for r in reqs:
        dp.submit(build(r))
    dp.run_until_done()
    dp.check_invariants()
    for sh in dp.shards:
        assert sh.engine.mgr.memory_stats().used_units == 0
    assert_greedy_equiv(solo, dp, label=f"fleet-{arch}")


def test_router1_bitwise_equals_solo():
    """A 1-shard fleet IS the solo engine plus a pass-through router:
    outputs must match bit for bit, no fork tolerance."""
    rng = random.Random(3)
    solo, _ = make_engine(max_num_batched_tokens=64)
    dp = _dp(n=1)
    for i in range(6):
        prompt = [rng.randint(0, 49) for _ in range(rng.randint(3, 18))]
        solo.submit(_req(f"r{i}", prompt, out=4))
        dp.submit(_req(f"r{i}", prompt, out=4))
        solo.step()
        dp.step()
    solo.run_until_done()
    dp.run_until_done()
    assert {r.rid: list(r.output) for r in solo.finished} \
        == {r.rid: list(r.output) for r in dp.finished}


def test_shard_replay_bitwise():
    """Any one shard's run replays bitwise on a standalone engine: same
    requests, same shard-local arrival steps => same batches, same
    dispatches, same tokens. (This is the determinism contract that makes
    fleet failures debuggable shard by shard.)"""
    rng = random.Random(17)
    dp = _dp(n=3)
    reqs = {}
    for i in range(9):
        r = _req(f"r{i}", [rng.randint(0, 49)
                           for _ in range(rng.randint(3, 15))], out=3)
        reqs[r.rid] = r
        dp.submit(r)
        if rng.random() < 0.6:
            dp.step()
    dp.run_until_done()
    for sh in dp.shards:
        fin = sh.engine.finished
        if not fin:
            continue
        replay, _ = make_engine(max_num_batched_tokens=64,
                                record_sample_logits=True)
        pending = sorted(fin, key=lambda r: (r.arrival, r.rid))
        guard = 0
        while pending or replay.scheduler.has_work() or replay.has_inflight:
            while pending and pending[0].arrival <= replay.step_count:
                src = pending.pop(0)
                replay.submit(_req(src.rid, src.prompt,
                                   out=src.sampling.max_new_tokens))
            if not replay.scheduler.has_work() and not replay.has_inflight:
                src = pending.pop(0)    # idle gap: arrivals don't advance
                replay.submit(_req(src.rid, src.prompt,
                                   out=src.sampling.max_new_tokens))
            replay.step()
            guard += 1
            assert guard < 500
        assert {r.rid: list(r.output) for r in replay.finished} \
            == {r.rid: list(r.output) for r in fin}, sh.sid


# ------------------------------------------------------------- autotuner
def test_autotuner_shard_window_scaling():
    """Per-shard budgets: the roofline seed is per-device (unchanged by
    fleet size), but the observation window scales with N — a shard sees
    1/N of the traffic, so it needs N x the steps before moving budgets."""
    _, cfg, _ = get_model("granite-3-2b")
    one = BudgetAutotuner(cfg)
    four = BudgetAutotuner(cfg, num_shards=4)
    assert four.budget == one.budget
    assert four.prefill_cap == one.prefill_cap
    assert four.window == 4 * one.window
    assert shard_pool_bytes(100, 4) == 25
    assert shard_pool_bytes(3, 8) == 1      # floor, never zero


def test_fleet_autotuned_budgets_per_shard():
    dp = _dp(n=2, autotune_budgets=True)
    for sh in dp.shards:
        assert sh.engine.autotuner is not None
        assert sh.engine.autotuner.num_shards == 2
    dp.submit(_req("a", [1, 2, 3, 4], out=3))
    dp.run_until_done()
    assert len(dp.finished) == 1


# --------------------------------------- prefill/decode disaggregation
def _mk_mgr_pair(n_large_src=16, n_large_dst=16):
    """Two standalone managers sharing a spec set — a prefill shard's and a
    decode shard's pools, without the engines around them."""
    from repro.core import (BYTES_PER_UNIT, JengaKVCacheManager,
                            attention_spec, make_geometry, mamba_spec)
    specs = [attention_spec("full_attn", num_layers=2, kv_heads=1,
                            head_dim=64, tokens_per_page=4),
             mamba_spec("ssm", num_layers=2, conv_units=64, ssm_units=64,
                        checkpoint_interval=4)]
    g = make_geometry(specs, total_memory_bytes=10**9)

    def mk(n_large):
        return JengaKVCacheManager(
            specs, total_memory_bytes=g.large_page_units * n_large *
            BYTES_PER_UNIT)
    return mk(n_large_src), mk(n_large_dst)


def test_export_adopt_roundtrip():
    """Manager-level handoff: export a computed request's typed page set,
    adopt it on a second manager. The destination mirrors the tables,
    registers the same hashes, resumes at the same position with zero
    tokens left to recompute — and both sides drain clean."""
    from repro.core import SequenceState
    src, dst = _mk_mgr_pair()
    r = SequenceState(rid="h0", tokens=list(range(100, 112)))
    ok, _ = src.begin_request(r)
    assert ok
    assert src.allocate_for_tokens(r, 12)
    src.advance(r, 12)
    export = src.export_request(r)
    assert export.num_tokens == 12

    r2 = SequenceState(rid="h0", tokens=list(r.tokens))
    ok, pairs = dst.adopt_request(r2, export)
    assert ok and pairs
    # position restored: nothing to recompute, chains continue verbatim
    assert r2.num_computed == 12 and r2.prefix_hit_tokens == 12
    assert len(r2.page_tables["full_attn"]) == len(r.page_tables["full_attn"])
    assert r2.page_hashes == r.page_hashes
    # every copy pair reads a USED source page into a USED dest page
    for name, s_eid, d_eid in pairs:
        from repro.core import PageState
        assert src.pools[name].pages[s_eid].state == PageState.USED
        assert dst.pools[name].pages[d_eid].state == PageState.USED
    assert dst.handoff_adopted == 1
    assert dst.handoff_pages_adopted == len(pairs)

    # decode continues on the destination as if it computed the prefill
    assert dst.allocate_for_tokens(r2, 14)
    r2.tokens.extend([7, 8])
    dst.advance(r2, 14)
    dst.free_request(r2, cache=True)
    # source side: release retires its copy into the prefix cache
    src.release_export(r, export)
    assert src.memory_stats().used_units == 0
    assert dst.memory_stats().used_units == 0
    src.check_invariants()
    dst.check_invariants()
    # both caches now serve the prompt: a fresh same-prompt arrival hits
    for m in (src, dst):
        probe = SequenceState(rid="p", tokens=list(range(100, 112)))
        assert m.lookup_prefix(probe) > 0, "adopted hashes not registered"


def test_adopt_failure_rolls_back():
    """Destination pool pressure mid-adopt: every allocation is undone,
    the request is cleared, and the source cancels back to normal
    ownership — the §5.4 transaction across a shard boundary."""
    from repro.core import SequenceState
    src, dst = _mk_mgr_pair(n_large_dst=1)     # destination cannot fit it
    r = SequenceState(rid="h1", tokens=list(range(100, 124)))
    ok, _ = src.begin_request(r)
    assert ok
    assert src.allocate_for_tokens(r, 24)
    src.advance(r, 24)
    export = src.export_request(r)

    before = dst.memory_stats()
    r2 = SequenceState(rid="h1", tokens=list(r.tokens))
    ok, pairs = dst.adopt_request(r2, export)
    assert not ok and pairs == []
    after = dst.memory_stats()
    assert after.used_units == before.used_units == 0, (before, after)
    assert not r2.page_tables and not r2.state_pages and not r2.ckpt_pages
    assert r2.num_computed == 0
    dst.check_invariants()
    # failover: the source cancels the export and keeps running
    src.cancel_export(export)
    src.free_request(r, cache=False)
    assert src.memory_stats().used_units == 0
    src.check_invariants()


def test_place_role_filter_and_fallback():
    """``want`` restricts placement to role-compatible shards; when no
    accepting shard qualifies the filter is dropped, not fatal — a
    degraded fleet keeps serving colocated."""
    dp = _dp(n=3, roles=["prefill", "decode", "decode"])
    assert dp.router.place(_req("a", [1, 2, 3]), dp.shards,
                           want="prefill") == 0
    assert dp.router.place(_req("b", [1, 2, 3]), dp.shards,
                           want="decode") in (1, 2)
    dp.shards[1].accepting = False
    dp.shards[2].accepting = False
    # no decode-capable shard accepting: fall back to whoever is
    assert dp.router.place(_req("c", [1, 2, 3]), dp.shards,
                           want="decode") == 0


def test_disagg_zero_decode_prefill_and_matches_solo():
    """The tentpole contract: a prefill/decode split fleet finishes every
    request with the solo engine's greedy tokens, the decode shard
    computes ZERO prefill tokens (handoff admits whole-prompt hits), the
    handoff log is populated, and both shards drain leak-free."""
    rng = random.Random(7)
    reqs = [(f"r{i}",
             [rng.randint(0, 49) for _ in range(rng.randint(4, 20))],
             rng.randint(2, 5))
            for i in range(5)]
    solo, _ = make_engine(max_num_batched_tokens=64,
                          record_sample_logits=True)
    for rid, prompt, out in reqs:
        solo.submit(_req(rid, prompt, out=out))
    solo.run_until_done()

    dp = _dp(n=2, roles=["prefill", "decode"])
    for rid, prompt, out in reqs:
        dp.submit(_req(rid, prompt, out=out))
    dp.run_until_done()
    dp.check_invariants()
    assert len(dp.finished) == len(reqs)
    assert dp.handoffs, "no handoffs happened — disagg never engaged"
    fs = dp.fleet_stats()
    assert fs["handoffs"] == len(dp.handoffs)
    assert fs["handoff_pages"] > 0
    # decode shard never computed a prefill token
    dec = dp.shards[1].engine
    assert sum(m.prefill_tokens for m in dec.metrics) == 0
    # prefill shard never decoded: every handed-off request left at
    # exactly its prompt boundary (t0 sampled, zero decode steps)
    for h in dp.handoffs:
        prompt = next(p for rid, p, _ in reqs if rid == h["rid"])
        assert h["tokens"] == len(prompt), h
    for sh in dp.shards:
        assert sh.engine.mgr.memory_stats().used_units == 0, sh.sid
        assert not sh.engine.runner._mirrors
    assert_greedy_equiv(solo, dp, label="disagg")


def test_disagg_all_decode_dead_falls_back_colocated():
    """Failover: every decode-capable shard dies while prefill-complete
    requests await handoff — the fleet flips the surviving prefill shard
    to colocated ("both") and still finishes everything exactly once."""
    rng = random.Random(23)
    reqs = [(f"r{i}",
             [rng.randint(0, 49) for _ in range(rng.randint(6, 16))], 4)
            for i in range(4)]
    solo, _ = make_engine(max_num_batched_tokens=64,
                          record_sample_logits=True)
    for rid, prompt, out in reqs:
        solo.submit(_req(rid, prompt, out=out))
    solo.run_until_done()

    dp = _dp(n=2, roles=["prefill", "decode"])
    for rid, prompt, out in reqs:
        dp.submit(_req(rid, prompt, out=out))
    dp.step()
    dp.inject_crash(1)                  # the only decode shard dies
    dp.run_until_done()
    assert dp.fleet_stats()["role_failovers"] >= 1
    assert dp.shards[0].engine.role == "both"   # flipped to colocated
    rids = [r.rid for r in dp.finished]
    assert sorted(rids) == sorted(r[0] for r in reqs)
    assert len(rids) == len(set(rids))
    for sh in dp.shards:
        assert sh.engine.mgr.memory_stats().used_units == 0, sh.sid
    assert_greedy_equiv(solo, dp, label="disagg-failover")
