"""PageSan mutation tests: a sanitizer that cannot fail is untested.

Every detection class gets an injected bug — double-free, free-while-
cached, leak at drain, poisoned state re-cache, poisoned checkpoint
registration, gather-from-freed — plus the two clean-path guarantees:
zero behaviour change with the sanitizer on (same outputs, same step
counts) and zero-cost no-op when disabled.
"""
import numpy as np
import pytest

from conftest import make_engine
from repro.analysis import PageSanError
from repro.core import (BYTES_PER_UNIT, JengaKVCacheManager, PageState,
                        SequenceState, attention_spec, cross_attention_spec,
                        make_geometry, mamba_spec)
from repro.serving import Request, SamplingParams


def specs_attn():
    """Fig. 6 geometry: small pages share large pages (spp 2 and 3), so a
    single free never retires the whole large page under the test's feet."""
    return [
        attention_spec("full_attn", num_layers=3, kv_heads=1, head_dim=64,
                       tokens_per_page=1),
        cross_attention_spec("cross_attn", num_layers=2, kv_heads=1,
                             head_dim=64, tokens_per_page=1),
    ]


def specs_state():
    return specs_attn() + [
        mamba_spec("ssm", num_layers=2, conv_units=64, ssm_units=64,
                   checkpoint_interval=4),
    ]


def mk_mgr(specs, n_large=16, **kw):
    kw.setdefault("page_sanitizer", True)
    g = make_geometry(specs, total_memory_bytes=10**9)
    return JengaKVCacheManager(
        specs, total_memory_bytes=g.large_page_units * n_large *
        BYTES_PER_UNIT, **kw)


def run_req(m, rid="r0", n=6):
    r = SequenceState(rid=rid, tokens=list(range(100, 100 + n)))
    ok, _ = m.begin_request(r)
    assert ok
    assert m.allocate_for_tokens(r, n)
    m.advance(r, n)
    return r


# ------------------------------------------------------------ env gating
def test_sanitizer_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_PAGE_SANITIZER", raising=False)
    m = mk_mgr(specs_attn(), page_sanitizer=None)
    assert m.sanitizer is None
    assert all(p.san is None for p in m.pools.values())


def test_sanitizer_env_enables(monkeypatch):
    monkeypatch.setenv("REPRO_PAGE_SANITIZER", "1")
    m = mk_mgr(specs_attn(), page_sanitizer=None)
    assert m.sanitizer is not None
    assert all(p.san is m.sanitizer for p in m.pools.values())
    monkeypatch.setenv("REPRO_PAGE_SANITIZER", "0")
    assert mk_mgr(specs_attn(), page_sanitizer=None).sanitizer is None


# ---------------------------------------------------------- clean paths
def test_clean_lifecycle_drains_and_verifies():
    m = mk_mgr(specs_state())
    r = run_req(m, n=12)
    m.check_invariants()            # includes shadow-vs-pool verify
    m.free_request(r, cache=True)
    m.check_invariants()
    m.sanitizer.assert_drained()    # cached pages are not leaks
    # a prefix hit re-acquires cached pages and returns them again
    r2 = SequenceState(rid="r1", tokens=list(range(100, 112)))
    m.begin_request(r2)
    assert m.allocate_for_tokens(r2, 12)
    m.advance(r2, 12)
    m.free_request(r2, cache=False)
    m.check_invariants()
    m.sanitizer.assert_drained()


# ------------------------------------------------------------ injections
def test_double_free_caught():
    m = mk_mgr(specs_attn())
    r = run_req(m)
    pool = m.pools["full_attn"]
    eid = r.page_tables["full_attn"][0]
    pool.free(eid)
    with pytest.raises(PageSanError, match="double free"):
        pool.free(eid)
    assert m.sanitizer.errors_raised == 1


def test_free_while_cached_caught():
    m = mk_mgr(specs_attn())
    r = run_req(m)
    m.free_request(r, cache=True)
    pool = m.pools["full_attn"]
    cached_eid = next(iter(pool.cached.values()))
    with pytest.raises(PageSanError, match="prefix cache"):
        pool.free(cached_eid)


def test_leak_at_drain_caught_with_owner_and_site():
    m = mk_mgr(specs_attn())
    run_req(m, rid="leaky")
    with pytest.raises(PageSanError) as ei:
        m.sanitizer.assert_drained()
    msg = str(ei.value)
    assert "leaked" in msg and "leaky" in msg and "allocated_at" in msg


def test_poisoned_state_recache_caught():
    """The §5.3 rule: a state page whose owner still has dispatched steps
    in flight must NOT enter the prefix cache — its device content runs
    ahead of the boundary hash."""
    m = mk_mgr(specs_state())
    r = run_req(m, n=8)             # interval 4 -> boundary hash at 8
    m.sanitizer.set_inflight({r.rid})
    with pytest.raises(PageSanError, match="cache-poisoning"):
        m.free_request(r, cache=True)           # cache_state defaults True


def test_state_recache_suppressed_is_clean():
    """cache_state=False (what the engine passes for EOS finishes with
    killed-but-dispatched deeper steps) plain-frees the state page."""
    m = mk_mgr(specs_state())
    r = run_req(m, n=8)
    m.sanitizer.set_inflight({r.rid})
    m.free_request(r, cache=True, cache_state=False)
    m.sanitizer.clear_inflight(r.rid)
    m.sanitizer.assert_drained()
    m.check_invariants()


def test_poisoned_checkpoint_registration_caught():
    """Checkpoint copies snapshot the live page at a boundary; if deeper
    dispatched steps keep mutating it, the snapshot is over-advanced."""
    m = mk_mgr(specs_state())
    r = SequenceState(rid="r0", tokens=list(range(100, 108)))
    m.begin_request(r)
    assert m.allocate_for_tokens(r, 8)
    m.sanitizer.set_inflight({r.rid})
    with pytest.raises(PageSanError, match="cache-poisoning"):
        m.advance(r, 8)             # crosses checkpoint boundaries 4 and 8
    # allow_checkpoints=False (the engine's depth>=3 guard) is clean
    m2 = mk_mgr(specs_state())
    r2 = SequenceState(rid="r0", tokens=list(range(100, 108)))
    m2.begin_request(r2)
    assert m2.allocate_for_tokens(r2, 8)
    m2.sanitizer.set_inflight({r2.rid})
    assert m2.advance(r2, 8, allow_checkpoints=False) == []
    m2.check_invariants()


def test_gather_from_freed_caught():
    m = mk_mgr(specs_attn())
    r = run_req(m)
    eid = r.page_tables["full_attn"][0]
    m.pools["full_attn"].free(eid)
    arrs = {
        "tables": {"full_attn": np.asarray([[eid]], np.int32)},
        "write_eids": None, "state_eids": None, "page_seg": None,
    }
    with pytest.raises(PageSanError, match="gather-from-freed"):
        m.sanitizer.check_dispatch(arrs)
    # killed segments (page_seg < 0) are excluded from the check
    arrs["page_seg"] = {"full_attn": np.asarray([[-2]], np.int32)}
    m.sanitizer.check_dispatch(arrs)


def test_windowed_cached_table_entry_allowed():
    """SWA in-flight retirement caches slid-out pages while an already-
    prepared async dispatch still lists the eid: CACHED is legal in a
    windowed spec's tables (the gather is window-masked), but a plain-
    freed page is still a bug."""
    specs = [attention_spec("swa", num_layers=3, kv_heads=1, head_dim=64,
                            tokens_per_page=1, kind="swa", sliding_window=2),
             cross_attention_spec("cross_attn", num_layers=2, kv_heads=1,
                                  head_dim=64, tokens_per_page=1)]
    m = mk_mgr(specs)
    run_req(m)          # window 2: advance retires pages 0..3 to the cache
    pool = m.pools["swa"]
    assert pool.cached, "in-flight retirement should have cached pages"
    eid = next(iter(pool.cached.values()))
    arrs = {"tables": {"swa": np.asarray([[eid]], np.int32)},
            "write_eids": None, "state_eids": None, "page_seg": None}
    m.sanitizer.check_dispatch(arrs)        # CACHED: fine for swa tables
    assert pool._pop_small_evictable() == eid
    with pytest.raises(PageSanError, match="gather-from-freed"):
        m.sanitizer.check_dispatch(arrs)    # now actually FREE: caught


# -------------------------------------------------- handoff / in-transit
def test_export_release_roundtrip_clean():
    """Clean handoff source side: export moves every page to IN_TRANSIT
    (verify accepts the pool's USED for it), release returns them to plain
    ownership and retires the request into the prefix cache — drained."""
    m = mk_mgr(specs_state())
    r = run_req(m, n=8)
    export = m.export_request(r)
    m.check_invariants()            # shadow IN_TRANSIT vs pool USED: legal
    m.release_export(r, export)
    m.sanitizer.assert_drained()
    m.check_invariants()


def test_lost_in_transit_caught_at_drain():
    """An export never released nor cancelled is a mid-handoff crash that
    leaked its pages — drain reports them as lost-in-transit, with the
    owner and export site, distinct from a generic leak."""
    m = mk_mgr(specs_attn())
    r = run_req(m, rid="crashed")
    m.export_request(r)
    with pytest.raises(PageSanError) as ei:
        m.sanitizer.assert_drained()
    msg = str(ei.value)
    assert "lost-in-transit" in msg and "LOST IN TRANSIT" in msg
    assert "crashed" in msg and "exported_at" in msg


def test_free_of_in_transit_page_caught():
    """The copy stream still reads exported pages: freeing one mid-handoff
    is use-after-free on the destination. Cancel lifts the marks and the
    source frees normally."""
    m = mk_mgr(specs_attn())
    r = run_req(m)
    export = m.export_request(r)
    eid = r.page_tables["full_attn"][0]
    with pytest.raises(PageSanError, match="exported for handoff"):
        m.pools["full_attn"].free(eid)
    m.cancel_export(export)         # failover path: source keeps ownership
    m.free_request(r, cache=False)
    m.sanitizer.assert_drained()
    m.check_invariants()


def test_double_export_caught():
    """One page set, one handoff: exporting a page already in transit
    means two destinations would copy from (and then own) it."""
    m = mk_mgr(specs_attn())
    r = run_req(m)
    m.export_request(r)
    with pytest.raises(PageSanError, match="double export"):
        m.export_request(r)


def test_double_adopt_caught():
    """Completing the same export twice (release after cancel) means the
    handoff was adopted on two destinations."""
    m = mk_mgr(specs_attn())
    r = run_req(m)
    export = m.export_request(r)
    m.cancel_export(export)
    with pytest.raises(PageSanError, match="export completion"):
        m.cancel_export(export)


def test_verify_detects_shadow_pool_divergence():
    m = mk_mgr(specs_attn())
    r = run_req(m)
    pool = m.pools["full_attn"]
    eid = r.page_tables["full_attn"][0]
    # bypass the event hooks entirely — exactly the misuse verify exists for
    pool.pages[eid].state = PageState.EMPTY
    with pytest.raises(PageSanError, match="diverged"):
        m.sanitizer.verify(m.pools)


# ------------------------------------------------------ engine integration
def _run_engine(monkeypatch, san, **cfg_kw):
    if san:
        monkeypatch.setenv("REPRO_PAGE_SANITIZER", "1")
    else:
        monkeypatch.delenv("REPRO_PAGE_SANITIZER", raising=False)
    eng, _ = make_engine("zamba2-1.2b", **cfg_kw)
    for i in range(4):
        eng.submit(Request(
            rid=f"r{i}", prompt=[(7 * i + j) % 50 for j in range(6 + 3 * i)],
            sampling=SamplingParams(max_new_tokens=6)))
    eng.run_until_done()
    assert (eng.mgr.sanitizer is not None) == san
    if san:
        eng.mgr.sanitizer.assert_drained()
        eng.mgr.check_invariants()
    return {r.rid: list(r.output) for r in eng.finished}, eng.step_count


@pytest.mark.parametrize("kw", [
    dict(async_scheduling=False),
    dict(async_scheduling=True, pipeline_depth=2),
    dict(async_scheduling=True, pipeline_depth=4),
], ids=["sync", "async2", "async4"])
def test_engine_unchanged_under_sanitizer(monkeypatch, kw):
    """Sanitizer on == sanitizer off: same tokens, same step counts — it
    observes, it never steers. zamba2 exercises the state-kind (mamba)
    poison checks through real checkpoint traffic."""
    base_out, base_steps = _run_engine(monkeypatch, False, **kw)
    san_out, san_steps = _run_engine(monkeypatch, True, **kw)
    assert san_out == base_out
    assert san_steps == base_steps


def test_engine_mid_run_double_free_caught(monkeypatch):
    # zamba2: hundreds of small pages per large page, so the request's
    # sibling pages keep the large page alive across the first free
    monkeypatch.setenv("REPRO_PAGE_SANITIZER", "1")
    eng, _ = make_engine("zamba2-1.2b")
    eng.submit(Request(rid="a", prompt=list(range(9)),
                       sampling=SamplingParams(max_new_tokens=5)))
    eng.step()
    req = next(r for r in eng.scheduler.running if r.rid == "a")
    table = req.seq.page_tables["full_attn"]
    assert len(table) >= 2 and table[0] >= 0
    pool = eng.mgr.pools["full_attn"]
    pool.free(table[0])
    with pytest.raises(PageSanError, match="double free"):
        pool.free(table[0])


def test_engine_gather_from_freed_caught(monkeypatch):
    """Free a live page behind the engine's back: the very next dispatch
    still references it through the request's table and must fail."""
    monkeypatch.setenv("REPRO_PAGE_SANITIZER", "1")
    eng, _ = make_engine("granite-3-2b")
    eng.submit(Request(rid="a", prompt=list(range(9)),
                       sampling=SamplingParams(max_new_tokens=5)))
    eng.step()
    req = next(r for r in eng.scheduler.running if r.rid == "a")
    name, table = next((n, t) for n, t in req.seq.page_tables.items()
                       if t and t[0] >= 0)
    eng.mgr.pools[name].free(table[0])
    with pytest.raises(PageSanError, match="gather-from-freed"):
        for _ in range(50):
            eng.step()


# ----------------------------------------- deferred catch-up checkpoints
class SmallInterval:
    """Model proxy: same geometry, state checkpoints every ``interval``
    tokens. ``state_checkpoint_interval`` does not enter page_units, so
    only checkpoint cadence changes — the reduced models' default of 512
    never crosses a boundary inside a small engine test."""

    def __init__(self, model, interval=8):
        self._m, self._iv = model, interval

    def __getattr__(self, k):
        return getattr(self._m, k)

    def kv_specs(self):
        import dataclasses
        return tuple(
            dataclasses.replace(s, state_checkpoint_interval=self._iv)
            if s.kind in ("mamba", "rwkv") else s
            for s in self._m.kv_specs())


def test_deferred_checkpoints_catch_up_at_depth4(monkeypatch):
    """Depth >= 3 suppresses state-checkpoint copies at boundary crossings
    (the live page runs ahead of the boundary under deep pipelining) —
    but suppressed boundaries must be DEFERRED, not dropped: a catch-up
    snapshot fires at the next quiet advance, so a long-decode run ends
    with the same checkpoint set as the sync engine. At depth <= 2 the
    machinery is a provable no-op. Outputs are bit-identical throughout
    (checkpoints feed the prefix cache, never the compute)."""
    from conftest import get_model
    from repro.serving import Engine, EngineConfig

    monkeypatch.setenv("REPRO_PAGE_SANITIZER", "1")
    model, _, params = get_model("zamba2-1.2b")
    pm = SmallInterval(model)
    base = dict(kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
                max_num_batched_tokens=64)

    def run(depth):
        kw = dict(base)
        if depth > 1:
            kw.update(async_scheduling=True, pipeline_depth=depth)
        eng = Engine(pm, EngineConfig(**kw), params=params)
        for i in range(3):
            eng.submit(Request(rid=f"r{i}", prompt=[7 + i, 3, 9, 2 + i],
                               sampling=SamplingParams(max_new_tokens=40)))
        eng.run_until_done()
        out = {r.rid: list(r.output) for r in eng.finished}
        ckpt_hashes = {
            name: sorted(pool.cached)
            for name, pool in eng.mgr.pools.items()
            if eng.mgr.spec(name).kind in ("mamba", "rwkv")}
        eng.mgr.sanitizer.assert_drained()
        eng.mgr.check_invariants()
        return (out, ckpt_hashes, eng.mgr.suppressed_checkpoints,
                eng.mgr.catchup_checkpoints)

    o1, ck1, sup1, cu1 = run(1)
    o2, ck2, sup2, cu2 = run(2)
    o4, ck4, sup4, cu4 = run(4)
    assert o1 == o2 == o4                       # bit-identical outputs
    assert sup1 == cu1 == 0                     # sync never suppresses
    assert sup2 == cu2 == 0                     # depth 2: provable no-op
    assert sup4 > 0, "depth 4 never suppressed a boundary — dead test"
    assert cu4 == sup4, (sup4, cu4)             # every deferral caught up
    # the prefix cache ends with the SAME checkpoint hashes as sync
    assert ck4 == ck1, {k: (len(ck1[k]), len(ck4[k])) for k in ck1}


def test_engine_leak_caught_at_drain(monkeypatch):
    """Drop a page from the request's table mid-run (free_request will
    skip it): the page stays ALLOCATED forever and drain reports it."""
    monkeypatch.setenv("REPRO_PAGE_SANITIZER", "1")
    eng, _ = make_engine("granite-3-2b")
    eng.submit(Request(rid="a", prompt=list(range(9)),
                       sampling=SamplingParams(max_new_tokens=3)))
    eng.step()
    req = next(r for r in eng.scheduler.running if r.rid == "a")
    name, table = next((n, t) for n, t in req.seq.page_tables.items()
                       if t and t[0] >= 0)
    req.seq.mark_freed(name, 0)     # forget the page without freeing it
    eng.run_until_done()
    with pytest.raises(PageSanError, match="leaked"):
        eng.mgr.sanitizer.assert_drained()
