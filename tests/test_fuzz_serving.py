"""Deterministic serving fuzz harness.

Randomized workloads — staggered arrival steps, random prompt/output
lengths, mm/encoder items, shared prefixes, random EOS tokens, pool sizes
tight enough to force preemption — are driven through the engine in async,
synchronous-packed, and serial modes, asserting for every model archetype:

  * greedy token equality: async == sync bit for bit; sync == serial
    token-exact up to fork-checked ambiguous near-ties;
  * no page leaks after drain: zero referenced pages, and with prefix
    caching off the pool's free count is fully restored;
  * refcount / mirror invariants: ``check_invariants`` on every pool plus
    no runner mirror survives its request;
  * transactional rollback on injected OOM: an unsatisfiable batch
    allocation mid-run leaves the manager bit-identical.

Every case derives from a stdlib ``random.Random`` seed, so a failure
reproduces from the seed alone. When hypothesis is installed the same
machinery runs under its strategies with shrinking on top
(``test_fuzz_hypothesis_async_equals_sync``); the seeded tests keep the
coverage alive when it is not.

async == sync is a STRICT bitwise property (double buffering reorders
host work only — plans, dispatch shapes, and reduction orders are
identical) and is asserted exactly. sync == serial changes bf16
reduction orders (packed stream vs one-request steps, MoE expert tiling,
mamba2 packed vs chunked scans), so it is compared with the fork-aware
checker (``conftest.assert_greedy_equiv``): token-exact until a
divergence, which must itself be a genuinely ambiguous near-tie in both
modes' recorded fp32 logit rows — a real semantic bug (leak, wrong mask)
diverges with a large gap and still fails. No seed pinning needed.
"""
import os
import random
import zlib

import pytest

from conftest import assert_greedy_equiv, get_model
from repro.core.request import MMItem
from repro.serving import (DPEngine, Engine, EngineConfig, Request,
                           SamplingParams)


# ------------------------------------------------------------- generator
def gen_workload(rng: random.Random, cfg, *, n_lo=2, n_hi=4, p_hi=22):
    """One random workload: a list of (arrival_step, request_spec) dicts.
    Specs, not Request objects — each engine run builds fresh requests."""
    out = []
    n = rng.randint(n_lo, n_hi)
    shared = [rng.randint(0, 49) for _ in range(rng.randint(4, 10))]
    for i in range(n):
        plen = rng.randint(1, p_hi)
        spec = dict(
            rid=f"r{i}",
            prompt=([*shared] + [rng.randint(0, 49) for _ in range(plen)]
                    if rng.random() < 0.4 else
                    [rng.randint(0, 49) for _ in range(plen)]),
            max_new_tokens=rng.randint(1, 7),
            # greedy runs emit tokens in a narrow band; a random EOS in it
            # sometimes triggers the speculative kill/rollback path
            eos_token=rng.choice([None, rng.randint(5, 25)]),
            arrival=rng.randint(0, 5),
            mm=None, enc=None,
        )
        if cfg.family == "vlm" and rng.random() < 0.6:
            p = len(spec["prompt"])
            start = rng.randint(0, max(0, p - 2))
            spec["mm"] = (start, rng.randint(1, max(1, min(5, p - start))),
                          rng.randint(0, 2))
        if cfg.family == "encdec":
            spec["enc"] = (0, cfg.encoder_seq, rng.randint(0, 2))
        out.append(spec)
    return out


def build_request(spec):
    kw = {}
    if spec["mm"]:
        s, l, h = spec["mm"]
        kw["mm_items"] = (MMItem(s, l, mm_hash=h),)
    if spec["enc"]:
        s, l, h = spec["enc"]
        kw["encoder_items"] = (MMItem(s, l, mm_hash=h),)
    return Request(rid=spec["rid"], prompt=list(spec["prompt"]),
                   sampling=SamplingParams(
                       max_new_tokens=spec["max_new_tokens"],
                       eos_token=spec["eos_token"]), **kw)


def drive(eng, workload):
    """Submit with staggered arrivals and run to drain."""
    pending = sorted(workload, key=lambda s: (s["arrival"], s["rid"]))
    guard = 0
    while pending or eng.scheduler.has_work() or eng.has_inflight:
        while pending and pending[0]["arrival"] <= eng.step_count:
            eng.submit(build_request(pending.pop(0)))
        if not eng.scheduler.has_work() and not eng.has_inflight:
            eng.submit(build_request(pending.pop(0)))   # skip the idle gap
        eng.step()
        guard += 1
        assert guard < 3000, "fuzz workload failed to drain"
    return {r.rid: list(r.output) for r in eng.finished}


def check_drained(eng, n_req):
    """Leak / invariant sweep after drain."""
    assert len(eng.finished) == n_req, \
        (len(eng.finished), eng.scheduler.preemption_count)
    eng.mgr.check_invariants()
    san = getattr(eng.mgr, "sanitizer", None)
    if san is not None:     # REPRO_PAGE_SANITIZER=1 CI leg
        san.assert_drained()
    stats = eng.mgr.memory_stats()
    assert stats.used_units == 0, f"leaked referenced pages: {stats}"
    assert not eng.runner._mirrors, list(eng.runner._mirrors)
    if not eng.cfg.enable_prefix_caching:
        # nothing cached -> the pool's free count is fully restored
        assert stats.free_units == stats.total_units, stats


def run_mode(arch, workload, *, mode="packed", async_=False, pool=8 << 20,
             caching=True, budget=64):
    model, cfg, params = get_model(arch)
    eng = Engine(model, EngineConfig(
        kv_pool_bytes=pool, max_running=4, chunk_size=8,
        max_num_batched_tokens=budget, batching_mode=mode,
        async_scheduling=async_, enable_prefix_caching=caching,
        record_sample_logits=True),
        params=params)
    outs = drive(eng, workload)
    check_drained(eng, len(workload))
    return eng, outs


# ------------------------------------------------------------ arch sweep
@pytest.mark.parametrize("arch", ["granite-3-2b", "h2o-danube-3-4b",
                                  "qwen2-vl-2b", "zamba2-1.2b", "rwkv6-3b",
                                  "whisper-tiny", "dbrx-132b"])
def test_fuzz_async_sync_serial_equal(arch):
    """For every archetype: one seeded random workload, greedy equality
    across async double-buffered, synchronous packed, and legacy serial
    schedules, with drain invariants after each run."""
    rng = random.Random(zlib.crc32(arch.encode()))
    _, cfg, _ = get_model(arch)
    wl = gen_workload(rng, cfg)
    sync_eng, sync = run_mode(arch, wl, mode="packed", async_=False)
    _, asyn = run_mode(arch, wl, mode="packed", async_=True)
    serial_eng, _ = run_mode(arch, wl, mode="serial", async_=False)
    assert sync == asyn, (arch, sync, asyn)     # bitwise: same dispatches
    assert_greedy_equiv(sync_eng, serial_eng, label=arch)


# ------------------------------------------------------------- deep fuzz
@pytest.mark.parametrize("seed", [1, 2, 3, 4])
def test_fuzz_granite_deep(seed):
    """Deeper seeded fuzz on one arch: pool sizes tight enough to force
    preemption, prefix caching on/off, packed and padded layouts, async vs
    sync — equality and drain invariants throughout. EOS tokens are
    injected from a sync probe run's OBSERVED outputs, so some requests
    deterministically EOS mid-generation and exercise the async
    speculative kill + page rollback."""
    rng = random.Random(1000 + seed)
    _, cfg, _ = get_model("granite-3-2b")
    wl = gen_workload(rng, cfg, n_lo=4, n_hi=6, p_hi=28)
    if rng.random() < 0.5:              # burst arrivals: max memory pressure
        for spec in wl:
            spec["arrival"] = 0
            spec["max_new_tokens"] = rng.randint(4, 14)
    # ~48 large pages at 70-90KB: several seeds force recompute preemption
    pool = rng.choice([70_000, 90_000, 8 << 20])
    caching = rng.random() < 0.5
    layout = rng.choice(["packed", "padded"])
    budget = rng.choice([24, 64])
    kw = dict(pool=pool, caching=caching, budget=budget)
    # probe: observe greedy outputs, then arm EOS mid-output for some
    # requests — the reruns must cut generation at exactly that token
    _, probe = run_mode("granite-3-2b", wl, mode=layout, **kw)
    armed = 0
    for spec in wl:
        out = probe[spec["rid"]]
        if len(out) > 1 and rng.random() < 0.6:
            spec["eos_token"] = out[rng.randint(0, len(out) - 2)]
            armed += 1
    e_sync, sync = run_mode("granite-3-2b", wl, mode=layout, **kw)
    e_asyn, asyn = run_mode("granite-3-2b", wl, mode=layout, async_=True,
                            **kw)
    assert sync == asyn, (seed, layout, pool, caching, sync, asyn)
    # a mid-generation EOS on an async engine must have gone through the
    # speculative kill (the +1 decode was already planned) — this keeps
    # each seed self-contained, no cross-test aggregation needed.
    # (Preemption coverage is pinned by test_fuzz_preemption_equality.)
    if armed:
        assert e_asyn.spec_kills >= 1, (seed, armed, e_asyn.spec_kills)


@pytest.mark.parametrize("seed", [0, 3])   # 0: packed@60K, 3: padded@60K
def test_fuzz_preemption_equality(seed):
    """Pool sized below the workload's working set (~48 large pages vs 6
    decode-heavy requests): recompute preemption MUST fire, and async ==
    sync greedy equality must survive it — preempted in-flight victims are
    released uncached and regenerate the same tokens."""
    rng = random.Random(50 + seed)
    wl = [dict(rid=f"r{i}",
               prompt=[(11 * i + j) % 50 for j in range(rng.randint(18, 26))],
               max_new_tokens=rng.randint(10, 16), eos_token=None,
               arrival=0, mm=None, enc=None)
          for i in range(6)]
    pool = rng.choice([60_000, 80_000])
    # caching off: evictable cached pages would absorb the pressure before
    # recompute preemption ever fires (eviction is the cheaper resort)
    kw = dict(pool=pool, caching=False,
              mode="packed" if seed % 2 == 0 else "padded", budget=256)
    e_sync, sync = run_mode("granite-3-2b", wl, **kw)
    e_asyn, asyn = run_mode("granite-3-2b", wl, async_=True, **kw)
    assert sync == asyn, (seed, pool, sync, asyn)
    assert e_sync.scheduler.preemption_count > 0 \
        and e_asyn.scheduler.preemption_count > 0, \
        (e_sync.scheduler.preemption_count, e_asyn.scheduler.preemption_count)


# -------------------------------------------------------- injected OOM
def test_fuzz_injected_oom_transactional():
    """Mid-run, an unsatisfiable batch allocation (injected OOM) must be a
    perfect no-op on the manager — the §5.4 transaction at plan level."""
    from repro.core.request import SequenceState
    rng = random.Random(7)
    model, cfg, params = get_model("granite-3-2b")
    eng = Engine(model, EngineConfig(kv_pool_bytes=400_000, max_running=4,
                                     chunk_size=8,
                                     max_num_batched_tokens=64),
                 params=params)
    for spec in gen_workload(rng, cfg, n_lo=3, n_hi=3):
        eng.submit(build_request(spec))
    for _ in range(4):
        eng.step()
    mgr = eng.mgr
    mgr.check_invariants()
    before = mgr.memory_stats()
    victim = SequenceState(rid="oom", tokens=[0] * 50_000)
    ok, _ = mgr.begin_request(victim)
    assert ok
    live = [r.seq for r in eng.scheduler.running]
    assert not mgr.allocate_for_batch(
        live + [victim], [s.num_computed + 2 for s in live] + [50_000])
    after = mgr.memory_stats()
    # §5.4 transaction: every page the failed attempt took is returned
    # (used unchanged). The attempt may legitimately have EVICTED cached
    # pages before exhausting — those become free, so the free+evictable
    # total is conserved but not its split.
    assert before.used_units == after.used_units, (before, after)
    assert before.free_units + before.evictable_units \
        == after.free_units + after.evictable_units, (before, after)
    mgr.check_invariants()
    mgr.free_request(victim, cache=False)
    eng.run_until_done(max_steps=1000)      # and the engine still drains
    check_drained(eng, 3)


# ------------------------------------------------- multi-engine fleet
# The same seeded workloads driven through a data-parallel fleet
# (serving.dp_engine): N engine shards behind the cache-aware router,
# with injected shard stalls/crashes. Invariants: router(fleet) produces
# the same per-request greedy outputs as one solo engine (fork-aware —
# shard batch mixes differ), no request is lost or duplicated across a
# failover, and EVERY shard (dead ones included) drains to zero used
# pages. REPRO_ROUTER_SHARDS overrides the fleet width (the tier-1
# router CI leg runs the suite at 3).

def _n_shards(rng):
    env = os.environ.get("REPRO_ROUTER_SHARDS")
    return int(env) if env else rng.randint(2, 4)


def drive_dp(dp, workload):
    """Submit with staggered arrivals (fleet ticks) and run to drain."""
    pending = sorted(workload, key=lambda s: (s["arrival"], s["rid"]))
    guard = 0
    while pending or dp.has_work:
        while pending and pending[0]["arrival"] <= dp.tick:
            dp.submit(build_request(pending.pop(0)))
        dp.step()
        guard += 1
        assert guard < 3000, "fleet workload failed to drain"
    return {r.rid: list(r.output) for r in dp.finished}


def check_drained_dp(dp, n_req):
    """Exactly-once + leak sweep over every shard, crashed ones included."""
    rids = [r.rid for r in dp.finished]
    assert len(rids) == len(set(rids)), f"duplicated finishes: {rids}"
    assert len(rids) == n_req, (sorted(rids), n_req)
    dp.check_invariants()
    for sh in dp.shards:
        stats = sh.engine.mgr.memory_stats()
        assert stats.used_units == 0, (sh.sid, stats)
        assert not sh.engine.runner._mirrors, \
            (sh.sid, list(sh.engine.runner._mirrors))
        san = getattr(sh.engine.mgr, "sanitizer", None)
        if san is not None:
            san.assert_drained()


def run_dp(arch, workload, *, n_shards, pool=8 << 20, caching=True,
           budget=64, policy=None):
    model, cfg, params = get_model(arch)
    dp = DPEngine(model, EngineConfig(
        kv_pool_bytes=pool, max_running=4, chunk_size=8,
        max_num_batched_tokens=budget, enable_prefix_caching=caching,
        record_sample_logits=True),
        params=params, num_shards=n_shards, policy=policy,
        split_pool=False)
    outs = drive_dp(dp, workload)
    check_drained_dp(dp, len(workload))
    return dp, outs


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_fuzz_dp_equals_solo(seed):
    """Seeded workloads through a 2-4 shard fleet == one solo engine,
    per request (fork-aware), with drain invariants on every shard."""
    rng = random.Random(7000 + seed)
    _, cfg, _ = get_model("granite-3-2b")
    wl = gen_workload(rng, cfg, n_lo=5, n_hi=8, p_hi=24)
    solo_eng, solo = run_mode("granite-3-2b", wl)
    dp, _ = run_dp("granite-3-2b", wl, n_shards=_n_shards(rng))
    assert_greedy_equiv(solo_eng, dp, label=f"dp-seed{seed}")


def test_fuzz_dp_failover():
    """Mid-run shard crash + transient stall on another shard: every
    request still completes exactly once, greedy outputs still match the
    solo engine, and the dead shard holds zero pages. Burst arrivals and
    multi-token outputs keep work in flight at the injection ticks."""
    rng = random.Random(4242)
    _, cfg, _ = get_model("granite-3-2b")
    wl = gen_workload(rng, cfg, n_lo=7, n_hi=9, p_hi=24)
    for spec in wl:
        spec["arrival"] = 0
        spec["max_new_tokens"] = rng.randint(6, 12)
        spec["eos_token"] = None
    solo_eng, solo = run_mode("granite-3-2b", wl)

    model, _, params = get_model("granite-3-2b")
    dp = DPEngine(model, EngineConfig(
        kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
        max_num_batched_tokens=64, record_sample_logits=True),
        params=params, num_shards=3, split_pool=False)
    for spec in sorted(wl, key=lambda s: s["rid"]):
        dp.submit(build_request(spec))
    dp.step()
    dp.step()
    stalled = dp.inject_stall(1, resume_after=3)    # graceful: unstarted move
    crashed = dp.inject_crash(0)                    # failover: everything moves
    assert crashed, "crash drained nothing — injection too late"
    dead = dp.shards[0].engine.mgr.memory_stats()
    assert dead.used_units == 0, dead
    guard = 0
    while dp.has_work:
        dp.step()
        guard += 1
        assert guard < 3000
    check_drained_dp(dp, len(wl))
    assert dp.fleet_stats()["readmissions"] == len(stalled) + len(crashed)
    # crashed shard took no new work after the failover
    assert not dp.shards[0].engine.scheduler.has_work()
    assert_greedy_equiv(solo_eng, dp, label="dp-failover")


def test_fuzz_dp_backpressure_tiny_pools():
    """Per-shard pools far below the workload's working set: defers and
    recompute preemptions fire on the shards, the router's health costing
    sees them, and the fleet still drains to the solo outputs."""
    rng = random.Random(9090)
    wl = [dict(rid=f"r{i}",
               prompt=[(13 * i + j) % 50 for j in range(rng.randint(18, 26))],
               max_new_tokens=rng.randint(10, 16), eos_token=None,
               arrival=0, mm=None, enc=None)
          for i in range(10)]
    solo_eng, solo = run_mode("granite-3-2b", wl, caching=False,
                              budget=256)
    # ~60KB per shard (~40 large pages) against 5 decode-heavy requests
    # each — the test_fuzz_preemption_equality regime, per shard
    dp, _ = run_dp("granite-3-2b", wl, n_shards=2, pool=60_000,
                   caching=False, budget=256)
    fs = dp.fleet_stats()
    assert sum(fs["preemptions"]) + sum(fs["defers"]) > 0, fs
    assert_greedy_equiv(solo_eng, dp, label="dp-backpressure")


def test_fuzz_dp_indefinite_stall_escalates():
    """An indefinite stall with escalation configured turns into a crash
    after the deadline: the stuck shard's started requests fail over and
    the fleet still finishes everything exactly once."""
    rng = random.Random(31337)
    _, cfg, _ = get_model("granite-3-2b")
    wl = gen_workload(rng, cfg, n_lo=6, n_hi=6, p_hi=20)
    for spec in wl:
        spec["arrival"] = 0
        spec["max_new_tokens"] = rng.randint(6, 10)
        spec["eos_token"] = None
    solo_eng, _ = run_mode("granite-3-2b", wl)
    model, _, params = get_model("granite-3-2b")
    dp = DPEngine(model, EngineConfig(
        kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
        max_num_batched_tokens=64, record_sample_logits=True),
        params=params, num_shards=2, split_pool=False,
        stall_escalate_ticks=4)
    for spec in sorted(wl, key=lambda s: s["rid"]):
        dp.submit(build_request(spec))
    dp.step()
    dp.inject_stall(0, resume_after=None)   # hung device, never resumes
    guard = 0
    while dp.has_work:
        dp.step()
        guard += 1
        assert guard < 3000
    assert not dp.shards[0].alive            # escalated to crash
    check_drained_dp(dp, len(wl))
    assert_greedy_equiv(solo_eng, dp, label="dp-escalate")


# ------------------------------------------- prefill/decode disaggregation
# Shard 0 prefill-only, the rest decode-only: prompts are computed on the
# prefill shard, then the typed page set is exported, copied across, and
# adopted by a decode shard as a whole-prompt prefix hit. Invariants: the
# split fleet matches one solo engine per request (fork-aware), decode
# shards compute ZERO prefill tokens, every shard drains leak-free (the
# pagesan CI leg also checks no page is lost in transit), and a crash on
# either side of the handoff falls back to recompute with exactly-once
# finishes. REPRO_DISAGG=1 (the tier-1 disagg CI leg) widens the sweep.

def _disagg_seeds():
    return ([21, 22, 23, 24, 25] if os.environ.get("REPRO_DISAGG")
            else [21, 22])


def run_disagg(arch, workload, *, n_shards=2, pool=8 << 20, caching=True,
               budget=64):
    model, cfg, params = get_model(arch)
    dp = DPEngine(model, EngineConfig(
        kv_pool_bytes=pool, max_running=4, chunk_size=8,
        max_num_batched_tokens=budget, enable_prefix_caching=caching,
        record_sample_logits=True),
        params=params, num_shards=n_shards, split_pool=False,
        roles=["prefill"] + ["decode"] * (n_shards - 1))
    outs = drive_dp(dp, workload)
    check_drained_dp(dp, len(workload))
    if dp.fleet_stats()["role_failovers"] == 0:
        # roles held for the whole run: decode shards never computed a
        # prefill token — the zero-recompute half of the handoff contract
        for sh in dp.shards[1:]:
            pf = sum(m.prefill_tokens for m in sh.engine.metrics)
            assert pf == 0, (sh.sid, pf)
    return dp, outs


@pytest.mark.parametrize("seed", _disagg_seeds())
def test_fuzz_disagg_equals_solo(seed):
    """Seeded workloads through a prefill/decode split fleet == one solo
    engine per request, with handoffs actually firing and drain
    invariants (leaks, lost-in-transit) on both sides of the split."""
    rng = random.Random(8800 + seed)
    _, cfg, _ = get_model("granite-3-2b")
    wl = gen_workload(rng, cfg, n_lo=5, n_hi=8, p_hi=24)
    solo_eng, solo = run_mode("granite-3-2b", wl)
    dp, _ = run_disagg("granite-3-2b", wl, n_shards=2 + seed % 2)
    assert dp.handoffs, "disagg fuzz produced no handoffs"
    assert_greedy_equiv(solo_eng, dp, label=f"disagg-seed{seed}")


def test_fuzz_disagg_decode_crash_recovers():
    """The only decode shard dies while handoffs are landing on it: its
    requests fail over (PR-8 recompute), the prefill shard flips to
    colocated so prompt-complete requests are not stranded, and every
    request finishes exactly once with the solo outputs."""
    rng = random.Random(6161)
    _, cfg, _ = get_model("granite-3-2b")
    wl = gen_workload(rng, cfg, n_lo=6, n_hi=8, p_hi=24)
    for spec in wl:
        spec["arrival"] = 0
        spec["max_new_tokens"] = rng.randint(6, 12)
        spec["eos_token"] = None
    solo_eng, _ = run_mode("granite-3-2b", wl)
    model, _, params = get_model("granite-3-2b")
    dp = DPEngine(model, EngineConfig(
        kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
        max_num_batched_tokens=64, record_sample_logits=True),
        params=params, num_shards=2, split_pool=False,
        roles=["prefill", "decode"])
    for spec in sorted(wl, key=lambda s: s["rid"]):
        dp.submit(build_request(spec))
    dp.step()
    dp.step()                           # handoffs have landed on shard 1
    assert dp.handoffs, "injection too late — nothing handed off yet"
    crashed = dp.inject_crash(1)
    assert crashed, "crash drained nothing"
    assert dp.shards[1].engine.mgr.memory_stats().used_units == 0
    guard = 0
    while dp.has_work:
        dp.step()
        guard += 1
        assert guard < 3000
    check_drained_dp(dp, len(wl))
    # stranded prompt-complete requests forced the colocated fallback
    assert dp.fleet_stats()["role_failovers"] >= 1
    assert dp.shards[0].engine.role == "both"
    assert_greedy_equiv(solo_eng, dp, label="disagg-crash-decode")


def test_fuzz_disagg_prefill_crash_recovers():
    """The prefill shard dies mid-run: in-flight and quiet prompt-complete
    requests (abandoned exports included — their pages drain with the
    dead shard) re-place onto the decode shard, which computes their
    prefill itself (the role filter is dropped when nothing qualifies).
    Exactly-once finishes, solo outputs, zero pages on the dead shard."""
    rng = random.Random(7272)
    _, cfg, _ = get_model("granite-3-2b")
    wl = gen_workload(rng, cfg, n_lo=6, n_hi=8, p_hi=24)
    for spec in wl:
        spec["arrival"] = 0
        spec["max_new_tokens"] = rng.randint(6, 12)
        spec["eos_token"] = None
    solo_eng, _ = run_mode("granite-3-2b", wl)
    model, _, params = get_model("granite-3-2b")
    dp = DPEngine(model, EngineConfig(
        kv_pool_bytes=8 << 20, max_running=4, chunk_size=8,
        max_num_batched_tokens=64, record_sample_logits=True),
        params=params, num_shards=2, split_pool=False,
        roles=["prefill", "decode"])
    for spec in sorted(wl, key=lambda s: s["rid"]):
        dp.submit(build_request(spec))
    dp.step()
    crashed = dp.inject_crash(0)
    assert crashed, "crash drained nothing"
    assert dp.shards[0].engine.mgr.memory_stats().used_units == 0
    guard = 0
    while dp.has_work:
        dp.step()
        guard += 1
        assert guard < 3000
    check_drained_dp(dp, len(wl))
    assert not dp.shards[0].engine.scheduler.has_work()
    assert_greedy_equiv(solo_eng, dp, label="disagg-crash-prefill")


# ------------------------------------------------- hypothesis (optional)
def test_fuzz_hypothesis_async_equals_sync():
    """Property form of the harness: hypothesis drives the same generator
    space (with shrinking) for async==sync equality on one arch. Skips
    cleanly when hypothesis is absent; tier-1 CI installs it."""
    pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 2 ** 31))
    def check(seed):
        rng = random.Random(seed)
        _, cfg, _ = get_model("granite-3-2b")
        wl = gen_workload(rng, cfg)
        pool = rng.choice([300_000, 8 << 20])
        kw = dict(pool=pool, caching=rng.random() < 0.5)
        _, sync = run_mode("granite-3-2b", wl, **kw)
        _, asyn = run_mode("granite-3-2b", wl, async_=True, **kw)
        assert sync == asyn, (seed, sync, asyn)

    check()
