"""Device-side sampling + multi-slot pipeline (the fused dispatch tail).

Covers the three contracts the pipeline rests on:

  * greedy parity: the device band-argmax is bit-identical to the host
    ``greedy_token`` form, and whole-engine greedy outputs are identical
    across pipeline depths 1 (sync), 2 (double buffer, host sampling),
    and 4 (ring + device sampling) for every model archetype;
  * seeded temperature/top-k draws are keyed on (seed, rid_hash,
    position) only, so packed/padded layouts and host/device samplers
    all reproduce the same stochastic trajectory;
  * EOS landing while deeper ring slots are still queued kills every
    speculative segment and rolls its pages back — draining leaks
    nothing even at depth 4.
"""
import numpy as np
import pytest

from conftest import assert_greedy_equiv, get_model, make_engine
from repro.serving import Request, SamplingParams
from repro.serving.sampler import (TIE_EPS, get_sample_fn, greedy_token,
                                   host_sample, rid_hash)

import jax.numpy as jnp


# ------------------------------------------------------------- unit level
def test_band_pick_matches_host_greedy_bitwise():
    """The device sampler's boolean band-argmax must agree with the host
    ``np.flatnonzero`` form on every row, including engineered near-ties
    right at the band edge."""
    rng = np.random.default_rng(0)
    rows = rng.standard_normal((64, 128)).astype(np.float32)
    # engineered ties: push a lower id into the band of the max
    for r in range(0, 64, 4):
        m = int(rows[r].argmax())
        lo = (m + 37) % 128
        rows[r, lo] = rows[r, m] - 0.5 * TIE_EPS
    fn = get_sample_fn(False)
    board = jnp.zeros((64,), jnp.int32)
    toks, board = fn(jnp.asarray(rows), board,
                     jnp.arange(64, dtype=jnp.int32),
                     jnp.zeros((64,), jnp.float32),
                     jnp.zeros((64,), jnp.int32),
                     jnp.zeros((64,), jnp.uint32),
                     jnp.zeros((64,), jnp.int32),
                     jnp.zeros((64,), jnp.int32))
    toks = np.asarray(toks)
    for r in range(64):
        assert toks[r] == greedy_token(rows[r]), r
    # and the board scatter recorded exactly the same picks
    assert np.array_equal(np.asarray(board), toks)


def test_topk_membership_and_pad_immunity():
    """Every temperature draw stays inside the top-k set of its row, and
    -1e30 pad columns (the serve heads' masked vocab tail) can never be
    drawn even under extreme logit magnitudes."""
    rng = np.random.default_rng(1)
    v, pad = 40, 24
    for pos in range(20):
        row = np.full((v + pad,), -1e30, np.float32)
        row[:v] = rng.standard_normal(v) * (1e4 if pos % 5 == 0 else 3.0)
        tok = host_sample(row, temperature=1.2, top_k=5,
                          rh=rid_hash("rq"), pos=pos, seed=7)
        top5 = set(np.argsort(row)[::-1][:5].tolist())
        assert tok in top5, (pos, tok, sorted(top5))
        assert tok < v
    # reproducibility: identical (row, key) -> identical draw
    row = rng.standard_normal(v + pad).astype(np.float32)
    a = host_sample(row, 0.9, 0, rid_hash("x"), 3, 11)
    b = host_sample(row, 0.9, 0, rid_hash("x"), 3, 11)
    assert a == b


# ------------------------------------------------------- greedy parity e2e
def _submit_workload(eng, n=3, max_new=6, sampling_kw=None):
    for i in range(n):
        kw = dict(max_new_tokens=max_new)
        kw.update(sampling_kw or {})
        eng.submit(Request(rid=f"r{i}",
                           prompt=[(7 * i + j) % 50 for j in range(6 + 3 * i)],
                           sampling=SamplingParams(**kw)))
    eng.run_until_done()
    return {r.rid: list(r.output) for r in eng.finished}


@pytest.mark.parametrize("arch", ["granite-3-2b", "h2o-danube-3-4b",
                                  "qwen2-vl-2b", "zamba2-1.2b", "rwkv6-3b",
                                  "whisper-tiny", "dbrx-132b"])
def test_greedy_bit_identical_across_depths(arch):
    """Depth 1 (sync), depth 2 (double buffer, host-sampled), and depth 4
    (ring, device-sampled) plan identical dispatches for an EOS-free
    workload, so greedy outputs must be BITWISE equal — the fork-aware
    checker must report zero forks."""
    outs, engs = {}, {}
    legs = [(1, dict(async_scheduling=False)),
            (2, dict(async_scheduling=True, pipeline_depth=2)),
            (4, dict(async_scheduling=True, pipeline_depth=4))]
    for depth, kw in legs:
        eng, _ = make_engine(arch, record_sample_logits=True, **kw)
        outs[depth] = _submit_workload(eng)
        engs[depth] = eng
    assert outs[1] == outs[2] == outs[4], (arch, outs)
    assert engs[4].device_sampling and not engs[2].device_sampling
    assert assert_greedy_equiv(engs[1], engs[4], label=arch) == set()
    # device sampling really did keep host sampling out of the loop
    assert sum(m.host_sample_ms for m in engs[4].metrics) == 0.0


def test_seeded_sampling_reproducible_across_layouts_and_samplers():
    """Temperature/top-k trajectories depend only on (seed, rid_hash,
    position): packed vs padded layouts, sync host sampling vs depth-4
    device sampling — four engines, one output set."""
    sampling = dict(temperature=0.8, top_k=5, seed=42)
    legs = dict(
        packed_sync=dict(batching_mode="packed", async_scheduling=False),
        padded_sync=dict(batching_mode="padded", async_scheduling=False),
        packed_async2=dict(batching_mode="packed", async_scheduling=True,
                           pipeline_depth=2),
        packed_async4=dict(batching_mode="packed", async_scheduling=True,
                           pipeline_depth=4),
    )
    outs = {}
    for name, kw in legs.items():
        eng, _ = make_engine("granite-3-2b", **kw)
        outs[name] = _submit_workload(eng, max_new=8, sampling_kw=sampling)
    ref = outs["packed_sync"]
    for name, o in outs.items():
        assert o == ref, (name, o, ref)
    # a different seed must change the trajectory (16 draws at top_k=5)
    eng, _ = make_engine("granite-3-2b", **legs["packed_sync"])
    other = _submit_workload(eng, max_new=8,
                             sampling_kw=dict(sampling, seed=43))
    assert other != ref


# ----------------------------------------------- EOS at depth 4, no leaks
def test_eos_in_deep_ring_rolls_back_and_drains_clean():
    """Arm EOS tokens mid-output (observed from a sync probe) and run at
    depth 4: the finish is discovered while up to 3 speculative steps for
    that request are still queued — every one must be killed, their page
    commitments popped, and the drained pool fully restored."""
    probe, _ = make_engine("granite-3-2b", enable_prefix_caching=False)
    ref = _submit_workload(probe, n=4, max_new=10)
    eos = {rid: out[len(out) // 2] for rid, out in ref.items()
           if len(out) > 2}
    assert eos    # greedy on the reduced model always emits > 2 tokens

    eng, _ = make_engine("granite-3-2b", async_scheduling=True,
                         pipeline_depth=4, enable_prefix_caching=False)
    for i in range(4):
        rid = f"r{i}"
        eng.submit(Request(
            rid=rid, prompt=[(7 * i + j) % 50 for j in range(6 + 3 * i)],
            sampling=SamplingParams(max_new_tokens=10,
                                    eos_token=eos.get(rid))))
    eng.run_until_done()
    outs = {r.rid: list(r.output) for r in eng.finished}
    for rid, out in outs.items():
        if rid in eos:
            cut = ref[rid].index(eos[rid]) + 1
            assert out == ref[rid][:cut], (rid, out, ref[rid], eos[rid])
    assert eng.spec_kills >= 1, eng.spec_kills
    eng.mgr.check_invariants()
    stats = eng.mgr.memory_stats()
    assert stats.used_units == 0, f"leaked referenced pages: {stats}"
    assert stats.free_units == stats.total_units, stats


# ----------------------------------------------------- traffic accounting
def test_device_sampling_shrinks_fetch_traffic():
    """The whole point of the tentpole: completion blocks on 4 bytes per
    segment instead of a vocab-wide fp32 row. Same workload, host-sampled
    depth 2 vs device-sampled depth 4 — fetched bytes collapse while
    outputs stay identical."""
    kw = dict(async_scheduling=True, enable_prefix_caching=False)
    host_eng, cfg = make_engine("granite-3-2b", pipeline_depth=2, **kw)
    host_out = _submit_workload(host_eng, n=4, max_new=12)
    dev_eng, _ = make_engine("granite-3-2b", pipeline_depth=4, **kw)
    dev_out = _submit_workload(dev_eng, n=4, max_new=12)
    assert host_out == dev_out
    host_bytes = sum(m.sampled_bytes_fetched for m in host_eng.metrics)
    dev_bytes = sum(m.sampled_bytes_fetched for m in dev_eng.metrics)
    assert host_bytes == host_eng.runner.bytes_fetched
    assert dev_bytes == dev_eng.runner.bytes_fetched
    # device: 4 bytes per COMPLETED SEGMENT (samples + the few non-final
    # prefill chunks); host: a full >= vocab-width fp32 row per segment
    samples = sum(len(o) for o in dev_out.values())
    assert 4 * samples <= dev_bytes <= 4 * (samples + 16), \
        (dev_bytes, samples)
    assert host_bytes >= 10 * dev_bytes, \
        (host_bytes, dev_bytes, cfg.vocab_size)
