"""Speculative decoding (§6.1): shared Jenga pool, greedy equivalence."""
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams
from repro.serving.spec_decode import SpecDecodeConfig, SpecDecodeEngine


def test_spec_decode_matches_greedy_target():
    """Greedy speculative decoding must emit EXACTLY the target's greedy
    output, regardless of draft quality."""
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"],
                   num_layers=2, vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    target = build_model(tcfg, dist)
    draft = build_model(dcfg, dist)
    prompt = list(range(12))
    # reference: plain engine greedy on the target
    ref_model = build_model(tcfg, dist)
    eng = Engine(ref_model, EngineConfig(kv_pool_bytes=8 << 20, chunk_size=8,
                                         enable_prefix_caching=False),
                 params=None, seed=0)
    eng.submit(Request(rid="ref", prompt=list(prompt),
                       sampling=SamplingParams(max_new_tokens=8)))
    eng.run_until_done()
    ref_out = eng.finished[0].output

    sd = SpecDecodeEngine(target, draft,
                          SpecDecodeConfig(k=3, kv_pool_bytes=16 << 20,
                                           chunk_size=8),
                          target_params=eng.params, seed=0)
    out = sd.generate(prompt, max_new_tokens=8)
    assert out == ref_out, (out, ref_out)
    assert len(sd.accept_lengths) >= 1


def test_spec_decode_shared_pool_two_page_sizes():
    """The shared manager really holds two different page sizes (LCM>both)."""
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"], num_layers=2,
                   vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    sd = SpecDecodeEngine(build_model(tcfg, dist), build_model(dcfg, dist),
                          SpecDecodeConfig(k=2, kv_pool_bytes=16 << 20))
    sizes = {s.name: s.page_units for s in sd.mgr.specs}
    assert sizes["tgt_full_attn"] != sizes["draft_full_attn"]
    assert sd.mgr.geometry.large_page_units % sizes["tgt_full_attn"] == 0
    assert sd.mgr.geometry.large_page_units % sizes["draft_full_attn"] == 0
    out = sd.generate(list(range(10)), max_new_tokens=6)
    assert len(out) == 6


def test_spec_decode_async_flag_falls_back_to_sync():
    """SpecDecodeConfig.async_scheduling is accepted for config parity but
    EXPLICITLY falls back to the synchronous draft->verify loop (the
    lockstep data dependency admits no one-step delay without a delayed
    verify queue); outputs must be identical and the fallback recorded."""
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"], num_layers=2,
                   vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    outs = {}
    for async_ in (False, True):
        sd = SpecDecodeEngine(
            build_model(tcfg, dist), build_model(dcfg, dist),
            SpecDecodeConfig(k=2, kv_pool_bytes=16 << 20, chunk_size=8,
                             async_scheduling=async_),
            seed=0)
        assert sd.async_fallback is async_
        outs[async_] = sd.generate(list(range(10)), max_new_tokens=6)
    assert outs[False] == outs[True], outs
