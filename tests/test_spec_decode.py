"""Speculative decoding (§6.1): shared Jenga pool, greedy equivalence."""
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams
from repro.serving.spec_decode import SpecDecodeConfig, SpecDecodeEngine


def test_spec_decode_matches_greedy_target():
    """Greedy speculative decoding must emit EXACTLY the target's greedy
    output, regardless of draft quality."""
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"],
                   num_layers=2, vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    target = build_model(tcfg, dist)
    draft = build_model(dcfg, dist)
    prompt = list(range(12))
    # reference: plain engine greedy on the target
    ref_model = build_model(tcfg, dist)
    eng = Engine(ref_model, EngineConfig(kv_pool_bytes=8 << 20, chunk_size=8,
                                         enable_prefix_caching=False),
                 params=None, seed=0)
    eng.submit(Request(rid="ref", prompt=list(prompt),
                       sampling=SamplingParams(max_new_tokens=8)))
    eng.run_until_done()
    ref_out = eng.finished[0].output

    sd = SpecDecodeEngine(target, draft,
                          SpecDecodeConfig(k=3, kv_pool_bytes=16 << 20,
                                           chunk_size=8),
                          target_params=eng.params, seed=0)
    out = sd.generate(prompt, max_new_tokens=8)
    assert out == ref_out, (out, ref_out)
    assert len(sd.accept_lengths) >= 1


def test_spec_decode_shared_pool_two_page_sizes():
    """The shared manager really holds two different page sizes (LCM>both)."""
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"], num_layers=2,
                   vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    sd = SpecDecodeEngine(build_model(tcfg, dist), build_model(dcfg, dist),
                          SpecDecodeConfig(k=2, kv_pool_bytes=16 << 20))
    sizes = {s.name: s.page_units for s in sd.mgr.specs}
    assert sizes["tgt_full_attn"] != sizes["draft_full_attn"]
    assert sd.mgr.geometry.large_page_units % sizes["tgt_full_attn"] == 0
    assert sd.mgr.geometry.large_page_units % sizes["draft_full_attn"] == 0
    out = sd.generate(list(range(10)), max_new_tokens=6)
    assert len(out) == 6


def test_spec_decode_cross_round_speculation_books_balance():
    """The pipelined round loop pre-issues the next round's draft chain on
    the full-accept guess before the current round's tokens reach the
    host. Regardless of how often that guess lands (``overlapped_rounds``)
    or misses (its pages popped via ``rollback_tokens``, counted in
    ``spec_rollback_pages``), outputs stay the target's exact greedy
    trajectory and draining the engine leaks no pool pages."""
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"], num_layers=2,
                   vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    sd = SpecDecodeEngine(
        build_model(tcfg, dist), build_model(dcfg, dist),
        SpecDecodeConfig(k=2, kv_pool_bytes=16 << 20, chunk_size=8),
        seed=0)
    # use a drift-free copy of the target weights for the reference run
    ref_model = build_model(tcfg, dist)
    eng = Engine(ref_model,
                 EngineConfig(kv_pool_bytes=8 << 20, chunk_size=8,
                              enable_prefix_caching=False),
                 params=sd.tp, seed=0)
    eng.submit(Request(rid="ref", prompt=list(range(10)),
                       sampling=SamplingParams(max_new_tokens=12)))
    eng.run_until_done()
    out = sd.generate(list(range(10)), max_new_tokens=12)
    assert out == eng.finished[0].output, (out, eng.finished[0].output)
    # with 12 tokens at k=2 there were >= 3 rounds: every round after the
    # first either reused the pre-issued chain or rolled its pages back
    rounds = len(sd.accept_lengths)
    assert rounds >= 3
    assert sd.overlapped_rounds + (1 if sd.spec_rollback_pages else 0) >= 0
    full_accepts = sum(1 for a in sd.accept_lengths[:-1] if a == sd.cfg.k)
    assert sd.overlapped_rounds <= max(1, full_accepts + 1)
    # all pool pages returned after generate() freed both sequences
    stats = sd.mgr.memory_stats()
    assert stats.used_units == 0, f"leaked referenced pages: {stats}"
