"""Token-packed varlen dispatch: stream layout, segment isolation, token
bucketing, the latency-aware prefill cap, and the padding-waste win over
the padded layout.

The packed engine flattens every step into one (total_tokens_bucket,)
token stream with per-token segment ids; these tests pin down the
properties that make that safe: the segment mask never lets a token see
another segment or its own future, per-segment recurrent states reset at
segment boundaries (covered by the cross-family equivalence tests in
test_mixed_batching), and dispatched slots track the scheduler's token
budget instead of B*T padding.
"""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams
from repro.serving.runner import _tok_bucket


def make_engine(arch="granite-3-2b", **cfg_kw):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    kw = dict(kv_pool_bytes=8 << 20, max_running=4, chunk_size=8)
    kw.update(cfg_kw)
    return Engine(model, EngineConfig(**kw)), cfg


# ---------------------------------------------------------------- bucketing
def test_tok_bucket_shape():
    """pow2 below 16 (exact small decode steps), multiples of 16 above —
    bounded retraces with <= 15 pad slots per dispatch."""
    assert [_tok_bucket(n) for n in (1, 2, 3, 7, 8, 9, 16)] == \
        [1, 2, 4, 8, 8, 16, 16]
    assert _tok_bucket(17) == 32
    assert _tok_bucket(71) == 80
    assert _tok_bucket(255) == 256
    for n in range(17, 400):
        b = _tok_bucket(n)
        assert b >= n and b - n < 16 and b % 16 == 0


def test_packed_single_decode_is_one_slot():
    """A lone decode step dispatches a 1-token stream, not a padded row."""
    eng, _ = make_engine(batching_mode="packed")
    eng.submit(Request(rid="x", prompt=list(range(8)),
                       sampling=SamplingParams(max_new_tokens=3)))
    eng.run_until_done()
    decode_steps = [m for m in eng.metrics
                    if m.decode_batch == 1 and m.num_prefills == 0]
    assert decode_steps and all(m.dispatched_slots == 1
                                for m in decode_steps)


# ------------------------------------------------------------ padding waste
def test_packed_waste_below_padded():
    """The tentpole claim: on a decode-heavy mixed workload the packed
    stream's padding waste (pad slots / dispatched slots) collapses versus
    the padded (B, T) layout, whose decode rows pay the co-scheduled
    prefill chunk's length."""
    waste = {}
    for mode in ("padded", "packed"):
        eng, _ = make_engine(batching_mode=mode, max_running=8,
                             max_num_batched_tokens=128)
        for i in range(8):
            eng.submit(Request(rid=f"r{i}", prompt=list(range(48)),
                               sampling=SamplingParams(max_new_tokens=16)))
        eng.run_until_done(max_steps=2000)
        assert len(eng.finished) == 8
        r = eng.runner
        waste[mode] = 1.0 - r.tokens_dispatched / r.slots_dispatched
    assert waste["packed"] < waste["padded"], waste
    assert waste["packed"] < 0.25, waste   # stream tracks the budget


# ------------------------------------------------------- latency-aware cap
def test_max_prefill_tokens_per_step_caps_prefill():
    """A huge prompt must not monopolize the step budget: with the cap set,
    prefill tokens per step stay at the cap while decodes of other requests
    keep running every step."""
    eng, _ = make_engine(batching_mode="packed", max_running=4,
                         max_num_batched_tokens=64,
                         max_prefill_tokens_per_step=16)
    eng.submit(Request(rid="short", prompt=list(range(8)),
                       sampling=SamplingParams(max_new_tokens=24)))
    eng.run_until_done(max_steps=6)        # short request reaches decode
    eng.submit(Request(rid="huge", prompt=list(range(120)),
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done(max_steps=2000)
    assert len(eng.finished) == 2
    assert all(m.prefill_tokens <= 16 for m in eng.metrics), \
        [(m.step, m.prefill_tokens) for m in eng.metrics]
    # decode latency protected: every step that prefilled the huge prompt
    # after the short request reached decode also decoded it
    mixed = [m for m in eng.metrics if m.prefill_tokens > 0
             and m.decode_batch > 0]
    assert mixed, "prefill chunks should ride along with running decodes"


def test_max_prefill_cap_same_outputs():
    """The cap changes step packing, never outputs."""
    outs = []
    for cap in (None, 8):
        eng, _ = make_engine(batching_mode="packed",
                             max_num_batched_tokens=64,
                             max_prefill_tokens_per_step=cap)
        eng.submit(Request(rid="x", prompt=list(range(20)),
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run_until_done()
        outs.append(eng.finished[0].output)
    assert outs[0] == outs[1], outs


# ------------------------------------------------------- budget invariance
def test_packed_budget_invariance():
    """Generations must not depend on how the stream is packed/bucketed."""
    outs = []
    for chunk, budget in ((4, 16), (8, 64), (64, 256)):
        eng, _ = make_engine(batching_mode="packed", chunk_size=chunk,
                             max_num_batched_tokens=budget)
        eng.submit(Request(rid="x", prompt=list(range(20)),
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run_until_done()
        outs.append(eng.finished[0].output)
    assert outs[0] == outs[1] == outs[2], outs


def test_packed_oom_preemption_recovers():
    """Tiny pool forces preemption mid-plan under the packed layout too."""
    eng, _ = make_engine(batching_mode="packed", kv_pool_bytes=200_000,
                         max_num_batched_tokens=64)
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done(max_steps=500)
    assert len(done) == 4, (len(done), eng.scheduler.preemption_count)
    eng.mgr.check_invariants()


# ------------------------------------------------------------ segment mask
def test_segment_mask_basics():
    import jax.numpy as jnp
    from repro.models.attention import segment_mask
    seg = jnp.asarray([[0, 0, 1, 1, 1, -1]])
    pos = jnp.asarray([[5, 6, 0, 1, 2, 1 << 29]])
    m = np.asarray(segment_mask(seg, pos, seg, pos))
    # own past+self visible, futures and other segments invisible; when q
    # and kv are the SAME stream (the fresh-KV path) pads see only each
    # other — their rows are garbage and dropped by the caller, while real
    # tokens never see a pad (kv-side pads in the old-page stream carry -2
    # and match nothing at all)
    expect = np.zeros((6, 6), bool)
    expect[0, 0] = expect[1, 0] = expect[1, 1] = True
    expect[2, 2] = True
    expect[3, 2] = expect[3, 3] = True
    expect[4, 2] = expect[4, 3] = expect[4, 4] = True
    expect[5, 5] = True
    assert (m[0] == expect).all(), m[0].astype(int)


def test_segment_mask_property():
    """Hypothesis: for random packed layouts, token i never attends a slot
    of a different segment, a future position of its own segment, nor (with
    chunk_start) any slot at/after its chunk start; pads match nothing."""
    pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings
    import jax.numpy as jnp
    from repro.models.attention import segment_mask

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def check(data):
        n_seg = data.draw(st.integers(1, 4))
        lens = [data.draw(st.integers(1, 6)) for _ in range(n_seg)]
        starts = [data.draw(st.integers(0, 9)) for _ in range(n_seg)]
        window = data.draw(st.sampled_from([0, 3]))
        use_chunk = data.draw(st.booleans())
        q_seg, q_pos, q_cs = [], [], []
        for i, (ln, s0) in enumerate(zip(lens, starts)):
            q_seg += [i] * ln
            q_pos += list(range(s0, s0 + ln))
            q_cs += [s0] * ln
        pad = data.draw(st.integers(0, 3))
        q_seg += [-1] * pad
        q_pos += [1 << 29] * pad
        q_cs += [1 << 29] * pad
        # kv slot stream: random segments/positions (old pages)
        n_kv = data.draw(st.integers(1, 12))
        kv_seg = [data.draw(st.integers(-2, n_seg - 1)) for _ in range(n_kv)]
        kv_pos = [data.draw(st.integers(0, 12)) for _ in range(n_kv)]
        m = np.asarray(segment_mask(
            jnp.asarray([q_seg]), jnp.asarray([q_pos]),
            jnp.asarray([kv_seg]), jnp.asarray([kv_pos]), window=window,
            chunk_start=jnp.asarray([q_cs]) if use_chunk else None))[0]
        for i in range(len(q_seg)):
            for j in range(n_kv):
                if not m[i, j]:
                    continue
                assert q_seg[i] >= 0, "pad token attended something"
                assert kv_seg[j] == q_seg[i], "cross-segment attention"
                if use_chunk:
                    assert kv_pos[j] < q_cs[i], "slot at/after chunk start"
                else:
                    assert kv_pos[j] <= q_pos[i], "future position"
                if window:
                    assert kv_pos[j] > q_pos[i] - window, "outside window"

    check()


# ----------------------------------------------------------- runner layout
def test_packed_plan_layout():
    """The packed plan is one contiguous stream: segments back to back,
    positions continuing each sequence, per-segment last-token indices."""
    eng, _ = make_engine(batching_mode="packed", max_num_batched_tokens=64)
    reqs = []
    for i in range(3):
        r = Request(rid=f"r{i}", prompt=list(range(6 + i)),
                    sampling=SamplingParams(max_new_tokens=2))
        eng.submit(r)
        reqs.append(r)
    plan = eng.scheduler.schedule()
    items = [(s.req, s.num_tokens) for s in plan.scheduled]
    batch, info = eng.runner.build_plan(items, packed=True)
    total = sum(nt for _, nt in items)
    assert info["tokens"] == total and info["slots"] == _tok_bucket(total)
    seg = np.asarray(batch.seg_ids[0])
    pos = np.asarray(batch.positions[0])
    start = np.asarray(batch.seg_start_tok[0])
    last = np.asarray(batch.seg_last_tok)
    off = 0
    for si, (req, nt) in enumerate(items):
        nc = req.seq.num_computed        # schedule() does not advance
        assert (seg[off:off + nt] == si).all()
        assert (pos[off:off + nt] == np.arange(nc, nc + nt)).all()
        assert (start[off:off + nt] == off).all()
        assert last[si] == off + nt - 1
        off += nt
    assert (seg[off:] == -1).all()
