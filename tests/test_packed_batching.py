"""Token-packed varlen dispatch: stream layout, segment isolation, token
bucketing, the latency-aware prefill cap, and the padding-waste win over
the padded layout.

The packed engine flattens every step into one (total_tokens_bucket,)
token stream with per-token segment ids; these tests pin down the
properties that make that safe: the segment mask never lets a token see
another segment or its own future, per-segment recurrent states reset at
segment boundaries (covered by the cross-family equivalence tests in
test_mixed_batching), and dispatched slots track the scheduler's token
budget instead of B*T padding.
"""
import numpy as np
import pytest

from repro.serving import Request, SamplingParams
from repro.serving.runner import _tok_bucket


from conftest import make_engine


# ---------------------------------------------------------------- bucketing
def test_tok_bucket_shape():
    """pow2 below 16 (exact small decode steps), multiples of 16 above —
    bounded retraces with <= 15 pad slots per dispatch."""
    assert [_tok_bucket(n) for n in (1, 2, 3, 7, 8, 9, 16)] == \
        [1, 2, 4, 8, 8, 16, 16]
    assert _tok_bucket(17) == 32
    assert _tok_bucket(71) == 80
    assert _tok_bucket(255) == 256
    for n in range(17, 400):
        b = _tok_bucket(n)
        assert b >= n and b - n < 16 and b % 16 == 0


def test_packed_single_decode_is_one_slot():
    """A lone decode step dispatches a 1-token stream, not a padded row."""
    eng, _ = make_engine(batching_mode="packed")
    eng.submit(Request(rid="x", prompt=list(range(8)),
                       sampling=SamplingParams(max_new_tokens=3)))
    eng.run_until_done()
    decode_steps = [m for m in eng.metrics
                    if m.decode_batch == 1 and m.num_prefills == 0]
    assert decode_steps and all(m.dispatched_slots == 1
                                for m in decode_steps)


# ------------------------------------------------------------ padding waste
def test_packed_waste_below_padded():
    """The tentpole claim: on a decode-heavy mixed workload the packed
    stream's padding waste (pad slots / dispatched slots) collapses versus
    the padded (B, T) layout, whose decode rows pay the co-scheduled
    prefill chunk's length."""
    waste = {}
    for mode in ("padded", "packed"):
        eng, _ = make_engine(batching_mode=mode, max_running=8,
                             max_num_batched_tokens=128)
        for i in range(8):
            eng.submit(Request(rid=f"r{i}", prompt=list(range(48)),
                               sampling=SamplingParams(max_new_tokens=16)))
        eng.run_until_done(max_steps=2000)
        assert len(eng.finished) == 8
        r = eng.runner
        waste[mode] = 1.0 - r.tokens_dispatched / r.slots_dispatched
    assert waste["packed"] < waste["padded"], waste
    assert waste["packed"] < 0.25, waste   # stream tracks the budget


# ------------------------------------------------------- latency-aware cap
def test_max_prefill_tokens_per_step_caps_prefill():
    """A huge prompt must not monopolize the step budget: with the cap set,
    prefill tokens per step stay at the cap while decodes of other requests
    keep running every step."""
    eng, _ = make_engine(batching_mode="packed", max_running=4,
                         max_num_batched_tokens=64,
                         max_prefill_tokens_per_step=16)
    eng.submit(Request(rid="short", prompt=list(range(8)),
                       sampling=SamplingParams(max_new_tokens=24)))
    eng.run_until_done(max_steps=6)        # short request reaches decode
    eng.submit(Request(rid="huge", prompt=list(range(120)),
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done(max_steps=2000)
    assert len(eng.finished) == 2
    assert all(m.prefill_tokens <= 16 for m in eng.metrics), \
        [(m.step, m.prefill_tokens) for m in eng.metrics]
    # decode latency protected: every step that prefilled the huge prompt
    # after the short request reached decode also decoded it
    mixed = [m for m in eng.metrics if m.prefill_tokens > 0
             and m.decode_batch > 0]
    assert mixed, "prefill chunks should ride along with running decodes"


def test_max_prefill_cap_same_outputs():
    """The cap changes step packing, never outputs."""
    outs = []
    for cap in (None, 8):
        eng, _ = make_engine(batching_mode="packed",
                             max_num_batched_tokens=64,
                             max_prefill_tokens_per_step=cap)
        eng.submit(Request(rid="x", prompt=list(range(20)),
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run_until_done()
        outs.append(eng.finished[0].output)
    assert outs[0] == outs[1], outs


# ------------------------------------------------------- budget invariance
def test_packed_budget_invariance():
    """Generations must not depend on how the stream is packed/bucketed."""
    outs = []
    for chunk, budget in ((4, 16), (8, 64), (64, 256)):
        eng, _ = make_engine(batching_mode="packed", chunk_size=chunk,
                             max_num_batched_tokens=budget)
        eng.submit(Request(rid="x", prompt=list(range(20)),
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run_until_done()
        outs.append(eng.finished[0].output)
    assert outs[0] == outs[1] == outs[2], outs


def test_packed_oom_preemption_recovers():
    """Tiny pool forces preemption mid-plan under the packed layout too."""
    eng, _ = make_engine(batching_mode="packed", kv_pool_bytes=200_000,
                         max_num_batched_tokens=64)
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done(max_steps=500)
    assert len(done) == 4, (len(done), eng.scheduler.preemption_count)
    eng.mgr.check_invariants()


# ------------------------------------------------------------ segment mask
def test_segment_mask_basics():
    import jax.numpy as jnp
    from repro.models.attention import segment_mask
    seg = jnp.asarray([[0, 0, 1, 1, 1, -1]])
    pos = jnp.asarray([[5, 6, 0, 1, 2, 1 << 29]])
    m = np.asarray(segment_mask(seg, pos, seg, pos))
    # own past+self visible, futures and other segments invisible; when q
    # and kv are the SAME stream (the fresh-KV path) pads see only each
    # other — their rows are garbage and dropped by the caller, while real
    # tokens never see a pad (kv-side pads in the old-page stream carry -2
    # and match nothing at all)
    expect = np.zeros((6, 6), bool)
    expect[0, 0] = expect[1, 0] = expect[1, 1] = True
    expect[2, 2] = True
    expect[3, 2] = expect[3, 3] = True
    expect[4, 2] = expect[4, 3] = expect[4, 4] = True
    expect[5, 5] = True
    assert (m[0] == expect).all(), m[0].astype(int)


def test_segment_mask_property():
    """Hypothesis: for random packed layouts, token i never attends a slot
    of a different segment, a future position of its own segment, nor (with
    chunk_start) any slot at/after its chunk start; pads match nothing."""
    pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings
    import jax.numpy as jnp
    from repro.models.attention import segment_mask

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def check(data):
        n_seg = data.draw(st.integers(1, 4))
        lens = [data.draw(st.integers(1, 6)) for _ in range(n_seg)]
        starts = [data.draw(st.integers(0, 9)) for _ in range(n_seg)]
        window = data.draw(st.sampled_from([0, 3]))
        use_chunk = data.draw(st.booleans())
        q_seg, q_pos, q_cs = [], [], []
        for i, (ln, s0) in enumerate(zip(lens, starts)):
            q_seg += [i] * ln
            q_pos += list(range(s0, s0 + ln))
            q_cs += [s0] * ln
        pad = data.draw(st.integers(0, 3))
        q_seg += [-1] * pad
        q_pos += [1 << 29] * pad
        q_cs += [1 << 29] * pad
        # kv slot stream: random segments/positions (old pages)
        n_kv = data.draw(st.integers(1, 12))
        kv_seg = [data.draw(st.integers(-2, n_seg - 1)) for _ in range(n_kv)]
        kv_pos = [data.draw(st.integers(0, 12)) for _ in range(n_kv)]
        m = np.asarray(segment_mask(
            jnp.asarray([q_seg]), jnp.asarray([q_pos]),
            jnp.asarray([kv_seg]), jnp.asarray([kv_pos]), window=window,
            chunk_start=jnp.asarray([q_cs]) if use_chunk else None))[0]
        for i in range(len(q_seg)):
            for j in range(n_kv):
                if not m[i, j]:
                    continue
                assert q_seg[i] >= 0, "pad token attended something"
                assert kv_seg[j] == q_seg[i], "cross-segment attention"
                if use_chunk:
                    assert kv_pos[j] < q_cs[i], "slot at/after chunk start"
                else:
                    assert kv_pos[j] <= q_pos[i], "future position"
                if window:
                    assert kv_pos[j] > q_pos[i] - window, "outside window"

    check()


# ---------------------------------------------------------- async engine
# EngineConfig.async_scheduling double-buffers the step loop: plan N+1 is
# scheduled (speculative +1 decode per running request) and host-built
# while plan N's dispatch is in flight; sampling/advancing N happens when
# its logits are fetched, and the already-built batch N+1 is reconciled
# (dead segments killed, speculative pages rolled back, decode token ids
# patched) before its own dispatch. Everything observable must be
# BIT-IDENTICAL to the synchronous loop.

@pytest.mark.parametrize("arch", ["granite-3-2b", "h2o-danube-3-4b",
                                  "qwen2-vl-2b", "zamba2-1.2b", "rwkv6-3b",
                                  "whisper-tiny", "dbrx-132b"])
def test_async_matches_sync_greedy(arch):
    """Async greedy outputs equal the synchronous packed engine's, token
    for token, for every model family (attention, swa, vlm, hybrid-mamba2,
    rwkv6, encdec, moe) — including mm/encoder item routing."""
    from repro.core.request import MMItem
    outs = {}
    for async_ in (False, True):
        eng, cfg = make_engine(arch, batching_mode="packed",
                               max_num_batched_tokens=64,
                               async_scheduling=async_)
        for i in range(3):
            kw = {}
            if arch == "whisper-tiny":
                kw["encoder_items"] = (MMItem(0, cfg.encoder_seq,
                                              mm_hash=7 + i),)
            elif arch == "qwen2-vl-2b":
                kw["mm_items"] = (MMItem(2, 6, mm_hash=40 + i),)
            eng.submit(Request(rid=f"r{i}",
                               prompt=[(3 * i + j) % 50
                                       for j in range(12 + i)],
                               sampling=SamplingParams(max_new_tokens=5),
                               **kw))
        eng.run_until_done(max_steps=1000)
        eng.mgr.check_invariants()
        assert len(eng.finished) == 3
        # one-step-delayed sampling must still stamp first/finished steps
        # with the step that SAMPLED, matching the synchronous loop
        outs[async_] = {r.rid: (list(r.output), r.first_token_step,
                                r.finished_step) for r in eng.finished}
    assert outs[False] == outs[True], (arch, outs)


def test_async_matches_sync_padded_layout():
    """Async scheduling composes with the padded (B, T) layout too — the
    layout only changes how the runner flattens the plan."""
    outs = {}
    for async_ in (False, True):
        eng, _ = make_engine(batching_mode="padded",
                             max_num_batched_tokens=64,
                             async_scheduling=async_)
        for i in range(3):
            eng.submit(Request(rid=f"r{i}", prompt=list(range(10 + i)),
                               sampling=SamplingParams(max_new_tokens=4)))
        eng.run_until_done(max_steps=500)
        outs[async_] = {r.rid: list(r.output) for r in eng.finished}
    assert outs[False] == outs[True], outs


def test_async_serial_falls_back_to_sync():
    """serial mode issues two dispatch groups per step; async_scheduling is
    documented to fall back to the synchronous loop there."""
    eng, _ = make_engine(batching_mode="serial", async_scheduling=True)
    assert eng.async_scheduling is False
    eng.submit(Request(rid="x", prompt=list(range(10)),
                       sampling=SamplingParams(max_new_tokens=3)))
    eng.run_until_done(max_steps=200)
    assert len(eng.finished[0].output) == 3


def test_async_eos_spec_rollback():
    """A request that EOSes while its speculative +1 decode page is already
    committed: the dead segment is neutralized in the prepared batch and
    the page popped back (manager rollback), with outputs unchanged.
    tokens_per_page=4 on reduced configs; prompt length 12 puts the EOS'd
    request's speculative +1 exactly across a page boundary."""
    probe, _ = make_engine(batching_mode="packed", async_scheduling=False)
    probe.submit(Request(rid="p", prompt=[j % 50 for j in range(12)],
                         sampling=SamplingParams(max_new_tokens=4)))
    probe.run_until_done(max_steps=200)
    eos = probe.finished[0].output[0]

    outs = {}
    for async_ in (False, True):
        eng, _ = make_engine(batching_mode="packed",
                             async_scheduling=async_)
        eng.submit(Request(rid="x", prompt=[j % 50 for j in range(12)],
                           sampling=SamplingParams(max_new_tokens=8,
                                                   eos_token=eos)))
        eng.run_until_done(max_steps=200)
        eng.mgr.check_invariants()
        outs[async_] = list(eng.finished[0].output)
        if async_:
            assert eng.spec_kills >= 1, "EOS kill path never exercised"
            assert eng.spec_rollback_pages >= 1, \
                "speculative +1 page was never committed/rolled back"
        # dispatch accounting stays truthful through kills: killed slots
        # count as padding waste, never as dispatched tokens
        assert sum(m.batched_tokens for m in eng.metrics) == \
            eng.runner.tokens_dispatched
        assert sum(m.dispatched_slots for m in eng.metrics) == \
            eng.runner.slots_dispatched
    assert outs[False] == outs[True] and outs[True][-1] == eos, outs


def test_async_prefix_cache_hit_restart():
    """Prefix-cache-hit restart mid-run: a finished request's prompt is
    resubmitted while other requests are mid-decode; the hit restores
    state under async double-buffering exactly as under sync."""
    outs = {}
    for async_ in (False, True):
        eng, _ = make_engine(batching_mode="packed",
                             async_scheduling=async_)
        eng.submit(Request(rid="a", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=3)))
        eng.run_until_done(max_steps=200)           # a finishes, gets cached
        eng.submit(Request(rid="bg", prompt=[7] * 10,
                           sampling=SamplingParams(max_new_tokens=8)))
        for _ in range(3):
            eng.step()                              # bg mid-decode...
        eng.submit(Request(rid="a2", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=3)))
        eng.run_until_done(max_steps=400)
        assert len(eng.finished) == 3
        assert eng.mgr.prefix_hit_tokens_total > 0
        a, a2 = [next(r for r in eng.finished if r.rid == rid)
                 for rid in ("a", "a2")]
        assert a.output == a2.output, (a.output, a2.output)
        assert a2.seq.prefix_hit_tokens > 0          # the restart really hit
        outs[async_] = {r.rid: list(r.output) for r in eng.finished}
    assert outs[False] == outs[True], outs


# ----------------------------------------------------------- runner layout
def test_packed_plan_layout():
    """The packed plan is one contiguous stream: segments back to back,
    positions continuing each sequence, per-segment last-token indices."""
    eng, _ = make_engine(batching_mode="packed", max_num_batched_tokens=64)
    reqs = []
    for i in range(3):
        r = Request(rid=f"r{i}", prompt=list(range(6 + i)),
                    sampling=SamplingParams(max_new_tokens=2))
        eng.submit(r)
        reqs.append(r)
    plan = eng.scheduler.schedule()
    items = [(s.req, s.num_tokens) for s in plan.scheduled]
    batch, info = eng.runner.build_plan(items, packed=True)
    total = sum(nt for _, nt in items)
    assert info["tokens"] == total and info["slots"] == _tok_bucket(total)
    seg = np.asarray(batch.seg_ids[0])
    pos = np.asarray(batch.positions[0])
    start = np.asarray(batch.seg_start_tok[0])
    last = np.asarray(batch.seg_last_tok)
    off = 0
    for si, (req, nt) in enumerate(items):
        nc = req.seq.num_computed        # schedule() does not advance
        assert (seg[off:off + nt] == si).all()
        assert (pos[off:off + nt] == np.arange(nc, nc + nt)).all()
        assert (start[off:off + nt] == off).all()
        assert last[si] == off + nt - 1
        off += nt
    assert (seg[off:] == -1).all()
