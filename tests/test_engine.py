"""End-to-end serving engine tests on reduced models."""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.request import MMItem
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams


from conftest import make_engine


def test_generate_greedy_deterministic():
    eng, cfg = make_engine()
    for i in range(3):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(10 + i)),
                           sampling=SamplingParams(max_new_tokens=5)))
    done = eng.run_until_done()
    assert len(done) == 3
    assert all(len(r.output) == 5 for r in done)
    # same prompt twice -> identical outputs (greedy + prefix cache hit)
    eng2, _ = make_engine()
    eng2.submit(Request(rid="a", prompt=list(range(10)),
                        sampling=SamplingParams(max_new_tokens=5)))
    eng2.run_until_done()
    out_a = eng2.finished[0].output
    eng2.submit(Request(rid="b", prompt=list(range(10)),
                        sampling=SamplingParams(max_new_tokens=5)))
    eng2.run_until_done()
    out_b = eng2.finished[1].output
    assert out_a == out_b, (out_a, out_b)
    # and the second run hit the prefix cache
    assert eng2.finished[1].seq is not None


def test_prefix_cache_speeds_second_request():
    eng, _ = make_engine()
    eng.submit(Request(rid="a", prompt=list(range(32)),
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done()
    hit_before = eng.mgr.prefix_hit_tokens_total
    eng.submit(Request(rid="b", prompt=list(range(32)),
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done()
    assert eng.mgr.prefix_hit_tokens_total > hit_before


def test_chunked_prefill_matches_whole(monkeypatch):
    """Generations must not depend on the chunk size."""
    outs = []
    for chunk in (4, 64):
        eng, _ = make_engine(chunk_size=chunk)
        eng.submit(Request(rid="x", prompt=list(range(20)),
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run_until_done()
        outs.append(eng.finished[0].output)
    assert outs[0] == outs[1], outs


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "rwkv6-3b",
                                  "h2o-danube-3-4b", "dbrx-132b"])
def test_engine_all_families(arch):
    eng, _ = make_engine(arch)
    eng.submit(Request(rid="r", prompt=list(range(12)),
                       sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].output) == 4


def test_vlm_vision_cache_counts_encoder_runs():
    eng, cfg = make_engine("qwen2-vl-2b")
    mm = (MMItem(2, 6, mm_hash=42),)
    for rid in ("a", "b"):
        eng.submit(Request(rid=rid, prompt=list(range(16)), mm_items=mm,
                           sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done()
    # same image twice -> encoder ran once (vision embedding cache, Fig.18)
    assert eng.encoder_runs == 1


def test_whisper_engine():
    eng, cfg = make_engine("whisper-tiny")
    enc = (MMItem(0, cfg.encoder_seq, mm_hash=7),)
    eng.submit(Request(rid="w", prompt=list(range(8)), encoder_items=enc,
                       sampling=SamplingParams(max_new_tokens=3)))
    done = eng.run_until_done()
    assert len(done[0].output) == 3


def test_oom_preemption_recovers():
    """Tiny pool forces preemption; everything still completes."""
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, single_device_dist())
    eng = Engine(model, EngineConfig(kv_pool_bytes=200_000, max_running=4,
                                     chunk_size=8))
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done(max_steps=500)
    assert len(done) == 4, (len(done), eng.scheduler.preemption_count)


def test_step_metrics_surface_dispatch_counters():
    """StepMetrics surfaces the runner's dispatch-waste counters and the
    overlap timings per step: tokens scheduled vs slots paid (pad_slots),
    host batch-build ms, and device dispatch/fetch ms."""
    eng, _ = make_engine(max_num_batched_tokens=64)
    for i in range(3):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(12 + i)),
                           sampling=SamplingParams(max_new_tokens=4)))
    eng.run_until_done(max_steps=200)
    ms = [m for m in eng.metrics if m.batched_tokens > 0]
    assert ms
    for m in ms:
        assert m.dispatched_slots >= m.batched_tokens, m
        assert m.pad_slots == m.dispatched_slots - m.batched_tokens, m
        assert m.host_build_ms >= 0 and m.dispatch_ms >= 0, m
    assert any(m.host_build_ms > 0 for m in ms)
    assert any(m.dispatch_ms > 0 for m in ms)
    # the per-step surface sums to the runner's totals (packed sync mode:
    # one dispatch per step, every plan token dispatched)
    assert sum(m.dispatched_slots for m in eng.metrics) == \
        eng.runner.slots_dispatched
    assert sum(m.batched_tokens for m in eng.metrics) == \
        eng.runner.tokens_dispatched


def test_step_metrics_async_records_overlap_timings():
    """Async steps record the same surface: host build of plan N+1 plus the
    time blocked fetching plan N's logits."""
    eng, _ = make_engine(async_scheduling=True)
    eng.submit(Request(rid="x", prompt=list(range(12)),
                       sampling=SamplingParams(max_new_tokens=4)))
    eng.run_until_done(max_steps=200)
    assert eng.async_scheduling
    ms = eng.metrics
    assert any(m.host_build_ms > 0 for m in ms)
    assert any(m.dispatch_ms > 0 for m in ms)       # fetch of step N
    assert all(m.pad_slots >= 0 for m in ms)


def test_rollback_tokens_mirror_trim_resync():
    """Speculative rollback (async §5.4 access pattern): popping trailing
    pages must not bump the epoch, and the runner mirror must re-sync by
    trim events — including the shrink-then-regrow-to-same-length case,
    where a length-only comparison would keep a stale tail."""
    eng, _ = make_engine()
    eng.submit(Request(rid="x", prompt=list(range(10)),
                       sampling=SamplingParams(max_new_tokens=8)))
    for _ in range(3):
        eng.step()
    req = eng.scheduler.running[0]
    seq, mgr, runner = req.seq, eng.mgr, eng.runner
    name = next(iter(runner._table_specs))
    target0 = seq.num_computed
    epoch0 = seq.epoch
    n0 = len(seq.page_tables[name])
    # speculatively over-allocate a few tokens ahead; mirror follows
    assert mgr.allocate_for_tokens(seq, target0 + 6)
    n1 = len(seq.page_tables[name])
    assert n1 > n0
    m = runner._mirror(seq)
    assert m.n[name] == n1
    # rollback pops the speculative tail: no epoch bump, mirror clamps
    freed = mgr.rollback_tokens(seq, target0)
    assert freed >= 1 and seq.epoch == epoch0
    assert runner._mirror(seq) is m
    assert m.n[name] == len(seq.page_tables[name]) < n1
    # regrow to the SAME length with (possibly different) fresh pages: the
    # trim event forces the tail to re-sync even though len matches
    assert mgr.allocate_for_tokens(seq, target0 + 6)
    assert len(seq.page_tables[name]) == n1
    m2 = runner._mirror(seq)
    assert m2 is m and m.n[name] == n1
    live = np.asarray(seq.page_tables[name])
    synced = m.table[name][:n1]
    ok = (live == synced) | (live == -1)
    assert ok.all(), (live, synced)
    mgr.rollback_tokens(seq, target0)       # restore before draining
    eng.run_until_done(max_steps=200)
    eng.mgr.check_invariants()


def test_baseline_mode_wastes_more_memory():
    """paged-baseline allocates image-token KV for every token + never
    retires SWA pages -> strictly more used units at peak."""
    peaks = {}
    for mode in ("jenga", "paged-baseline"):
        eng, _ = make_engine("h2o-danube-3-4b", memory_mode=mode)
        eng.submit(Request(rid="r", prompt=list(range(48)),
                           sampling=SamplingParams(max_new_tokens=4)))
        eng.run_until_done()
        peaks[mode] = max(m.used_units for m in eng.metrics)
    assert peaks["paged-baseline"] > peaks["jenga"], peaks
