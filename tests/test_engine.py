"""End-to-end serving engine tests on reduced models."""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.request import MMItem
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams


def make_engine(arch="granite-3-2b", **cfg_kw):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    kw = dict(kv_pool_bytes=8 << 20, max_running=4, chunk_size=8)
    kw.update(cfg_kw)
    return Engine(model, EngineConfig(**kw)), cfg


def test_generate_greedy_deterministic():
    eng, cfg = make_engine()
    for i in range(3):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(10 + i)),
                           sampling=SamplingParams(max_new_tokens=5)))
    done = eng.run_until_done()
    assert len(done) == 3
    assert all(len(r.output) == 5 for r in done)
    # same prompt twice -> identical outputs (greedy + prefix cache hit)
    eng2, _ = make_engine()
    eng2.submit(Request(rid="a", prompt=list(range(10)),
                        sampling=SamplingParams(max_new_tokens=5)))
    eng2.run_until_done()
    out_a = eng2.finished[0].output
    eng2.submit(Request(rid="b", prompt=list(range(10)),
                        sampling=SamplingParams(max_new_tokens=5)))
    eng2.run_until_done()
    out_b = eng2.finished[1].output
    assert out_a == out_b, (out_a, out_b)
    # and the second run hit the prefix cache
    assert eng2.finished[1].seq is not None


def test_prefix_cache_speeds_second_request():
    eng, _ = make_engine()
    eng.submit(Request(rid="a", prompt=list(range(32)),
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done()
    hit_before = eng.mgr.prefix_hit_tokens_total
    eng.submit(Request(rid="b", prompt=list(range(32)),
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done()
    assert eng.mgr.prefix_hit_tokens_total > hit_before


def test_chunked_prefill_matches_whole(monkeypatch):
    """Generations must not depend on the chunk size."""
    outs = []
    for chunk in (4, 64):
        eng, _ = make_engine(chunk_size=chunk)
        eng.submit(Request(rid="x", prompt=list(range(20)),
                           sampling=SamplingParams(max_new_tokens=6)))
        eng.run_until_done()
        outs.append(eng.finished[0].output)
    assert outs[0] == outs[1], outs


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "rwkv6-3b",
                                  "h2o-danube-3-4b", "dbrx-132b"])
def test_engine_all_families(arch):
    eng, _ = make_engine(arch)
    eng.submit(Request(rid="r", prompt=list(range(12)),
                       sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].output) == 4


def test_vlm_vision_cache_counts_encoder_runs():
    eng, cfg = make_engine("qwen2-vl-2b")
    mm = (MMItem(2, 6, mm_hash=42),)
    for rid in ("a", "b"):
        eng.submit(Request(rid=rid, prompt=list(range(16)), mm_items=mm,
                           sampling=SamplingParams(max_new_tokens=2)))
    eng.run_until_done()
    # same image twice -> encoder ran once (vision embedding cache, Fig.18)
    assert eng.encoder_runs == 1


def test_whisper_engine():
    eng, cfg = make_engine("whisper-tiny")
    enc = (MMItem(0, cfg.encoder_seq, mm_hash=7),)
    eng.submit(Request(rid="w", prompt=list(range(8)), encoder_items=enc,
                       sampling=SamplingParams(max_new_tokens=3)))
    done = eng.run_until_done()
    assert len(done[0].output) == 3


def test_oom_preemption_recovers():
    """Tiny pool forces preemption; everything still completes."""
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, single_device_dist())
    eng = Engine(model, EngineConfig(kv_pool_bytes=200_000, max_running=4,
                                     chunk_size=8))
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(16)),
                           sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done(max_steps=500)
    assert len(done) == 4, (len(done), eng.scheduler.preemption_count)


def test_baseline_mode_wastes_more_memory():
    """paged-baseline allocates image-token KV for every token + never
    retires SWA pages -> strictly more used units at peak."""
    peaks = {}
    for mode in ("jenga", "paged-baseline"):
        eng, _ = make_engine("h2o-danube-3-4b", memory_mode=mode)
        eng.submit(Request(rid="r", prompt=list(range(48)),
                           sampling=SamplingParams(max_new_tokens=4)))
        eng.run_until_done()
        peaks[mode] = max(m.used_units for m in eng.metrics)
    assert peaks["paged-baseline"] > peaks["jenga"], peaks
