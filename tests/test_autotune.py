"""Roofline-seeded budget autotuning + attention-work accounting.

The autotuner is pure host logic, so its two adjustment rules are unit
tested on synthetic StepMetrics; the engine integration test checks the
seeding reaches the scheduler and a full run stays healthy. The
block-sparse attention-work counters (host mirror of the kernel's
segment-interval skip test) are asserted at both the runner and the
StepMetrics level.
"""
from conftest import make_engine
from repro.configs import ARCHS, reduced
from repro.serving import Request, SamplingParams
from repro.serving.autotune import (MAX_BUDGET, MIN_BUDGET, QUANTUM,
                                    BudgetAutotuner, roofline_token_budget)
from repro.serving.engine import StepMetrics


def mk_metrics(step, **kw):
    base = dict(decode_batch=1, prefill_tokens=0, used_units=0,
                evictable_units=0, empty_units=0, free_units=0)
    base.update(kw)
    return StepMetrics(step=step, **base)


# -------------------------------------------------------------- seeding
def test_roofline_seed_bounds_and_quantum():
    for arch in ("granite-3-2b", "dbrx-132b", "rwkv6-3b"):
        b = roofline_token_budget(reduced(ARCHS[arch]))
        assert MIN_BUDGET <= b <= MAX_BUDGET
        assert b % QUANTUM == 0


def test_moe_seed_exceeds_dense():
    """MoE total/active > 1 pushes the balance point right: a step must
    batch more tokens before the (all-expert) weight read is amortized."""
    dense = roofline_token_budget(reduced(ARCHS["granite-3-2b"]))
    moe = roofline_token_budget(reduced(ARCHS["dbrx-132b"]))
    assert moe > dense


# ----------------------------------------------------------- adjustments
def test_host_bound_grows_budget():
    tun = BudgetAutotuner(reduced(ARCHS["granite-3-2b"]), window=4)
    b0, p0 = tun.budget, tun.prefill_cap
    changed = []
    for i in range(4):
        changed.append(tun.observe(mk_metrics(
            i, host_build_ms=5.0, dispatch_ms=1.0)))
    assert changed == [False, False, False, True]
    assert tun.budget > b0 and tun.budget % QUANTUM == 0
    assert tun.prefill_cap >= p0
    assert tun.adjustments == 1
    assert len(tun._hist) == 0      # window restarts after an adjustment


def test_bytes_trend_shrinks_prefill_cap_to_floor():
    tun = BudgetAutotuner(reduced(ARCHS["granite-3-2b"]), window=4)
    floor = max(QUANTUM, QUANTUM * round(tun.budget / 2 / QUANTUM))
    for round_ in range(8):          # keep feeding growing-traffic windows
        for i in range(4):
            tun.observe(mk_metrics(
                4 * round_ + i, host_build_ms=0.1, dispatch_ms=1.0,
                attn_bytes_modeled=1e6 * (1 + 10 * (i // 2))))
    assert tun.prefill_cap == floor  # clamped, never collapses to QUANTUM
    assert tun.budget == roofline_token_budget(tun.model_cfg)  # untouched


def test_flat_traffic_no_adjustment():
    tun = BudgetAutotuner(reduced(ARCHS["granite-3-2b"]), window=4)
    for i in range(12):
        assert not tun.observe(mk_metrics(
            i, host_build_ms=0.1, dispatch_ms=1.0, attn_bytes_modeled=1e6))
    assert tun.adjustments == 0


# ------------------------------------------------------- work accounting
def test_attn_block_stats_flow_to_metrics():
    """Runner accumulates per-dispatch block-scan/skip counters and the
    engine slices them into per-step StepMetrics deltas that sum back to
    the runner totals."""
    eng, _ = make_engine(batching_mode="packed", max_num_batched_tokens=64)
    for i in range(4):
        eng.submit(Request(rid=f"r{i}",
                           prompt=[(3 * i + j) % 50 for j in range(12 + i)],
                           sampling=SamplingParams(max_new_tokens=4)))
    eng.run_until_done(max_steps=500)
    r = eng.runner
    assert r.kv_blocks_scanned > 0
    assert r.attn_flops_modeled > 0 and r.attn_bytes_modeled > 0
    ms = eng.metrics
    assert sum(m.kv_blocks_scanned for m in ms) == r.kv_blocks_scanned
    assert sum(m.kv_blocks_skipped for m in ms) == r.kv_blocks_skipped
    assert abs(sum(m.attn_flops_modeled for m in ms)
               - r.attn_flops_modeled) < 1e-6 * max(1.0, r.attn_flops_modeled)


def test_rwkv_has_no_attention_work():
    """No token-page attention tables -> the counters stay zero (the
    modeled work is attention-only by construction)."""
    eng, _ = make_engine("rwkv6-3b", batching_mode="packed")
    eng.submit(Request(rid="r0", prompt=list(range(10)),
                       sampling=SamplingParams(max_new_tokens=3)))
    eng.run_until_done(max_steps=200)
    assert eng.runner.kv_blocks_scanned == 0
    assert eng.runner.attn_flops_modeled == 0.0


# ----------------------------------------------------- engine integration
def test_engine_seeds_scheduler_from_roofline():
    eng, cfg = make_engine(autotune_budgets=True, batching_mode="packed")
    seed = roofline_token_budget(cfg)
    assert eng.autotuner is not None
    assert eng.scheduler.cfg.max_num_batched_tokens == seed
    assert eng.scheduler.cfg.max_prefill_tokens_per_step \
        == eng.autotuner.prefill_cap


def test_autotuned_run_completes():
    eng, _ = make_engine(autotune_budgets=True, batching_mode="packed")
    for i in range(4):
        eng.submit(Request(rid=f"r{i}",
                           prompt=[(5 * i + j) % 50 for j in range(16)],
                           sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run_until_done(max_steps=500)
    eng.mgr.check_invariants()
    assert len(done) == 4
    # budgets remain quantized and bounded whatever observe() did
    assert eng.scheduler.cfg.max_num_batched_tokens % QUANTUM == 0
    assert MIN_BUDGET <= eng.scheduler.cfg.max_num_batched_tokens \
        <= MAX_BUDGET
