"""Flash + Mamba kernels vs oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import (flash_attention_tpu,
                                                  flash_attention_varlen_tpu)
from repro.kernels.flash_attention.ref import (flash_attention_ref,
                                               flash_attention_varlen_ref)
from repro.kernels.mamba_scan.kernel import mamba_chunk_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref


def _packed_layout(rng, t, s, n_seg):
    """Random packed stream: contiguous q segments (chunks starting at
    arbitrary positions) + kv slot stream with random owners/positions."""
    q_seg = np.full(t, -1, np.int32)
    q_pos = np.zeros(t, np.int32)
    off = 0
    for i in range(n_seg):
        ln = int(rng.integers(1, max(2, (t - off) // max(1, n_seg - i))))
        if off + ln > t:
            break
        start = int(rng.integers(0, 32))
        q_seg[off:off + ln] = i
        q_pos[off:off + ln] = np.arange(start, start + ln)
        off += ln
    kv_seg = rng.integers(-2, n_seg, s).astype(np.int32)
    kv_pos = rng.integers(0, 40, s).astype(np.int32)
    return q_seg, q_pos, kv_seg, kv_pos


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("bh,t,s,d,blk", [
    (2, 128, 128, 64, 64),
    (1, 128, 256, 32, 64),
])
def test_flash_varlen_matches_ref(bh, t, s, d, blk, window):
    """Segment-id varlen kernel (the packed-dispatch schedule) vs the
    masked oracle: block-diagonal causality over random segment layouts."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((bh, t, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    q_seg, q_pos, kv_seg, kv_pos = _packed_layout(rng, t, s, 4)
    args = (jnp.asarray(q_seg), jnp.asarray(kv_seg),
            jnp.asarray(q_pos), jnp.asarray(kv_pos))
    out_k = flash_attention_varlen_tpu(q, k, v, *args, window=window,
                                       blk_q=blk, blk_k=blk, interpret=True)
    out_r = flash_attention_varlen_ref(q, k, v, *args, window=window)
    valid = q_seg >= 0
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32)[:, valid],
        np.asarray(out_r, np.float32)[:, valid], atol=3e-5, rtol=3e-5)


def test_flash_varlen_no_cross_segment_leak():
    """Zeroing one segment's K/V must not change any other segment's
    output (direct no-leak check, independent of the oracle)."""
    rng = np.random.default_rng(11)
    bh, t, s, d = 1, 64, 64, 32
    q = jnp.asarray(rng.standard_normal((bh, t, d)), jnp.float32)
    k = rng.standard_normal((bh, s, d)).astype(np.float32)
    v = rng.standard_normal((bh, s, d)).astype(np.float32)
    q_seg, q_pos, kv_seg, kv_pos = _packed_layout(rng, t, s, 3)
    args = (jnp.asarray(q_seg), jnp.asarray(kv_seg),
            jnp.asarray(q_pos), jnp.asarray(kv_pos))
    base = np.asarray(flash_attention_varlen_tpu(
        q, jnp.asarray(k), jnp.asarray(v), *args, blk_q=32, blk_k=32))
    k2, v2 = k.copy(), v.copy()
    k2[:, kv_seg == 0] = 1e3
    v2[:, kv_seg == 0] = -1e3
    pert = np.asarray(flash_attention_varlen_tpu(
        q, jnp.asarray(k2), jnp.asarray(v2), *args, blk_q=32, blk_k=32))
    others = q_seg > 0
    np.testing.assert_allclose(base[:, others], pert[:, others],
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,t,s,d,blk", [
    (2, 128, 128, 64, 64),
    (1, 256, 256, 128, 128),
    (3, 64, 64, 32, 32),
])
def test_flash_matches_ref(bh, t, s, d, blk, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((bh, t, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    out_k = flash_attention_tpu(q, k, v, causal=True, blk_q=blk, blk_k=blk,
                                interpret=True)
    out_r = flash_attention_ref(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
    out_k = flash_attention_tpu(q, k, v, causal=True, window=window,
                                blk_q=64, blk_k=64, interpret=True)
    out_r = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 64, 1, 32, 16, 16),
    (1, 256, 4, 64, 64, 64),
])
def test_mamba_chunk_matches_sequential(b, t, h, p, n, chunk):
    rng = np.random.default_rng(2)
    x = jnp.asarray(0.5 * rng.standard_normal((b, t, h, p)), jnp.float32)
    bm = jnp.asarray(0.5 * rng.standard_normal((b, t, n)), jnp.float32)
    cm = jnp.asarray(0.5 * rng.standard_normal((b, t, n)), jnp.float32)
    dt = jnp.asarray(0.1 + 0.5 * rng.random((b, t, h)), jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    out_k = mamba_chunk_scan(x, bm, cm, dt, a_log, chunk=chunk,
                             interpret=True)
    out_r = mamba_scan_ref(x, bm, cm, dt, a_log)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-4, rtol=2e-3)
