"""Flash + Mamba kernels vs oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mamba_scan.kernel import mamba_chunk_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,t,s,d,blk", [
    (2, 128, 128, 64, 64),
    (1, 256, 256, 128, 128),
    (3, 64, 64, 32, 32),
])
def test_flash_matches_ref(bh, t, s, d, blk, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((bh, t, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    out_k = flash_attention_tpu(q, k, v, causal=True, blk_q=blk, blk_k=blk,
                                interpret=True)
    out_r = flash_attention_ref(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
    out_k = flash_attention_tpu(q, k, v, causal=True, window=window,
                                blk_q=64, blk_k=64, interpret=True)
    out_r = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 64, 1, 32, 16, 16),
    (1, 256, 4, 64, 64, 64),
])
def test_mamba_chunk_matches_sequential(b, t, h, p, n, chunk):
    rng = np.random.default_rng(2)
    x = jnp.asarray(0.5 * rng.standard_normal((b, t, h, p)), jnp.float32)
    bm = jnp.asarray(0.5 * rng.standard_normal((b, t, n)), jnp.float32)
    cm = jnp.asarray(0.5 * rng.standard_normal((b, t, n)), jnp.float32)
    dt = jnp.asarray(0.1 + 0.5 * rng.random((b, t, h)), jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    out_k = mamba_chunk_scan(x, bm, cm, dt, a_log, chunk=chunk,
                             interpret=True)
    out_r = mamba_scan_ref(x, bm, cm, dt, a_log)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-4, rtol=2e-3)
