"""Segment-block-sparse kernel attention on the packed serve path.

Function-level: the block-sparse q-blocked ref (``_prefill_flash`` with
segment metadata) is BITWISE-equal to the dense masked scan — a skipped
KV block's online-softmax update is the identity, so skipping is a pure
compute save. The one-call Pallas path (``packed_kernel_attention``,
interpret mode on CPU) matches the ref two-part merge to bf16 tolerance:
it sums attention in a different order, so equality is numeric, not
bitwise. Cross-segment isolation and fully-masked rows (cross-attn
``enc_lens == 0``) are asserted directly.

Engine-level: greedy outputs under ``attention_impl="kernel"`` equal the
"ref" path token for token across every model archetype. The two impls
differ by bf16 reduction order, so token equality rides on the engine's
tie-banded greedy argmax (TIE_EPS in serving.engine).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import assert_greedy_equiv, make_engine
from repro.core.request import MMItem
from repro.models import attention as A
from repro.models import blocks_attn as BA
from repro.serving import Request, SamplingParams

ARCHS7 = ["granite-3-2b", "h2o-danube-3-4b", "qwen2-vl-2b", "zamba2-1.2b",
          "rwkv6-3b", "whisper-tiny", "dbrx-132b"]


# ---------------------------------------------------------- packed fixture
def packed_case(seed=0, t=20, s=96, kvl=2, g=2, d=16):
    """One hand-built packed step: seg0 = 8 prefill tokens from scratch,
    seg1 = 1 decode token over 12 old slots, seg2 = a 10-token chunk over
    4 old slots, 1 pad token; old pages segment-contiguous, pad slots
    seg -2."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, t, kvl, g, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, s, kvl, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, s, kvl, d)), jnp.bfloat16)
    kf = jnp.asarray(rng.standard_normal((1, t, kvl, d)), jnp.bfloat16)
    vf = jnp.asarray(rng.standard_normal((1, t, kvl, d)), jnp.bfloat16)
    seg = np.full((1, t), -1, np.int32)
    pos = np.full((1, t), 1 << 29, np.int32)
    cs = np.full((1, t), 1 << 29, np.int32)
    seg[0, :8] = 0; pos[0, :8] = np.arange(8); cs[0, :8] = 0
    seg[0, 8] = 1; pos[0, 8] = 12; cs[0, 8] = 12
    seg[0, 9:19] = 2; pos[0, 9:19] = np.arange(4, 14); cs[0, 9:19] = 4
    sseg = np.full((1, s), -2, np.int32)
    spos = np.full((1, s), np.iinfo(np.int32).max // 2, np.int32)
    sseg[0, :12] = 1; spos[0, :12] = np.arange(12)
    sseg[0, 12:16] = 2; spos[0, 12:16] = np.arange(4)
    return (q, k, v, kf, vf, *map(jnp.asarray, (seg, pos, cs, sseg, spos)))


def ref_packed(q, k, v, kf, vf, seg, pos, cs, sseg, spos, window=0):
    """The ref path's math: dense masked old-slot scan merged with the
    fresh-part segment attention, finalized."""
    m_old = (np.asarray(spos)[:, None, :] < np.asarray(cs)[:, :, None]) \
        & (np.asarray(sseg)[:, None, :] == np.asarray(seg)[:, :, None])
    if window:
        m_old &= (np.asarray(spos)[:, None, :]
                  > np.asarray(pos)[:, :, None] - window)
    oo, mo, lo = A.attend_tokens(q, k, v, jnp.asarray(m_old))
    m_f = A.segment_mask(seg, pos, seg, pos)
    if window:
        m_f = m_f & (pos[:, None, :] > pos[:, :, None] - window)
    of, mf, lf = A.attend_tokens(q, kf, vf, m_f)
    om, mm, lm = A.merge_partials(oo, mo, lo, of, mf, lf)
    return A.finalize_softmax(om, lm), m_old


# --------------------------------------------------------- function level
def test_sparse_ref_bitwise_equals_dense():
    """Block-skipping in the q-blocked ref is bitwise-exact for every row
    with visible old KV: a skipped block's online-softmax update is the
    identity (corr = exp(0) = 1, pexp underflows to exact 0). Rows with NO
    visible old slots differ pre-merge by design — the dense scan
    degenerates to a uniform average (m pinned at NEG_INF makes every pexp
    exp(0) = 1) while the sparse path returns the identity partial
    (l = 0) — and both are erased exactly by the fresh-part merge
    (corr_old = exp(NEG_INF - m_fresh) = 0), so the served output is
    unchanged either way."""
    q, k, v, kf, vf, seg, pos, cs, sseg, spos = packed_case()
    mask = (spos[:, None, :] < cs[:, :, None]) \
        & (sseg[:, None, :] == seg[:, :, None])
    o_d, m_d, l_d = A.attend_tokens(q, k, v, mask)
    o_s, m_s, l_s = BA._prefill_flash(q, k, v, spos, pos, window=0,
                                      chunk_start=cs, q_seg=seg, kv_seg=sseg)
    dense = np.asarray(A.finalize_softmax(o_d, l_d), np.float32)
    sparse = np.asarray(A.finalize_softmax(o_s, l_s), np.float32)
    rows = np.asarray(seg[0] >= 0) & np.asarray(mask[0].any(-1))
    assert rows.any()
    assert (dense[0, rows] == sparse[0, rows]).all()
    # no-old-KV rows: the sparse partial is the merge identity
    nokv = np.asarray(seg[0] >= 0) & ~np.asarray(mask[0].any(-1))
    assert nokv.any()
    assert (np.asarray(l_s, np.float32)[..., nokv] == 0.0).all()
    # ...and after merging the fresh part, ALL real rows agree bitwise
    m_f = A.segment_mask(seg, pos, seg, pos)
    of, mf, lf = A.attend_tokens(q, kf, vf, m_f)
    md = np.asarray(A.finalize_softmax(*_merge_ol(o_d, m_d, l_d, of, mf, lf)),
                    np.float32)
    msp = np.asarray(A.finalize_softmax(*_merge_ol(o_s, m_s, l_s, of, mf, lf)),
                     np.float32)
    real = np.asarray(seg[0]) >= 0
    assert (md[0, real] == msp[0, real]).all()


def _merge_ol(o1, m1, l1, o2, m2, l2):
    o, m, l = A.merge_partials(o1, m1, l1, o2, m2, l2)
    return o, l


@pytest.mark.parametrize("window", [0, 6])
def test_packed_kernel_matches_ref_merge(window):
    """One-call kernel (old slots ++ fresh concat) vs the ref two-part
    merge, causal and sliding-window, to bf16 tolerance."""
    q, k, v, kf, vf, seg, pos, cs, sseg, spos = packed_case()
    ref, _ = ref_packed(q, k, v, kf, vf, seg, pos, cs, sseg, spos,
                        window=window)
    kern = BA.packed_kernel_attention(q, k, v, spos, sseg, kf, vf, pos,
                                      seg, cs, window=window)
    rows = np.asarray(seg[0]) >= 0
    diff = np.abs(np.asarray(ref, np.float32)[0, rows]
                  - np.asarray(kern, np.float32)[0, rows])
    assert diff.max() < 2e-2, diff.max()   # bf16 value scale ~1e-2 ulp


def test_kernel_no_cross_segment_leak():
    """Scrambling another segment's old KV and fresh tokens leaves a
    segment's rows bitwise-unchanged — the kernel's seg-equality mask
    isolates segments exactly."""
    q, k, v, kf, vf, seg, pos, cs, sseg, spos = packed_case()
    base = np.asarray(BA.packed_kernel_attention(
        q, k, v, spos, sseg, kf, vf, pos, seg, cs), np.float32)
    # scramble segment 1's old slots (slots 0:12) and its fresh token (8)
    k2 = k.at[:, :12].set(jnp.asarray(
        np.random.default_rng(9).standard_normal((1, 12, *k.shape[2:])),
        jnp.bfloat16))
    kf2 = kf.at[:, 8].set(100.0)
    vf2 = vf.at[:, 8].set(-100.0)
    pert = np.asarray(BA.packed_kernel_attention(
        q, k2, v, spos, sseg, kf2, vf2, pos, seg, cs), np.float32)
    rows02 = np.isin(np.asarray(seg[0]), [0, 2])
    assert (base[0, rows02] == pert[0, rows02]).all()
    assert not (base[0, np.asarray(seg[0]) == 1]
                == pert[0, np.asarray(seg[0]) == 1]).all()


def test_cross_attn_kernel_masked_rows_zero():
    """Cross-attn kernel: rows whose segment has enc_lens == 0 come back
    exactly zero (matching the ref path's explicit zero guard), other
    rows match the ref masked softmax."""
    rng = np.random.default_rng(3)
    t, s, kvl, g, d = 12, 32, 2, 2, 16
    q = jnp.asarray(rng.standard_normal((1, t, kvl, g, d)), jnp.bfloat16)
    kc = jnp.asarray(rng.standard_normal((1, s, kvl, d)), jnp.bfloat16)
    vc = jnp.asarray(rng.standard_normal((1, s, kvl, d)), jnp.bfloat16)
    seg = np.full((1, t), -1, np.int32)
    seg[0, :6] = 0; seg[0, 6:10] = 1
    enc = np.zeros((1, t), np.int32)
    enc[0, :6] = 24          # seg0 sees 24 encoder slots
    # seg1: enc_lens 0 -> fully masked rows
    sseg = np.full((1, s), -2, np.int32)
    spos = np.full((1, s), np.iinfo(np.int32).max // 2, np.int32)
    sseg[0, :24] = 0; spos[0, :24] = np.arange(24)
    seg, enc, sseg, spos = map(jnp.asarray, (seg, enc, sseg, spos))
    kern = np.asarray(BA.packed_cross_attn_kernel(
        q, kc, vc, spos, sseg, seg, enc), np.float32)
    mask = (sseg[:, None, :] == seg[:, :, None]) \
        & (spos[:, None, :] < enc[:, :, None])
    o, m, l = A.attend_tokens(q, kc, vc, mask)
    ref = np.asarray(A.finalize_softmax(o, l), np.float32)
    assert (kern[0, 6:10] == 0.0).all()
    diff = np.abs(kern[0, :6] - ref[0, :6])
    assert diff.max() < 2e-2, diff.max()


def test_sparse_blocks_sizing():
    """Block sizing stays within kernel-friendly pow2 bounds and shrinks
    with the problem so small packed steps still split into blocks."""
    assert BA.sparse_blocks(16, 64) == (8, 64)
    qb, kb = BA.sparse_blocks(128, 2048)
    assert qb == 32 and kb == 128
    qb, kb = BA.sparse_blocks(10_000, 100_000)
    assert qb == BA.Q_BLOCK and kb == BA.KV_BLOCK


# ----------------------------------------------------------- engine level
@pytest.mark.parametrize("arch", ARCHS7)
def test_kernel_matches_ref_greedy(arch):
    """attention_impl="kernel" reproduces the ref path's greedy outputs
    for every archetype, including mm/encoder item routing (vlm mrope +
    whisper cross-attn). The two impls differ by bf16 reduction order, so
    the comparison is fork-aware (conftest.assert_greedy_equiv); when no
    request forks, first-token and finish step stamps must match too."""
    engs = {}
    for impl in ("ref", "kernel"):
        eng, cfg = make_engine(arch, batching_mode="packed",
                               max_num_batched_tokens=64,
                               attention_impl=impl,
                               record_sample_logits=True)
        for i in range(3):
            kw = {}
            if arch == "whisper-tiny":
                kw["encoder_items"] = (MMItem(0, cfg.encoder_seq,
                                              mm_hash=7 + i),)
            elif arch == "qwen2-vl-2b":
                kw["mm_items"] = (MMItem(2, 6, mm_hash=40 + i),)
            eng.submit(Request(rid=f"r{i}",
                               prompt=[(3 * i + j) % 50
                                       for j in range(12 + i)],
                               sampling=SamplingParams(max_new_tokens=5),
                               **kw))
        eng.run_until_done(max_steps=1000)
        eng.mgr.check_invariants()
        assert len(eng.finished) == 3
        engs[impl] = eng
    forked = assert_greedy_equiv(engs["ref"], engs["kernel"], label=arch)
    if not forked:
        stamps = {impl: {r.rid: (r.first_token_step, r.finished_step)
                         for r in engs[impl].finished} for impl in engs}
        assert stamps["ref"] == stamps["kernel"], (arch, stamps)


def test_kernel_async_composes():
    """Kernel impl under the async double-buffered loop still equals the
    synchronous kernel run bit for bit (async reorders host work only)."""
    outs = {}
    for async_ in (False, True):
        eng, _ = make_engine(batching_mode="packed",
                             max_num_batched_tokens=64,
                             attention_impl="kernel",
                             async_scheduling=async_)
        for i in range(3):
            eng.submit(Request(rid=f"r{i}",
                               prompt=[(3 * i + j) % 50
                                       for j in range(12 + i)],
                               sampling=SamplingParams(max_new_tokens=4)))
        eng.run_until_done(max_steps=1000)
        outs[async_] = {r.rid: list(r.output) for r in eng.finished}
    assert outs[False] == outs[True]
