"""Unit tests for the two-level LCM allocator (Jenga §4)."""
import pytest

from repro.core import (
    BYTES_PER_UNIT,
    JengaKVCacheManager,
    PageState,
    SequenceState,
    attention_spec,
    cross_attention_spec,
    make_geometry,
    mamba_spec,
)


def llama_vision_like_specs(tpp=1):
    """Paper Fig. 6: 2 cross-attn layers (page 256u) + 3 self-attn (384u),
    per-token-per-layer 128u, tokens_per_page=1 -> LCM 768."""
    self_attn = attention_spec(
        "full_attn", num_layers=3, kv_heads=1, head_dim=64, tokens_per_page=tpp
    )
    cross = cross_attention_spec(
        "cross_attn", num_layers=2, kv_heads=1, head_dim=64, tokens_per_page=tpp
    )
    return [self_attn, cross]


def test_lcm_geometry_matches_paper_fig6():
    specs = llama_vision_like_specs()
    assert specs[0].page_units == 384
    assert specs[1].page_units == 256
    geom = make_geometry(specs, total_memory_bytes=768 * 10 * BYTES_PER_UNIT)
    assert geom.large_page_units == 768  # LCM(256, 384)
    assert geom.num_large_pages == 10
    assert geom.small_pages_per_large(specs[0]) == 2
    assert geom.small_pages_per_large(specs[1]) == 3


def test_max_geometry():
    specs = llama_vision_like_specs()
    geom = make_geometry(
        specs, total_memory_bytes=384 * 10 * BYTES_PER_UNIT, mode="max"
    )
    assert geom.large_page_units == 384
    # MAX mode: every small page occupies a whole large page (§4.4)
    assert geom.small_pages_per_large(specs[1]) == 1


def test_gcd_geometry_rejected_for_pools():
    specs = llama_vision_like_specs()
    geom = make_geometry(
        specs, total_memory_bytes=128 * 100 * BYTES_PER_UNIT, mode="gcd"
    )
    assert geom.large_page_units == 128
    with pytest.raises(ValueError):
        geom.small_pages_per_large(specs[0])


def mgr(n_large=8, tpp=1, **kw):
    specs = llama_vision_like_specs(tpp)
    return JengaKVCacheManager(
        specs,
        total_memory_bytes=768 * tpp * n_large * BYTES_PER_UNIT,
        **kw,
    )


def new_req(rid, n_tokens, mm=()):
    return SequenceState(rid=rid, tokens=list(range(100, 100 + n_tokens)),
                         mm_items=tuple(mm))


def test_basic_alloc_free_roundtrip():
    m = mgr(enable_prefix_caching=False)
    r = new_req("r0", 5)
    ok, _ = m.begin_request(r)
    assert ok
    assert m.allocate_for_tokens(r, 5)
    # 5 tokens, tpp=1 -> 5 full-attn pages; no mm items -> 0 cross pages
    assert len(r.page_tables["full_attn"]) == 5
    assert r.page_tables.get("cross_attn", []) == []
    stats = m.memory_stats()
    assert stats.per_type["full_attn"].used == 5
    m.advance(r, 5)
    m.free_request(r, cache=False)
    stats = m.memory_stats()
    assert stats.used_units == 0
    assert stats.free_large == 8  # everything returned to the LCM pool
    m.check_invariants()


def test_request_aware_allocation_packs_per_request():
    """§4.3: small pages within one large page go to the same request."""
    m = mgr(n_large=4, enable_prefix_caching=False)
    a = new_req("a", 2)
    b = new_req("b", 2)
    for r in (a, b):
        ok, _ = m.begin_request(r)
        assert ok
    # interleave allocation
    assert m.allocate_for_tokens(a, 1)
    assert m.allocate_for_tokens(b, 1)
    assert m.allocate_for_tokens(a, 2)
    assert m.allocate_for_tokens(b, 2)
    pool = m.pools["full_attn"]
    pages_a = {pool.pages[e].large_id for e in a.page_tables["full_attn"]}
    pages_b = {pool.pages[e].large_id for e in b.page_tables["full_attn"]}
    # each request's 2 small pages share one large page; requests don't mix
    assert len(pages_a) == 1 and len(pages_b) == 1
    assert pages_a != pages_b
    # freeing one request returns exactly one large page
    free_before = m.large_alloc.num_free
    m.advance(a, 2)
    m.free_request(a, cache=False)
    assert m.large_alloc.num_free == free_before + 1
    m.check_invariants()


def test_fallback_to_other_requests_pages_when_full():
    """§5.4 step 4: use another request's associated page before failing."""
    m = mgr(n_large=1, enable_prefix_caching=False)  # 2 full-attn pages total
    a = new_req("a", 1)
    b = new_req("b", 1)
    for r in (a, b):
        ok, _ = m.begin_request(r)
        assert ok
    assert m.allocate_for_tokens(a, 1)
    # the only large page is associated with "a"; b must still succeed
    assert m.allocate_for_tokens(b, 1)
    pool = m.pools["full_attn"]
    assert pool.counts()["used"] == 2
    # pool exhausted now
    c = new_req("c", 1)
    ok, _ = m.begin_request(c)
    assert ok
    assert not m.allocate_for_tokens(c, 1)
    m.check_invariants()


def test_oom_returns_false_and_rolls_back():
    m = mgr(n_large=2, enable_prefix_caching=False)  # 4 full pages
    r = new_req("r", 10)
    ok, _ = m.begin_request(r)
    assert ok
    assert not m.allocate_for_tokens(r, 10)  # needs 10 > 4
    # transaction rolled back: nothing held
    assert m.memory_stats().used_units == 0
    assert len(r.page_tables["full_attn"]) == 0
    m.check_invariants()


def test_mm_pages_allocated_for_image_tokens_only():
    from repro.core import MMItem
    m = mgr(n_large=16, enable_prefix_caching=False)
    # 4 text + 3 image + 2 text
    r = SequenceState(
        rid="v", tokens=list(range(9)), mm_items=(MMItem(4, 3, mm_hash=77),)
    )
    ok, _ = m.begin_request(r)
    assert ok
    assert m.allocate_for_tokens(r, 9)
    assert len(r.page_tables["full_attn"]) == 9   # all positions get LLM KV
    assert len(r.page_tables["cross_attn"]) == 3  # only image tokens
    m.advance(r, 9)
    m.free_request(r, cache=False)
    assert m.memory_stats().used_units == 0
    m.check_invariants()


def test_lcm_eviction_reclaims_cached_large_pages():
    """§5.4 step 3: a new type can steal LRU evictable large pages from the
    other type's prefix cache."""
    m = mgr(n_large=4, tpp=1)
    # fill cache with full-attn pages of finished requests
    for i in range(2):
        r = new_req(f"r{i}", 4)
        r.tokens = [1000 * i + t for t in range(4)]
        ok, _ = m.begin_request(r)
        assert ok
        assert m.allocate_for_tokens(r, 4)
        m.advance(r, 4)
        m.free_request(r, cache=True)
    stats = m.memory_stats()
    assert stats.per_type["full_attn"].evictable == 8
    assert stats.free_large == 0
    # now a cross-attn-heavy request needs pages -> must evict large pages
    # (4 tokens: 4 full pages = 2 large + 3 cross pages = 1 large <= 4 large)
    from repro.core import MMItem
    r = SequenceState(rid="x", tokens=list(range(4)),
                      mm_items=(MMItem(0, 3, mm_hash=5),))
    ok, _ = m.begin_request(r)
    assert ok
    assert m.allocate_for_tokens(r, 4)
    assert len([p for p in r.page_tables["cross_attn"] if p >= 0]) == 3
    m.check_invariants()


def test_memory_stats_accounting():
    m = mgr(n_large=8, enable_prefix_caching=False)
    r = new_req("r", 3)
    ok, _ = m.begin_request(r)
    assert ok
    assert m.allocate_for_tokens(r, 3)
    s = m.memory_stats()
    # 3 used small pages of 384u; 2 large pages owned (2 per large) -> 1 empty
    assert s.used_units == 3 * 384
    assert s.per_type["full_attn"].owned_large == 2
    assert s.per_type["full_attn"].empty == 1
    assert s.free_large == 6
    assert 0 < s.utilization < 1


def test_mamba_state_allocation_and_checkpoint():
    specs = [
        attention_spec("full_attn", num_layers=2, kv_heads=1, head_dim=64,
                       tokens_per_page=4),
        mamba_spec("mamba", num_layers=2, conv_units=64, ssm_units=64,
                   checkpoint_interval=8),
    ]
    m = JengaKVCacheManager(
        specs, total_memory_bytes=10_000_000, enable_prefix_caching=True
    )
    r = new_req("m", 20)
    ok, ops = m.begin_request(r)
    assert ok and ops == []
    assert m.allocate_for_tokens(r, 20)
    assert "mamba" in r.state_pages
    ops = m.advance(r, 20)
    # checkpoints at 8 and 16
    kinds = [(o.kind, o.position) for o in ops if o.type_name == "mamba"]
    assert kinds == [("checkpoint", 8), ("checkpoint", 16)]
    m.free_request(r, cache=True)
    m.check_invariants()
    # a second identical request should hit at 16 and restore the snapshot
    r2 = new_req("m2", 20)
    ok, ops = m.begin_request(r2)
    assert ok
    assert r2.prefix_hit_tokens == 16
    restores = [o for o in ops if o.kind == "restore"]
    assert len(restores) == 1 and restores[0].position == 16
