"""Per-architecture smoke tests: reduced same-family config, one forward
train step + serve prefill/decode on CPU; asserts shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.spec import lcm as _lcm
from repro.models.lm import DecodeBatch
from repro.models.registry import build_model
from repro.models.tp import single_device_dist

ARCH_IDS = sorted(ARCHS)


def buffer_for(model, min_units=1 << 20):
    """Unified buffer sized as a multiple of the LCM page (geometry rule)."""
    big = _lcm([s.page_units for s in model.kv_specs()])
    units = -(-min_units // big) * big
    return jnp.zeros((1, 1, units), jnp.bfloat16)


def make_serve_batch(model, cfg, B, T, n_tokens, *, prefill, buffer_units,
                     enc_seq=0):
    """Hand-rolled page tables with DISJOINT unit ranges per type (the real
    Jenga allocator guarantees this; here we emulate with a unit cursor)."""
    tpp = cfg.tokens_per_page
    specs = {s.name: s for s in model.kv_specs()}
    tables, page_pos, write_eids, state_eids = {}, {}, {}, {}
    n_pages = -(-n_tokens // tpp)
    cursor = 0  # unit offset; each type's pages start at the next S_t boundary

    def take(s, count):
        nonlocal cursor
        start = -(-cursor // s.page_units)
        cursor = (start + count) * s.page_units
        assert cursor <= buffer_units, (s.name, cursor, buffer_units)
        return jnp.arange(start, start + count, dtype=jnp.int32)

    for name, s in specs.items():
        if s.kind in ("mamba", "rwkv"):
            state_eids[name] = take(s, B)[None]
            continue
        if s.kind == "cross_attn":
            npg = -(-enc_seq // tpp)
            tables[name] = take(s, B * npg).reshape(1, 1, B, npg)
            page_pos[name] = jnp.broadcast_to(
                (jnp.arange(npg, dtype=jnp.int32) * tpp)[None, None, None],
                (1, 1, B, npg))
            write_eids[name] = jnp.repeat(
                tables[name], tpp, axis=3)[:, :, :, :enc_seq]
            continue
        tables[name] = take(s, B * n_pages).reshape(1, 1, B, n_pages)
        page_pos[name] = jnp.broadcast_to(
            (jnp.arange(n_pages, dtype=jnp.int32) * tpp)[None, None, None],
            (1, 1, B, n_pages))
    if prefill:
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    else:
        pos = jnp.full((B, 1), n_tokens - 1, jnp.int32)
    for name in tables:
        if name == "cross_attn" and enc_seq:
            continue
        write_eids[name] = jnp.take_along_axis(
            tables[name][0, 0], pos // tpp, axis=1)[None, None]
    kw = {}
    if cfg.family == "encdec":
        kw["enc_lens"] = jnp.full((B,), enc_seq, jnp.int32)
        if prefill:
            kw["enc_embeds"] = jnp.zeros((B, enc_seq, cfg.d_model),
                                         jnp.float32) + 0.1
            ew = tables["cross_attn"][0, 0]
            kw["enc_write_eids"] = jnp.repeat(
                ew, tpp, axis=1)[:, :enc_seq][None, None]
    if cfg.family == "vlm" and prefill:
        kw["mm_embeds"] = jnp.full((B, T, cfg.d_model), 0.05, jnp.float32)
        kw["mm_mask"] = (jnp.arange(T)[None] < 2).repeat(B, 0)
        kw["mrope_pos"] = jnp.stack([pos] * 3)
    batch = DecodeBatch(
        tokens=(jnp.arange(B * (T if prefill else 1), dtype=jnp.int32)
                .reshape(B, -1) % cfg.vocab_size),
        positions=pos,
        seq_lens=jnp.full((B,), n_tokens, jnp.int32),
        tables=tables, page_pos=page_pos, write_eids=write_eids,
        state_eids=state_eids,
        last_idx=jnp.full((B,), T - 1, jnp.int32) if prefill else None,
        **kw)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced(ARCHS[arch])
    dist = single_device_dist()
    model = build_model(cfg, dist)
    params = model.init(0)
    B, T = 2, 16
    tokens = (jnp.arange(B * T, dtype=jnp.int32).reshape(B, T)
              % cfg.vocab_size)
    targets = jnp.roll(tokens, -1, axis=1)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jnp.full((B, cfg.encoder_seq, cfg.d_model), 0.1,
                                    jnp.float32)
    if cfg.family == "vlm":
        kw["mm_embeds"] = jnp.full((B, T, cfg.d_model), 0.05, jnp.float32)
        kw["mm_mask"] = (jnp.arange(T)[None] < 2).repeat(B, 0)
        kw["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32)[None, None], (3, B, T))
    loss = jax.jit(lambda p: model.train_loss(p, tokens, targets, **kw))(params)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert 1.0 < float(loss) < 20.0, (arch, float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_prefill_decode(arch):
    cfg = reduced(ARCHS[arch])
    dist = single_device_dist()
    model = build_model(cfg, dist)
    params = model.init(0)
    B, T = 2, 12
    enc_seq = cfg.encoder_seq if cfg.family == "encdec" else 0
    buffer = buffer_for(model)
    U = buffer.shape[-1]
    pre = make_serve_batch(model, cfg, B, T, T, prefill=True,
                           buffer_units=U, enc_seq=enc_seq)
    logits, buffer = jax.jit(
        lambda p, b, ba: model.serve_step(p, b, ba, prefill=True)
    )(params, buffer, pre)
    assert logits.shape[0] == B
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    dec = make_serve_batch(model, cfg, B, 1, T + 1, prefill=False,
                           buffer_units=U, enc_seq=enc_seq)
    dlogits, buffer = jax.jit(
        lambda p, b, ba: model.serve_step(p, b, ba, prefill=False)
    )(params, buffer, dec)
    assert dlogits.shape[0] == B
    assert np.isfinite(np.asarray(dlogits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b"])
def test_recurrent_prefill_decode_consistency(arch):
    """Chunked prefill then N decode steps must equal one long prefill."""
    cfg = reduced(ARCHS[arch])
    dist = single_device_dist()
    model = build_model(cfg, dist)
    params = model.init(0)
    B, T = 1, 8
    U = buffer_for(model).shape[-1]
    toks = jnp.arange(T + 3, dtype=jnp.int32)[None] % cfg.vocab_size

    def prefill_upto(n):
        buffer = buffer_for(model)
        batch = make_serve_batch(model, cfg, B, n, n, prefill=True,
                                 buffer_units=U)
        batch = DecodeBatch(**{**batch.__dict__,
                               "tokens": toks[:, :n]})
        lg, buf = jax.jit(lambda p, b, ba: model.serve_step(
            p, b, ba, prefill=True))(params, buffer, batch)
        return lg, buf

    # long prefill of T+2 tokens -> logits predicting token T+2
    l_long, _ = prefill_upto(T + 2)
    # prefill T then decode 2 steps
    l, buf = prefill_upto(T)
    for i in range(2):
        n = T + i + 1
        dec = make_serve_batch(model, cfg, B, 1, n, prefill=False,
                               buffer_units=U)
        dec = DecodeBatch(**{**dec.__dict__, "tokens": toks[:, n - 1:n]})
        l, buf = jax.jit(lambda p, b, ba: model.serve_step(
            p, b, ba, prefill=False))(params, buf, dec)
    err = float(jnp.max(jnp.abs(l - l_long)))
    assert err < 0.25, (arch, err)
