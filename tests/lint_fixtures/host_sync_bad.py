# Linted as serving/sampler.py — every call below is a hot-path host sync.
import jax
import numpy as np


def prepare_step(logits, x, handle):
    a = np.asarray(logits)                  # forbidden: device fetch
    b = np.array(handle)                    # forbidden
    jax.device_get(x)                       # forbidden
    x.block_until_ready()                   # forbidden
    c = x.item()                            # forbidden
    d = float(x.sum())                      # forbidden: non-trivial arg
    e = bool(x.any())                       # forbidden
    return a, b, c, d, e
