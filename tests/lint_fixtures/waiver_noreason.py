# Linted as serving/sampler.py — waiver without a reason is a violation.
import numpy as np


def fetch(handle):
    # jengalint: allow[host-sync]
    return np.asarray(handle)
