# Linted as serving/engine.py — transactional allocation, result handled.


def admit(mgr, req, scheduler):
    ok = mgr.allocate_for_tokens(req, 8)
    if not ok:
        scheduler.defer(req)                 # defer/preempt outcome handled
        return False
    if not mgr.allocate_for_batch([req], 8):
        mgr.rollback_tokens(req, req.num_computed)
        return False
    return True
