# Linted as serving/scheduler.py — every construct below breaks replay.
import random
import time


def schedule(running, waiting):
    now = time.time()                        # forbidden wall clock
    t2 = time.perf_counter()                 # forbidden
    pick = random.choice(waiting)            # forbidden global RNG
    order = {id(r): i for i, r in enumerate(running)}   # forbidden id()
    for r in set(running):                   # forbidden set iteration
        pass
    firsts = [r for r in {1, 2, 3}]          # forbidden set comprehension
    it = iter(set(waiting))                  # forbidden
    return now, t2, pick, order, firsts, it
