# Linted as kernels/step.py — clean jitted function.
import jax
import jax.numpy as jnp
from functools import partial


def serve_step(params, x, *, prefill):
    if prefill:                              # kwonly: static flag idiom
        x = x * 2
    if x.shape[0] > 1:                       # .shape access is static
        x = x[:1]
    return jnp.where(x > 0, x + 1, x)        # traced branch done in-graph


step = jax.jit(partial(serve_step, None, prefill=True))


def host_helper(x):
    print("not jitted, print is fine", x)
    if x > 0:
        return x + 1
    return x
