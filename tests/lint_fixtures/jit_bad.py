# Linted as kernels/step.py — impure jitted function.
import jax
from functools import partial


def serve_step(params, x, n):
    print("tracing", x)                      # forbidden in jitted fn
    if x > 0:                                # forbidden traced branch
        x = x + 1
    out = jax.pure_callback(lambda v: v, x, x)   # forbidden host callback
    return out, n


step = jax.jit(partial(serve_step, None))
