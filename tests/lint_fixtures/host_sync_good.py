# Linted as serving/sampler.py — clean hot-path code.
import jax.numpy as jnp
import numpy as np


def prepare_step(tokens, x, flag, handle):
    up = jnp.asarray(tokens)        # upload, not a sync: never flagged
    y = float(flag)                 # bare name: host scalar, fine
    z = bool(flag)
    # jengalint: allow[host-sync] fetch phase: result row already on host
    out = np.asarray(handle)
    return up, y, z, out
