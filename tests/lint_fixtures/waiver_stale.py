# Linted as serving/sampler.py — waiver that matches nothing is stale.


def clean(x):
    # jengalint: allow[host-sync] this line has no violation at all
    return x + 1
