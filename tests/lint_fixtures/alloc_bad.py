# Linted as serving/engine.py — allocator misuse.


def admit(mgr, pool, req, eid):
    page = pool.allocate(req.rid)            # forbidden direct lifecycle
    pool.free(eid)                           # forbidden
    pool.release_to_cache(eid, 0)            # forbidden
    pool.acquire_cached(eid, req.rid)        # forbidden
    mgr.allocate_for_batch([req], 8)         # forbidden: result discarded
    mgr.allocate_for_tokens(req, 8)          # forbidden: result discarded
    return page
