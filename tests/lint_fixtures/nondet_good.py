# Linted as serving/scheduler.py — deterministic equivalents.
import random


def schedule(running, waiting, clock):
    rng = random.Random(0)                   # seeded instance: allowed
    pick = rng.choice(waiting)
    order = {r.rid: i for i, r in enumerate(running)}   # rid-keyed
    for r in sorted(set(running), key=lambda r: r.rid):  # sorted first
        pass
    return clock, pick, order
