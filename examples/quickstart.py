"""Quickstart: train a reduced model for a few steps, then serve it with the
Jenga-managed engine. Run: PYTHONPATH=src python examples/quickstart.py"""
from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams
from repro.training import AdamWConfig, SyntheticLM, Trainer, TrainerConfig


def main():
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, single_device_dist())

    print("== train a few steps (AdamW, NaN watchdog, async checkpoints) ==")
    trainer = Trainer(model, AdamWConfig(lr=1e-2, warmup_steps=5),
                      TrainerConfig(ckpt_dir="/tmp/quickstart_ckpt",
                                    ckpt_every=10, micro_batches=2))
    params, state = trainer.init_state(0)
    data = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8)
    params, state, hist = trainer.run(
        params, state, data, num_steps=20,
        on_metrics=lambda s, m: print(f"  step {s}: loss={m['loss']:.3f}"))
    print(f"  loss {hist[0]:.3f} -> {hist[-1]:.3f}")

    print("== serve with the Jenga KV manager (prefix caching on) ==")
    eng = Engine(model, EngineConfig(kv_pool_bytes=8 << 20, chunk_size=16),
                 params=params)
    for i in range(3):
        eng.submit(Request(rid=f"req{i}", prompt=list(range(10 + 2 * i)),
                           sampling=SamplingParams(max_new_tokens=8)))
    for r in eng.run_until_done():
        print(f"  {r.rid}: out={r.output}")
    stats = eng.mgr.memory_stats()
    print(f"  pool: used={stats.used_units}u cached={stats.evictable_units}u "
          f"free={stats.free_units}u")


if __name__ == "__main__":
    main()
