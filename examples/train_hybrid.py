"""Train the reduced Zamba2-style hybrid (Mamba2 + shared attention) with
fault-tolerant checkpointing; kill-and-resume is exact.
Run: PYTHONPATH=src python examples/train_hybrid.py"""
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.training import AdamWConfig, SyntheticLM, Trainer, TrainerConfig


def main():
    cfg = reduced(ARCHS["zamba2-1.2b"])
    model = build_model(cfg, single_device_dist())
    trainer = Trainer(model, AdamWConfig(lr=5e-3, warmup_steps=10,
                                         total_steps=300),
                      TrainerConfig(ckpt_dir="/tmp/hybrid_ckpt",
                                    ckpt_every=50, micro_batches=2))
    params, state = trainer.init_state(0)
    data = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=8)
    params, state, hist = trainer.run(
        params, state, data, num_steps=120, log_every=20,
        on_metrics=lambda s, m: print(
            f"step {s}: loss={m['loss']:.3f} gnorm={m['grad_norm']:.2f} "
            f"{m['sec_per_step']*1e3:.0f}ms"))
    print(f"loss: {hist[0]:.3f} -> {np.mean(hist[-10:]):.3f}")
    last = trainer.ckpt.latest_step()
    p2, s2, meta = trainer.restore(last)
    print(f"restored step {last} (model={meta['extra']['model']}) — resume OK")


if __name__ == "__main__":
    main()
