"""Speculative decoding (§6.1): draft + target share ONE Jenga pool with two
page sizes. Run: PYTHONPATH=src python examples/spec_decode_demo.py"""
from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving.spec_decode import SpecDecodeConfig, SpecDecodeEngine


def main():
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"], num_layers=2,
                   vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    sd = SpecDecodeEngine(build_model(tcfg, dist), build_model(dcfg, dist),
                          SpecDecodeConfig(k=3, kv_pool_bytes=16 << 20))
    sizes = {s.name: s.page_units for s in sd.mgr.specs}
    print("pool page sizes:", sizes,
          "LCM large page:", sd.mgr.geometry.large_page_units)
    out = sd.generate(list(range(16)), max_new_tokens=12)
    print("output:", out)
    print("accepted per round:", sd.accept_lengths)


if __name__ == "__main__":
    main()
