"""Serve three heterogeneous families through the SAME engine — one memory
manager for SWA mixes, hybrid SSM state, and cross-attention caches; Jenga
vs PagedAttention-baseline peak pool usage.
Run: PYTHONPATH=src python examples/serve_heterogeneous.py"""
from repro.configs import ARCHS, reduced
from repro.core.request import MMItem
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams


def serve(arch: str, mode: str):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    eng = Engine(model, EngineConfig(kv_pool_bytes=4 << 20, chunk_size=16,
                                     memory_mode=mode))
    kw = {}
    if cfg.family == "encdec":
        kw["encoder_items"] = (MMItem(0, cfg.encoder_seq, mm_hash=5),)
    for i in range(3):
        eng.submit(Request(rid=f"r{i}", prompt=list(range(40)),
                           sampling=SamplingParams(max_new_tokens=4), **kw))
    eng.run_until_done(max_steps=600)
    return max(m.used_units for m in eng.metrics)


def main():
    for arch in ("h2o-danube-3-4b", "zamba2-1.2b", "whisper-tiny"):
        j = serve(arch, "jenga")
        p = serve(arch, "paged-baseline")
        print(f"{arch:20s} peak used units: jenga={j:>9} paged={p:>9} "
              f"({p/max(1,j):.2f}x waste)")


if __name__ == "__main__":
    main()
