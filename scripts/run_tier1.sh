#!/usr/bin/env bash
# Tier-1 verification: the full pytest suite with the src/ layout on the
# path. Record the final pass/fail line in CHANGES.md for every PR so
# regressions are visible per PR.
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q "$@"
