#!/usr/bin/env bash
# Tier-1 verification: the full pytest suite with the src/ layout on the
# path. Record the final pass/fail line in CHANGES.md for every PR so
# regressions are visible per PR.
set -u
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Serving-invariant lint first: cheap (pure AST, no jax import) and a
# violation means the suite below could pass while the invariant contract
# is already broken.
python scripts/run_lint.py || exit 1

# Fail loudly if something still shadows src/ under the EXACT path the run
# uses: `repro` is a NAMESPACE package, so a stale REGULAR `repro` package
# (with __init__.py) anywhere on PYTHONPATH or in site-packages beats it
# even though src/ is prepended — the suite would silently test the WRONG
# code.
shadow="$(python - <<'EOF'
import os
import importlib.util
spec = importlib.util.find_spec("repro")
if spec is None:
    print("")
elif spec.origin:                       # regular package: .../__init__.py
    print(os.path.dirname(spec.origin))
else:                                   # namespace package: first location wins
    print(next(iter(spec.submodule_search_locations), ""))
EOF
)"
expected="$(pwd)/src/repro"
if [ "$shadow" != "$expected" ]; then
  echo "error: PYTHONPATH shadows src/: 'repro' resolves to" >&2
  echo "  ${shadow:-<nothing>}" >&2
  echo "instead of" >&2
  echo "  $expected" >&2
  echo "unset PYTHONPATH (or remove the stale entry) and re-run." >&2
  exit 1
fi

# --bench: after the suite, run the router A/B benchmark (writes
# BENCH_router.json at the repo root) so the fleet perf trajectory is
# recorded alongside the test result.
run_bench=0
args=()
for a in "$@"; do
  if [ "$a" = "--bench" ]; then run_bench=1; else args+=("$a"); fi
done

if [ "$run_bench" = "1" ]; then
  python -m pytest -q ${args[@]+"${args[@]}"} || exit $?
  exec python benchmarks/bench_throughput.py router
fi
exec python -m pytest -q ${args[@]+"${args[@]}"}
