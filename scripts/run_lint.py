#!/usr/bin/env python
"""Run jengalint over the whole src/repro tree.

Exit 0 when the tree is clean (every remaining host-sync / nondeterminism
/ allocation-lifecycle site carries a reviewed ``# jengalint: allow[...]``
waiver with a reason); exit 1 and print each violation otherwise.

    python scripts/run_lint.py                # lint the tree
    python scripts/run_lint.py --list-waivers # audit the waiver inventory
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis import jengalint  # noqa: E402

if __name__ == "__main__":
    sys.exit(jengalint.main(sys.argv[1:]))
