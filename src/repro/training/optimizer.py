"""AdamW in raw JAX, with optional ZeRO-1 optimizer-state sharding over the
data axis and a bf16 error-feedback compressed-psum utility for DP gradient
sync (distributed-optimization tricks; DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: Any
    mu: Any
    nu: Any


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, params, grads, state: OptState
           ) -> Tuple[Any, OptState, dict]:
    """One AdamW step. Everything elementwise -> sharding-preserving."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics


# ------------------------------------------------------------------- ZeRO-1
def zero1_spec(param_spec: P, shape: Tuple[int, ...], data_size: int,
               axis: str = "data") -> P:
    """Shard optimizer state over the data axis on the first dim that is
    free (unsharded) and divisible — the ZeRO-1 memory win. Falls back to
    the param's own spec (e.g. FSDP/EP params already use the data axis)."""
    flat = []
    for e in param_spec:
        flat.extend(e if isinstance(e, tuple) else (e,))
    if axis in flat:
        return param_spec
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (e, n) in enumerate(zip(entries, shape)):
        if e is None and n % data_size == 0 and n > 0:
            entries[i] = axis
            return P(*entries)
    return param_spec


def zero1_shardings(param_specs, param_shapes, mesh, axis: str = "data"):
    data_size = mesh.shape[axis]
    # multi-pod: additionally shard optimizer state over the pod axis
    # (ZeRO over DCN — states are only touched once per step)
    pod = "pod" in mesh.axis_names

    def one(spec, shp):
        out = zero1_spec(spec, shp.shape, data_size, axis)
        if pod:
            out = zero1_spec(out, shp.shape, mesh.shape["pod"], "pod")
        return NamedSharding(mesh, out)

    return jax.tree.map(one, param_specs, param_shapes)


# ------------------------------------------- compressed DP gradient all-reduce
def compressed_psum(x, axis_name: str, error: Optional[jax.Array] = None):
    """bf16 all-reduce with error feedback: quantize (x + e) to bf16, psum,
    and return (sum, new_error). Halves DP gradient-sync bytes; the error
    carry keeps the long-run bias at zero."""
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    q = xf.astype(jnp.bfloat16)
    new_error = xf - q.astype(jnp.float32)
    total = jax.lax.psum(q, axis_name).astype(jnp.float32)
    return total, new_error
