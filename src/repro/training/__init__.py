from .checkpoint import Checkpointer
from .data import Prefetcher, SyntheticLM
from .optimizer import AdamWConfig, OptState, compressed_psum, init, update
from .trainer import Trainer, TrainerConfig
