"""Deterministic synthetic data pipeline (sharded, prefetching).

Two modes:
  * "uniform"  — iid tokens (throughput benchmarking);
  * "markov"   — a fixed random Markov chain over the vocab, so a model can
    actually learn structure (loss visibly decreases in examples).

Determinism: batch(step) depends only on (seed, step), so training resumes
bit-exactly after checkpoint restore — required for fault tolerance.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, mode: str = "markov", order_states: int = 64):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.mode = mode
        if mode == "markov":
            rng = np.random.default_rng(seed + 12345)
            s = min(order_states, vocab_size)
            # sparse-ish transition table: each state prefers ~4 successors
            self.succ = rng.integers(0, vocab_size, size=(s, 4))
            self.states = s

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        if self.mode == "uniform":
            toks = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1),
                                dtype=np.int32)
        else:
            toks = np.empty((self.batch, self.seq + 1), np.int32)
            cur = rng.integers(0, self.states, size=self.batch)
            choice = rng.integers(0, 4, size=(self.batch, self.seq + 1))
            noise = rng.random((self.batch, self.seq + 1)) < 0.05
            rand = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1))
            for t in range(self.seq + 1):
                nxt = self.succ[cur % self.states, choice[:, t]]
                nxt = np.where(noise[:, t], rand[:, t], nxt)
                toks[:, t] = nxt
                cur = nxt
        return toks[:, :-1], toks[:, 1:]

    def iterate(self, start_step: int = 0) -> Iterator:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of host batches (overlap with device step)."""

    def __init__(self, dataset: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            it = dataset.iterate(start_step)
            while not self._stop.is_set():
                try:
                    self.q.put(next(it), timeout=0.5)
                except queue.Full:
                    continue

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def next(self, timeout: float = 30.0):
        return self.q.get(timeout=timeout)

    def stop(self):
        self._stop.set()
