"""Mesh-shape-independent checkpoints with async save and elastic restore.

Format: one .npy per pytree leaf (path-encoded filename) + meta.json.
Restore re-places every leaf with the *target* NamedSharding, so a
checkpoint written on one mesh restores onto any other mesh shape (elastic
scaling / shrink-to-recover after node failure). Saves run on a background
thread (training continues; `wait()` joins before the next save).

On a multi-host deployment the same format extends to per-host shard files;
here process_count == 1 so full-leaf files are exact.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_files(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in paths:
        name = jax.tree_util.keystr(kp)
        fname = re.sub(r"[^A-Za-z0-9_.-]+", "_", name).strip("_") + ".npy"
        out.append((fname, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap vs device step), then
        # write files on a background thread (async checkpointing).
        host = [(f, np.asarray(jax.device_get(x)))
                for f, x in _leaf_files(tree)]
        meta = {"step": int(step), "extra": extra or {},
                "leaves": [f for f, _ in host]}

        def write():
            tmp = tempfile.mkdtemp(dir=self.dir)
            for fname, arr in host:
                np.save(os.path.join(tmp, fname), arr)
            with open(os.path.join(tmp, "meta.json"), "w") as fh:
                json.dump(meta, fh)
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any, shardings: Any = None):
        """Load into the structure of ``target_tree``; if ``shardings`` is a
        matching pytree of NamedShardings, leaves are placed sharded (the
        elastic-rescale path: target mesh may differ from the save mesh)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as fh:
            meta = json.load(fh)
        files = dict.fromkeys(meta["leaves"])
        leaves = _leaf_files(target_tree)
        assert [f for f, _ in leaves] == list(files), "tree structure changed"
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(leaves))
        out = []
        for (fname, ref), sh in zip(leaves, shard_leaves):
            arr = np.load(os.path.join(d, fname))
            assert arr.shape == ref.shape, (fname, arr.shape, ref.shape)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        treedef = jax.tree.structure(target_tree)
        return jax.tree.unflatten(treedef, out), meta
