"""Fault-tolerant training driver.

Features (DESIGN.md §5):
  * jitted train step: shard_map loss -> grads -> AdamW (optionally ZeRO-1
    sharded states) with microbatch gradient accumulation;
  * deterministic data keyed by step -> bit-exact resume;
  * NaN/Inf watchdog: restore last checkpoint and skip the bad step;
  * async checkpointing every N steps + elastic restore onto any mesh;
  * straggler monitor: per-step wall-time EMA, slow-step counter and hook.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from . import optimizer as opt
from .checkpoint import Checkpointer
from .data import SyntheticLM


@dataclasses.dataclass
class TrainerConfig:
    micro_batches: int = 1
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    zero1: bool = True
    straggler_factor: float = 3.0
    max_restores: int = 3


class Trainer:
    def __init__(self, model, adamw: opt.AdamWConfig,
                 tcfg: TrainerConfig, extra_batch: Optional[Callable] = None):
        self.model = model
        self.dist = model.dist
        self.adamw = adamw
        self.tcfg = tcfg
        self.extra_batch = extra_batch or (lambda tokens: {})
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        self._build()
        # straggler stats
        self.step_ema: Optional[float] = None
        self.slow_steps = 0
        self.restores = 0

    # ------------------------------------------------------------------ build
    def _build(self):
        model, mesh = self.model, self.dist.mesh
        specs = model.specs()
        self.param_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs)
        struct = model.struct()
        if self.tcfg.zero1:
            state_shardings = opt.zero1_shardings(specs, struct, mesh)
        else:
            state_shardings = self.param_shardings
        self.opt_shardings = opt.OptState(
            step=NamedSharding(mesh, P()),
            mu=state_shardings, nu=jax.tree.map(lambda x: x, state_shardings))
        acfg = self.adamw
        n_micro = self.tcfg.micro_batches

        def loss_fn(params, tokens, targets, extras):
            return model.train_loss(params, tokens, targets, **extras)

        def step_fn(params, state, tokens, targets, extras):
            b = tokens.shape[0]
            mb = b // n_micro

            def micro(carry, xs):
                gsum, lsum = carry
                tok, tgt, ex = xs
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, tok, tgt, ex)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            split = lambda a: a.reshape(n_micro, mb, *a.shape[1:])
            ex_split = jax.tree.map(split, extras)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (gz, jnp.float32(0)),
                (split(tokens), split(targets), ex_split))
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
            params2, state2, metrics = opt.update(acfg, params, grads, state)
            metrics["loss"] = loss
            return params2, state2, metrics

        self._step = jax.jit(
            step_fn,
            in_shardings=(self.param_shardings, self.opt_shardings,
                          None, None, None),
            out_shardings=(self.param_shardings, self.opt_shardings, None),
            donate_argnums=(0, 1),
        )

    # ------------------------------------------------------------------- init
    def init_state(self, seed: int = 0):
        params = self.model.init(seed)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, s), params, self.param_shardings)
        state = opt.init(params)
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state, self.opt_shardings)
        return params, state

    # -------------------------------------------------------------------- run
    def run(self, params, state, dataset: SyntheticLM, num_steps: int,
            start_step: int = 0, log_every: int = 10,
            on_metrics: Optional[Callable[[int, Dict], None]] = None):
        step = start_step
        history = []
        while step < num_steps:
            tokens_np, targets_np = dataset.batch_at(step)
            extras = self.extra_batch(tokens_np)
            t0 = time.perf_counter()
            params2, state2, metrics = self._step(
                params, state, jnp.asarray(tokens_np),
                jnp.asarray(targets_np), extras)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            # ---- NaN watchdog: restore + skip the poisoned step
            if not np.isfinite(loss):
                self.restores += 1
                if self.restores > self.tcfg.max_restores:
                    raise RuntimeError("too many NaN restores")
                last = self.ckpt.latest_step()
                if last is None:
                    raise RuntimeError(f"NaN at step {step}, no checkpoint")
                params, state, _ = self.restore(last)
                step = last + 1  # skip the bad batch deterministically
                continue
            params, state = params2, state2
            # ---- straggler monitor
            if self.step_ema is None:
                self.step_ema = dt
            else:
                if dt > self.tcfg.straggler_factor * self.step_ema:
                    self.slow_steps += 1
                self.step_ema = 0.9 * self.step_ema + 0.1 * dt
            history.append(loss)
            if on_metrics and step % log_every == 0:
                on_metrics(step, {**{k: float(v) for k, v in metrics.items()},
                                  "sec_per_step": dt,
                                  "slow_steps": self.slow_steps})
            step += 1
            if step % self.tcfg.ckpt_every == 0:
                self.save(step, params, state)
        self.ckpt.wait()
        return params, state, history

    # ----------------------------------------------------------- checkpoints
    def save(self, step: int, params, state, blocking: bool = False):
        self.ckpt.save(step, {"params": params, "opt": state},
                       extra={"model": self.model.cfg.name}, blocking=blocking)

    def restore(self, step: int):
        target = {"params": self.model.struct(),
                  "opt": opt.OptState(
                      step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=self.model.struct(), nu=self.model.struct())}
        shardings = {"params": self.param_shardings, "opt": self.opt_shardings}
        # struct leaves are fp32 for mu/nu
        target["opt"] = opt.OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                            self.model.struct()),
            nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                            self.model.struct()))
        tree, meta = self.ckpt.restore(step, target, shardings)
        return tree["params"], tree["opt"], meta
