"""Recurrent sequence mixers: Mamba2 (SSD chunked form) and RWKV6.

TPU adaptation (DESIGN.md §3): both mixers are computed in *chunked parallel*
form — intra-chunk quadratic matmuls (MXU-friendly) + inter-chunk state
carries — instead of the token-sequential CUDA scans of the reference
implementations. All decay exponent differences are clamped ≤ 0, so the
chunked math never overflows.

State layout per layer (local to a tp shard):
  Mamba2: [ssm_state (H_local*P*N) | conv_state ((W-1)*(d_in_local+2N))]
  RWKV6:  [wkv_state (H_local*hs*hs) | att_shift (d) | cm_shift (d)]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import dense, rms_norm
from .tp import Dist, psum_tp


# ====================================================================== Mamba2
def mamba2_dims(d_model: int, expand: int, headdim: int, d_state: int,
                conv_width: int, tp: int):
    d_inner = expand * d_model
    heads = d_inner // headdim
    assert heads % tp == 0, (heads, tp)
    h_local = heads // tp
    d_in_local = h_local * headdim
    ssm_units = h_local * headdim * d_state
    conv_units = (conv_width - 1) * (d_in_local + 2 * d_state)
    return dict(d_inner=d_inner, heads=heads, h_local=h_local,
                d_in_local=d_in_local, ssm_units=ssm_units,
                conv_units=conv_units)


def _causal_conv(x, w, x_init=None):
    """Depthwise causal conv: x (B,T,C), w (W,C). x_init: (B,W-1,C) carry.
    Returns (out, xp) where xp is the carry-prefixed input — the conv state
    after token j is ``xp[:, j+1 : j+width]`` (callers slice/gather it)."""
    width = w.shape[0]
    if x_init is None:
        x_init = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([x_init, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(width))
    return out, xp


def _conv_state_at(xp, width: int, last_idx=None):
    """Conv carry after the last *valid* token of each row: the ``width-1``
    xp rows ending at that token (ragged mixed batches pad rows past
    ``last_idx``; the naive trailing slice would capture pad garbage)."""
    if width <= 1:
        return xp[:, :0]
    if last_idx is None:
        return xp[:, -(width - 1):]
    idx = last_idx[:, None] + 1 + jnp.arange(width - 1)[None]   # (B, W-1)
    return jnp.take_along_axis(xp, idx[..., None], axis=1)


# ----------------------------------------------------- packed-stream helpers
def _packed_causal_conv(xf, w, conv0, seg_ids, seg_start):
    """Depthwise causal conv over a PACKED token stream. xf: (TT, C);
    w: (W, C); conv0: (S, W-1, C) per-segment carry; seg_ids/seg_start:
    (TT,) — segment id per token (-1 pad) and the stream index of the
    token's segment's first token. Predecessors that fall before a
    segment's first stream slot are read from that segment's carry, so
    neighbouring segments never leak into each other."""
    tt, _ = xf.shape
    width = w.shape[0]
    idx = jnp.arange(tt)
    segc = jnp.maximum(seg_ids, 0)
    out = xf * w[width - 1][None]
    for k in range(1, width):
        shifted = xf[jnp.maximum(idx - k, 0)]
        off = idx - seg_start                        # in-segment offset
        ci = jnp.clip(width - 1 + off - k, 0, width - 2)
        carry = conv0[segc, ci].astype(xf.dtype)
        out = out + w[width - 1 - k][None] * jnp.where(
            (idx - k >= seg_start)[:, None], shifted, carry)
    return out


def _packed_conv_state(xf, conv0, seg_start, seg_last, width):
    """Per-segment conv carry after each segment's last token: the last
    ``width-1`` stream inputs of the segment, topped up from the incoming
    carry when the segment is shorter than the window. xf: (TT, C);
    conv0: (S, W-1, C); seg_start: (TT,); seg_last: (S,)."""
    if width <= 1:
        return conv0[:, :0]
    tt = xf.shape[0]
    last = jnp.clip(seg_last, 0, tt - 1)
    start_seg = seg_start[last]                                 # (S,)
    o_last = last - start_seg                                   # in-seg offset
    offs = o_last[:, None] - (width - 2) + jnp.arange(width - 1)[None]
    gidx = jnp.clip(start_seg[:, None] + offs, 0, tt - 1)       # (S, W-1)
    from_x = xf[gidx].astype(conv0.dtype)
    from_0 = jnp.take_along_axis(
        conv0, jnp.clip(width - 1 + offs, 0, width - 2)[..., None], axis=1)
    return jnp.where((offs >= 0)[..., None], from_x, from_0)


def _packed_shift(xf, shift0, seg_ids, seg_start):
    """Token-shift over a packed stream: x_prev[t] = x[t-1] within the
    token's segment, or the segment's carried shift state at the segment's
    first token. xf: (TT, d); shift0: (S, 1, d). Returns (1, TT, d)."""
    tt = xf.shape[0]
    idx = jnp.arange(tt)
    segc = jnp.maximum(seg_ids, 0)
    prev = xf[jnp.maximum(idx - 1, 0)]
    carry = shift0[segc, 0].astype(xf.dtype)
    return jnp.where((idx - 1 >= seg_start)[:, None], prev, carry)[None]


def _mamba_project(p, x, md):
    """Shared projections for all modes. Returns z, xr, Bm, Cm, dt."""
    z = dense(x, p["w_z"])                                    # (B,T,d_in_local)
    xr = dense(x, p["w_x"])
    Bm = dense(x, p["w_B"])                                   # (B,T,N) replicated
    Cm = dense(x, p["w_C"])
    dt = dense(x, p["w_dt"]).astype(jnp.float32)              # (B,T,H_local)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    return z, xr, Bm, Cm, dt


def mamba2_chunked(p, x, dist: Dist, md: dict, *, d_state: int, headdim: int,
                   conv_width: int, chunk: int = 128, norm_eps=1e-5,
                   init_state=None, length_mask=None, last_idx=None):
    """Mamba2 over a full sequence (train / prefill / mixed serving batch).

    x: (B, T, d) replicated. Returns (y, final_state_flat).

    Ragged mixed batches: ``length_mask`` (B, T) marks valid tokens and
    ``last_idx`` (B,) the last valid slot per row. Padded tokens get dt=0 —
    zero decay exponent and zero state contribution — so the final SSM state
    is exactly the state after each row's last real token; the conv carry is
    gathered at ``last_idx`` for the same reason. Outputs at padded slots
    are garbage and must be discarded by the caller."""
    b, t, _ = x.shape
    hl, dil = md["h_local"], md["d_in_local"]
    xn = rms_norm(x, p["norm"], norm_eps)
    z, xr, Bm, Cm, dt = _mamba_project(p, xn, md)
    if length_mask is not None:
        dt = dt * length_mask[..., None].astype(dt.dtype)

    if init_state is not None:
        ssm0, conv0 = split_mamba_state(init_state, md, d_state, headdim,
                                        conv_width)
    else:
        ssm0 = jnp.zeros((b, hl, headdim, d_state), jnp.float32)
        conv0 = jnp.zeros((b, conv_width - 1, dil + 2 * d_state), x.dtype)

    xbc = jnp.concatenate([xr, Bm, Cm], axis=-1)
    xbc, xp_conv = _causal_conv(xbc, p["conv_w"], conv0)
    conv_state = _conv_state_at(xp_conv, conv_width, last_idx)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xr = xbc[..., :dil]
    Bm = xbc[..., dil:dil + d_state].astype(jnp.float32)
    Cm = xbc[..., dil + d_state:].astype(jnp.float32)

    # pad to chunk multiple
    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    def padt(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
    xh = padt(xr).reshape(b, nchunk, chunk, hl, headdim)
    Bc = padt(Bm).reshape(b, nchunk, chunk, d_state)
    Cc = padt(Cm).reshape(b, nchunk, chunk, d_state)
    dtc = padt(dt).reshape(b, nchunk, chunk, hl)

    a_log = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H_local,) < 0

    def chunk_step(S, inp):
        """SSD chunk: intra-chunk quadratic + inter-chunk state carry.

        Contribution of step s to y_t (s<=t) decays by exp(L_t - L_s) <= 0
        in log space, so no exponent here can overflow."""
        xck, bck, cck, dck = inp           # (B,L,H,P) (B,L,N) (B,L,N) (B,L,H)
        ldec = dck * a_log[None, None]     # (B,L,H) <= 0
        L = jnp.cumsum(ldec, axis=1)       # inclusive
        # intra-chunk: score_ts = (C_t . B_s) * exp(L_t - L_s) * dt_s, s<=t
        cb = jnp.einsum("btn,bsn->bts", cck, bck)             # (B,L,L)
        diff = L[:, :, None, :] - L[:, None, :, :]            # (B,t,s,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dec = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        score = cb[..., None] * dec * dck[:, None]            # (B,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", score, xck.astype(jnp.float32))
        # inter-chunk: carry state read, decayed by exp(L_t) <= 1
        rfac = jnp.exp(L)
        y += jnp.einsum("btn,bhpn,bth->bthp", cck, S, rfac)
        # state update: S_out = exp(L_last) S + sum_s exp(L_last-L_s) dt_s x_s B_s
        sfac = jnp.exp(L[:, -1][:, None, :] - L) * dck        # (B,L,H) <= dt
        S_add = jnp.einsum("blh,blhp,bln->bhpn", sfac,
                           xck.astype(jnp.float32), bck)
        S_new = S * jnp.exp(L[:, -1])[:, :, None, None] + S_add
        return S_new, y

    (S_fin, ys) = jax.lax.scan(
        chunk_step, ssm0,
        (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(Bc, 1, 0),
         jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(dtc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nchunk * chunk, hl, headdim)[:, :t]
    y = y + xr.reshape(b, t, hl, headdim).astype(jnp.float32) \
        * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, t, dil).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(y, p["w_out"])
    out = psum_tp(out, dist)
    state = flatten_mamba_state(S_fin, conv_state, md)
    return x + out, state


def mamba2_packed(p, x, dist: Dist, md: dict, *, d_state: int, headdim: int,
                  conv_width: int, seg_ids, seg_start, seg_last, init_state,
                  chunk: int = 128, norm_eps=1e-5):
    """Mamba2 over a PACKED token stream: ``x`` is (1, TT, d) holding S
    independent segments back to back (segment contiguity is the layout
    invariant every reset below relies on). seg_ids (TT,): segment per
    token (-1 pad); seg_start (TT,): stream index of the token's segment's
    first token; seg_last (S,): stream index of each segment's last token;
    init_state (S, units): per-segment entry state.

    The chunked SSD scan carries ONE state per SEGMENT instead of one per
    batch row: within a chunk, cross-segment score terms are masked by
    segment equality, the inter-chunk state read decays by the cumulative
    log-decay since the segment's first in-chunk token (cumsum differences
    cancel other segments' decay because segments are contiguous), and the
    per-segment state update only folds in that segment's tokens — so at
    scan end ``states[i]`` is exactly the state after segment i's last
    token (segments untouched by a chunk pass through unchanged, pads
    contribute dt=0). Returns (y (1,TT,d), final_states (S, units));
    outputs at pad slots are garbage and must be discarded by the caller."""
    b, t, _ = x.shape
    assert b == 1, "packed streams are single-row"
    nseg = init_state.shape[0]
    hl, dil = md["h_local"], md["d_in_local"]
    xn = rms_norm(x, p["norm"], norm_eps)
    z, xr, Bm, Cm, dt = _mamba_project(p, xn, md)
    valid = (seg_ids >= 0)
    dt = dt * valid[None, :, None].astype(dt.dtype)

    ssm0, conv0 = split_mamba_state(init_state, md, d_state, headdim,
                                    conv_width)                 # (S, ...)
    raw = jnp.concatenate([xr, Bm, Cm], axis=-1)[0]             # (TT, C)
    conv_out = _packed_causal_conv(raw, p["conv_w"], conv0, seg_ids,
                                   seg_start)
    conv_state = _packed_conv_state(raw, conv0, seg_start, seg_last,
                                    conv_width)
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    # zero pad tokens so no pad garbage can reach any state/score term
    # (0 * non-finite would poison the per-segment scatter-adds)
    xbc = xbc * valid[:, None].astype(xbc.dtype)
    xr_s = xbc[:, :dil]
    Bm_s = xbc[:, dil:dil + d_state].astype(jnp.float32)
    Cm_s = xbc[:, dil + d_state:].astype(jnp.float32)

    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    xh = jnp.pad(xr_s, ((0, pad), (0, 0))).reshape(
        nchunk, chunk, hl, headdim)
    Bc = jnp.pad(Bm_s, ((0, pad), (0, 0))).reshape(nchunk, chunk, d_state)
    Cc = jnp.pad(Cm_s, ((0, pad), (0, 0))).reshape(nchunk, chunk, d_state)
    dtc = jnp.pad(dt[0], ((0, pad), (0, 0))).reshape(nchunk, chunk, hl)
    segc = jnp.pad(seg_ids, (0, pad), constant_values=-1).reshape(
        nchunk, chunk)
    a_log = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,) < 0

    def chunk_step(S_seg, inp):
        xck, bck, cck, dck, sk = inp
        oneh = (sk[:, None] == jnp.arange(nseg)[None]).astype(jnp.float32)
        ldec = dck * a_log[None]                                # (L,H) <= 0
        cumL = jnp.cumsum(ldec, axis=0)                         # inclusive
        cumL_ex = cumL - ldec
        same = (sk[:, None] == sk[None, :]) & (sk >= 0)[:, None]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool)) & same
        # intra-chunk: cumsum differences only accumulate own-segment decay
        # because cross-segment (t, s) pairs are masked and segments are
        # contiguous within the chunk
        cb = jnp.einsum("tn,sn->ts", cck, bck)
        diff = cumL[:, None] - cumL[None]                       # (t,s,H)
        dec = jnp.exp(jnp.where(mask[..., None], diff, -jnp.inf))
        score = cb[..., None] * dec * dck[None]
        y = jnp.einsum("tsh,shp->thp", score, xck.astype(jnp.float32))
        # inter-chunk: each token reads ITS segment's carried state,
        # decayed since the segment's first in-chunk token
        big = jnp.where(oneh[..., None] > 0, cumL_ex[:, None], -jnp.inf)
        base = jnp.max(big, axis=0)                             # (S,H)
        base = jnp.where(jnp.isfinite(base), base, 0.0)         # absent segs
        rfac = jnp.exp(cumL - base[jnp.maximum(sk, 0)])         # (L,H) <= 1
        S_tok = S_seg[jnp.maximum(sk, 0)]                       # (L,H,P,N)
        y = y + jnp.einsum("tn,thpn,th->thp", cck, S_tok, rfac)
        # per-segment state update: decay by the segment's own in-chunk
        # decay mass; scatter-add contributions by segment
        seg_sum = jnp.einsum("ls,lh->sh", oneh, ldec)           # (S,H) <= 0
        segend = jnp.min(jnp.where(oneh[..., None] > 0, cumL[:, None],
                                   jnp.inf), axis=0)            # (S,H)
        segend = jnp.where(jnp.isfinite(segend), segend, 0.0)
        sfac = jnp.exp(segend[jnp.maximum(sk, 0)] - cumL) * dck  # (L,H)
        S_add = jnp.einsum("ls,lh,lhp,ln->shpn", oneh, sfac,
                           xck.astype(jnp.float32), bck)
        S_new = S_seg * jnp.exp(seg_sum)[..., None, None] + S_add
        return S_new, y

    S_fin, ys = jax.lax.scan(chunk_step, ssm0, (xh, Bc, Cc, dtc, segc))
    y = ys.reshape(nchunk * chunk, hl, headdim)[:t][None]
    y = y + xr_s.reshape(1, t, hl, headdim).astype(jnp.float32) \
        * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(1, t, dil).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(y, p["w_out"])
    out = psum_tp(out, dist)
    state = flatten_mamba_state(S_fin, conv_state, md)
    return x + out, state


def mamba2_step(p, x, state_flat, dist: Dist, md: dict, *, d_state: int,
                headdim: int, conv_width: int, norm_eps=1e-5):
    """Single-token decode. x: (B, 1, d). Returns (y, new_state_flat)."""
    b = x.shape[0]
    hl, dil = md["h_local"], md["d_in_local"]
    ssm, conv = split_mamba_state(state_flat, md, d_state, headdim, conv_width)
    xn = rms_norm(x, p["norm"], norm_eps)
    z, xr, Bm, Cm, dt = _mamba_project(p, xn, md)
    xbc = jnp.concatenate([xr, Bm, Cm], axis=-1)              # (B,1,·)
    xbc, xp_conv = _causal_conv(xbc, p["conv_w"], conv)
    conv = _conv_state_at(xp_conv, conv_width)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xr = xbc[:, 0, :dil]
    Bm = xbc[:, 0, dil:dil + d_state].astype(jnp.float32)
    Cm = xbc[:, 0, dil + d_state:].astype(jnp.float32)
    dt = dt[:, 0]                                             # (B,H)
    a_log = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a_log[None])                         # (B,H)
    xh = xr.reshape(b, hl, headdim).astype(jnp.float32)
    ssm = ssm * decay[..., None, None] + \
        jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm)
    y = jnp.einsum("bn,bhpn->bhp", Cm, ssm)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, dil).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = psum_tp(dense(y, p["w_out"]), dist)
    return x + out, flatten_mamba_state(ssm, conv, md)


def flatten_mamba_state(ssm, conv, md):
    b = ssm.shape[0]
    return jnp.concatenate([
        ssm.astype(jnp.float32).reshape(b, -1),
        conv.astype(jnp.float32).reshape(b, -1),
    ], axis=-1)


def split_mamba_state(flat, md, d_state, headdim, conv_width):
    b = flat.shape[0]
    hl, dil = md["h_local"], md["d_in_local"]
    n_ssm = md["ssm_units"]
    ssm = flat[:, :n_ssm].reshape(b, hl, headdim, d_state).astype(jnp.float32)
    conv = flat[:, n_ssm:].reshape(b, conv_width - 1, dil + 2 * d_state)
    return ssm, conv.astype(jnp.bfloat16)


# ====================================================================== RWKV6
def rwkv6_dims(d_model: int, head_size: int, tp: int):
    heads = d_model // head_size
    heads_pad = -(-heads // tp) * tp
    h_local = heads_pad // tp
    d_att_local = h_local * head_size
    wkv_units = h_local * head_size * head_size
    shift_units = 2 * d_model   # att shift + channel-mix shift (replicated)
    return dict(heads=heads, heads_pad=heads_pad, h_local=h_local,
                d_att_local=d_att_local, wkv_units=wkv_units,
                shift_units=shift_units)


def _rwkv_mix(x, x_prev, mu):
    """Token-shift lerp. x,x_prev: (B,T,d); mu: (d,)."""
    return x + (x_prev - x) * mu.astype(x.dtype)


def _rwkv_proj(p, x, x_prev, rd, head_size: int):
    """Time-mix projections. Returns r,k,v,g (B,T,H,hs), logw (B,T,H,hs)<=0."""
    b, t, _ = x.shape
    hl = rd["h_local"]
    r = dense(_rwkv_mix(x, x_prev, p["mu_r"]), p["w_r"]).reshape(b, t, hl, head_size)
    k = dense(_rwkv_mix(x, x_prev, p["mu_k"]), p["w_k"]).reshape(b, t, hl, head_size)
    v = dense(_rwkv_mix(x, x_prev, p["mu_v"]), p["w_v"]).reshape(b, t, hl, head_size)
    g = dense(_rwkv_mix(x, x_prev, p["mu_g"]), p["w_g"]).reshape(b, t, hl, head_size)
    # data-dependent decay (the Finch feature): low-rank lora on w
    xw = _rwkv_mix(x, x_prev, p["mu_w"])
    ww = jnp.tanh(dense(xw, p["w_lora_a"]).astype(jnp.float32))
    ww = jnp.einsum("btr,rd->btd", ww, p["w_lora_b"].astype(jnp.float32))
    ww = ww + p["w_base"].astype(jnp.float32)                 # (B,T,d_att_local)
    logw = -jnp.exp(ww).reshape(b, t, hl, head_size)          # <= 0
    return r, k, v, g, logw


def rwkv6_chunked(p, x, dist: Dist, rd: dict, *, head_size: int,
                  chunk: int = 64, norm_eps=1e-5, init_state=None,
                  length_mask=None, last_idx=None):
    """RWKV6 time-mix + channel-mix over a sequence. Returns (y, state).

    Ragged mixed batches: padded tokens get k=0 (no state contribution) and
    logw=0 (no decay), so the final wkv state is exactly the state after
    each row's last real token; the token-shift carries are gathered at
    ``last_idx`` instead of the trailing (possibly padded) slot."""
    b, t, d = x.shape
    hl = rd["h_local"]
    if init_state is not None:
        S0, att_shift, cm_shift = split_rwkv_state(init_state, rd, head_size, d)
    else:
        S0 = jnp.zeros((b, hl, head_size, head_size), jnp.float32)
        att_shift = jnp.zeros((b, 1, d), x.dtype)
        cm_shift = jnp.zeros((b, 1, d), x.dtype)

    # ---- time mix
    xn = rms_norm(x, p["ln1"], norm_eps)
    x_prev = jnp.concatenate([att_shift, xn[:, :-1]], axis=1)
    r, k, v, g, logw = _rwkv_proj(p, xn, x_prev, rd, head_size)
    if length_mask is not None:
        valid = length_mask[:, :, None, None]
        k = jnp.where(valid, k, 0.0)
        logw = jnp.where(valid, logw, 0.0)
    u = p["u"].astype(jnp.float32)                            # (H_local, hs)

    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    def padt(a, val=0.0):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                       constant_values=val)
    rc = padt(r).reshape(b, nchunk, chunk, hl, head_size)
    kc = padt(k).reshape(b, nchunk, chunk, hl, head_size)
    vc = padt(v).reshape(b, nchunk, chunk, hl, head_size)
    wc = padt(logw).reshape(b, nchunk, chunk, hl, head_size)

    def chunk_step(S, inp):
        rk, kk, vk, lw = (a.astype(jnp.float32) for a in inp)  # (B,L,H,hs)
        L = jnp.cumsum(lw, axis=1)                             # inclusive
        Lprev = L - lw                                         # exclusive
        # intra: score_ts = sum_c r_tc k_sc exp(Lprev_t - L_s), s < t
        diff = Lprev[:, :, None] - L[:, None]                  # (B,t,s,H,hs)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        dec = jnp.exp(jnp.minimum(
            jnp.where(mask[None, :, :, None, None], diff, -jnp.inf), 0.0))
        score = jnp.einsum("bthc,btshc,bshc->bhts", rk, dec, kk)
        # diagonal bonus term
        diag = jnp.einsum("bthc,hc,bthc->bth", rk, u, kk)
        y = jnp.einsum("bhts,bshc->bthc", score, vk)
        y += diag[..., None] * vk
        # inter: carry state
        rdec = rk * jnp.exp(Lprev)
        y += jnp.einsum("bthk,bhkv->bthv", rdec, S)
        # state update
        kdec = kk * jnp.exp(L[:, -1][:, None] - L)
        S = S * jnp.exp(L[:, -1])[..., None] + \
            jnp.einsum("bshk,bshv->bhkv", kdec, vk)
        return S, y

    S_fin, ys = jax.lax.scan(
        chunk_step, S0,
        (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
         jnp.moveaxis(vc, 1, 0), jnp.moveaxis(wc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nchunk * chunk, hl, head_size)[:, :t]
    y = _rwkv_out(p, y, g, dist, b, t, norm_eps)
    x = x + y

    # ---- channel mix
    xc = rms_norm(x, p["ln2"], norm_eps)
    xc_prev = jnp.concatenate([cm_shift, xc[:, :-1]], axis=1)
    cm = _channel_mix(p, xc, xc_prev, dist)
    x = x + cm
    if last_idx is None:
        att_out, cm_out = xn[:, -1:], xc[:, -1:]
    else:
        gather = lambda a: jnp.take_along_axis(
            a, last_idx[:, None, None].astype(jnp.int32), axis=1)
        att_out, cm_out = gather(xn), gather(xc)
    state = flatten_rwkv_state(S_fin, att_out, cm_out, rd)
    return x, state


def rwkv6_packed(p, x, dist: Dist, rd: dict, *, head_size: int,
                 seg_ids, seg_start, seg_last, init_state,
                 chunk: int = 64, norm_eps=1e-5):
    """RWKV6 over a PACKED token stream (see ``mamba2_packed`` for the
    layout contract). The wkv chunked scan carries one state per SEGMENT
    with segment-equality masking on the intra-chunk scores; token-shift
    lerps read each segment's carried shift state at its first stream slot
    instead of the previous segment's last token. Returns
    (y (1,TT,d), final_states (S, units))."""
    b, t, d = x.shape
    assert b == 1, "packed streams are single-row"
    nseg = init_state.shape[0]
    hl = rd["h_local"]
    S0, att_shift, cm_shift = split_rwkv_state(init_state, rd, head_size, d)
    valid = (seg_ids >= 0)

    # ---- time mix
    xn = rms_norm(x, p["ln1"], norm_eps)
    x_prev = _packed_shift(xn[0], att_shift, seg_ids, seg_start)
    r, k, v, g, logw = _rwkv_proj(p, xn, x_prev, rd, head_size)
    vmask = valid[None, :, None, None]
    k = jnp.where(vmask, k, 0.0)          # pads: no state contribution
    logw = jnp.where(vmask, logw, 0.0)    # pads: no decay
    u = p["u"].astype(jnp.float32)                            # (H, hs)

    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    padt = lambda a: jnp.pad(a[0], ((0, pad),) + ((0, 0),) * (a.ndim - 2))
    rc = padt(r).reshape(nchunk, chunk, hl, head_size)
    kc = padt(k).reshape(nchunk, chunk, hl, head_size)
    vc = padt(v).reshape(nchunk, chunk, hl, head_size)
    wc = padt(logw).reshape(nchunk, chunk, hl, head_size)
    segc = jnp.pad(seg_ids, (0, pad), constant_values=-1).reshape(
        nchunk, chunk)

    def chunk_step(S_seg, inp):
        rk, kk, vk, lw, sk = inp
        rk, kk, vk, lw = (a.astype(jnp.float32) for a in (rk, kk, vk, lw))
        oneh = (sk[:, None] == jnp.arange(nseg)[None]).astype(jnp.float32)
        L = jnp.cumsum(lw, axis=0)                             # (L,H,hs)
        Lprev = L - lw
        same = (sk[:, None] == sk[None, :]) & (sk >= 0)[:, None]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1) & same
        diff = Lprev[:, None] - L[None]                        # (t,s,H,hs)
        dec = jnp.exp(jnp.minimum(
            jnp.where(mask[..., None, None], diff, -jnp.inf), 0.0))
        score = jnp.einsum("thc,tshc,shc->hts", rk, dec, kk)
        diag = jnp.einsum("thc,hc,thc->th", rk, u, kk)
        y = jnp.einsum("hts,shc->thc", score, vk)
        y += diag[..., None] * vk
        # inter-chunk: read the token's segment state, decayed since the
        # segment's first in-chunk token (state reads exclude own w)
        big = jnp.where(oneh[..., None, None] > 0, Lprev[:, None], -jnp.inf)
        base = jnp.max(big, axis=0)                            # (S,H,hs)
        base = jnp.where(jnp.isfinite(base), base, 0.0)
        rdec = rk * jnp.exp(Lprev - base[jnp.maximum(sk, 0)])
        S_tok = S_seg[jnp.maximum(sk, 0)]                      # (L,H,hs,hs)
        y += jnp.einsum("thk,thkv->thv", rdec, S_tok)
        # per-segment state update
        seg_sum = jnp.einsum("ls,lhc->shc", oneh, lw)          # (S,H,hs)
        segend = jnp.min(jnp.where(oneh[..., None, None] > 0, L[:, None],
                                   jnp.inf), axis=0)           # (S,H,hs)
        segend = jnp.where(jnp.isfinite(segend), segend, 0.0)
        kdec = kk * jnp.exp(segend[jnp.maximum(sk, 0)] - L)
        S_add = jnp.einsum("ls,lhk,lhv->shkv", oneh, kdec, vk)
        S_new = S_seg * jnp.exp(seg_sum)[..., None] + S_add
        return S_new, y

    S_fin, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc, segc))
    y = ys.reshape(nchunk * chunk, hl, head_size)[:t][None]
    y = _rwkv_out(p, y, g, dist, 1, t, norm_eps)
    x = x + y

    # ---- channel mix
    xc = rms_norm(x, p["ln2"], norm_eps)
    xc_prev = _packed_shift(xc[0], cm_shift, seg_ids, seg_start)
    cm = _channel_mix(p, xc, xc_prev, dist)
    x = x + cm
    last = jnp.clip(seg_last, 0, t - 1)
    att_out = xn[0][last][:, None]                             # (S, 1, d)
    cm_out = xc[0][last][:, None]
    state = flatten_rwkv_state(S_fin, att_out, cm_out, rd)
    return x, state


def rwkv6_step(p, x, state_flat, dist: Dist, rd: dict, *, head_size: int,
               norm_eps=1e-5):
    """Single-token decode. x: (B,1,d)."""
    b, _, d = x.shape
    hl = rd["h_local"]
    S, att_shift, cm_shift = split_rwkv_state(state_flat, rd, head_size, d)
    xn = rms_norm(x, p["ln1"], norm_eps)
    r, k, v, g, logw = _rwkv_proj(p, xn, att_shift, rd, head_size)
    rk = r[:, 0].astype(jnp.float32)
    kk = k[:, 0].astype(jnp.float32)
    vk = v[:, 0].astype(jnp.float32)
    w = jnp.exp(logw[:, 0])                                    # (B,H,hs)
    u = p["u"].astype(jnp.float32)
    wkv = S + u[None, :, :, None] * jnp.einsum("bhk,bhv->bhkv", kk, vk)
    y = jnp.einsum("bhk,bhkv->bhv", rk, wkv)[:, None]          # (B,1,H,hs)
    S = S * w[..., None] + jnp.einsum("bhk,bhv->bhkv", kk, vk)
    y = _rwkv_out(p, y.reshape(b, 1, hl, head_size), g, dist, b, 1, norm_eps)
    x = x + y
    xc = rms_norm(x, p["ln2"], norm_eps)
    cm = _channel_mix(p, xc, cm_shift, dist)
    x = x + cm
    return x, flatten_rwkv_state(S, xn[:, -1:], xc[:, -1:], rd)


def _rwkv_out(p, y, g, dist, b, t, norm_eps):
    hl, hs = y.shape[2], y.shape[3]
    y = y.reshape(b, t, hl * hs).astype(jnp.bfloat16)
    y = rms_norm(y, p["ln_x"], norm_eps)
    y = y * jax.nn.silu(g.reshape(b, t, -1).astype(jnp.float32)).astype(y.dtype)
    return psum_tp(dense(y, p["w_o"]), dist)


def _channel_mix(p, xc, xc_prev, dist: Dist):
    """Output-column-sharded channel mix; all-gather to replicate."""
    xk = _rwkv_mix(xc, xc_prev, p["cm_mu_k"])
    xr = _rwkv_mix(xc, xc_prev, p["cm_mu_r"])
    k = dense(xk, p["cm_wk"])                                  # (B,T,ff_local)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(xc.dtype)
    vloc = dense(k, p["cm_wv"])                                # (B,T,d_local)
    rloc = jax.nn.sigmoid(dense(xr, p["cm_wr"]).astype(jnp.float32))
    out_loc = (vloc.astype(jnp.float32) * rloc).astype(xc.dtype)
    return jax.lax.all_gather(out_loc, dist.tp_axis, axis=-1, tiled=True)


def flatten_rwkv_state(S, att_shift, cm_shift, rd):
    b = S.shape[0]
    return jnp.concatenate([
        S.astype(jnp.float32).reshape(b, -1),
        att_shift.astype(jnp.float32).reshape(b, -1),
        cm_shift.astype(jnp.float32).reshape(b, -1),
    ], axis=-1)


def split_rwkv_state(flat, rd, head_size, d):
    b = flat.shape[0]
    hl = rd["h_local"]
    n = rd["wkv_units"]
    S = flat[:, :n].reshape(b, hl, head_size, head_size).astype(jnp.float32)
    att = flat[:, n:n + d].reshape(b, 1, d).astype(jnp.bfloat16)
    cm = flat[:, n + d:n + 2 * d].reshape(b, 1, d).astype(jnp.bfloat16)
    return S, att, cm
