"""Manual tensor-parallel primitives used inside shard_map.

Design (DESIGN.md §5): the whole model step runs in one shard_map over the
mesh; params are stored in an *expanded layout* with a leading ``tp`` dim so
that a plain ``P("model", ...)`` in_spec hands every device exactly its
Megatron slice — including GQA KV-head *replication* groups, which plain
PartitionSpecs cannot express.

GQA layout (``gqa_tp_layout``): with ``kv_tp = gcd(kv_heads, tp)`` real KV
shards and ``repl = tp // kv_tp`` replicas, device ``m`` owns KV heads
``[kg*kv_local, (kg+1)*kv_local)`` where ``kg = m // repl``, and the q heads
of those groups are split across the ``repl`` replicas (padded to equal
size). Padded q heads have zero projection rows; their attention output is
annihilated by zero o-proj rows.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .common import PARAM_DTYPE, gqa_tp_layout


@dataclasses.dataclass(frozen=True)
class Dist:
    """Mesh + axis-role bookkeeping passed through all model code."""

    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    sp: bool = False                  # sequence-parallel decode (long-context)
    fsdp: bool = False                # shard layer weights over "data" (train)

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.dp_axes) + (self.tp_axis,)

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


# ------------------------------------------------------- version compat
# The repo targets recent JAX (jax.shard_map / AxisType / check_vma) but must
# run on older releases where these live under jax.experimental (shard_map
# with check_rep) and meshes carry no axis_types. Feature-detect once here;
# every model file imports `shard_map` / `make_mesh_auto` from this module.
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh_auto(shape, names, devices=None):
    if _HAS_AXIS_TYPE:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, names,
                             axis_types=(AxisType.Auto,) * len(names),
                             devices=devices)
    return jax.make_mesh(shape, names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map on new JAX; jax.experimental.shard_map fallback (where
    the kwarg disabling replication checking is spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def single_device_dist() -> Dist:
    mesh = make_mesh_auto((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    return Dist(mesh=mesh)


# --------------------------------------------------------------- param layout
def expand_rows(key, shape_per_shard, tp: int, init, **kw):
    """Init an expanded param (tp, *shape_per_shard): independent shards."""
    keys = jax.random.split(key, tp)
    return jnp.stack([init(k, shape_per_shard, **kw) for k in keys])


def expand_gqa_q(key, d_model: int, num_heads: int, num_kv_heads: int,
                 head_dim: int, tp: int, scale=0.02):
    """Q-projection in padded GQA layout: (tp, d_model, q_local*head_dim).

    Real heads get normal init; padded slots are zero."""
    q_pad, q_local, kv_tp, kv_local = gqa_tp_layout(num_heads, num_kv_heads, tp)
    repl = tp // kv_tp
    group = num_heads // num_kv_heads
    group_pad = q_pad // num_kv_heads
    gpp = group_pad // repl
    w = scale * jax.random.normal(
        key, (num_kv_heads, group_pad, d_model, head_dim))
    # zero the padded group slots
    mask = (jnp.arange(group_pad) < group)[None, :, None, None]
    w = jnp.where(mask, w, 0.0)
    # device m = (kg, r): heads = w[kg*kv_local:(kg+1)*kv_local, r*gpp:(r+1)*gpp]
    w = w.reshape(kv_tp, kv_local, repl, gpp, d_model, head_dim)
    w = jnp.transpose(w, (0, 2, 1, 3, 4, 5))       # (kv_tp, repl, kv_local, gpp, d, hd)
    w = w.reshape(tp, kv_local * gpp, d_model, head_dim)
    w = jnp.transpose(w, (0, 2, 1, 3))             # (tp, d, q_local, hd)
    return w.astype(PARAM_DTYPE).reshape(tp, d_model, q_local * head_dim)


def expand_gqa_o(key, d_model: int, num_heads: int, num_kv_heads: int,
                 head_dim: int, tp: int, scale=0.02):
    """O-projection transpose-layout: (tp, q_local*head_dim, d_model)."""
    q_pad, q_local, kv_tp, kv_local = gqa_tp_layout(num_heads, num_kv_heads, tp)
    repl = tp // kv_tp
    group = num_heads // num_kv_heads
    group_pad = q_pad // num_kv_heads
    gpp = group_pad // repl
    w = scale * jax.random.normal(
        key, (num_kv_heads, group_pad, head_dim, d_model))
    mask = (jnp.arange(group_pad) < group)[None, :, None, None]
    w = jnp.where(mask, w, 0.0)
    w = w.reshape(kv_tp, kv_local, repl, gpp, head_dim, d_model)
    w = jnp.transpose(w, (0, 2, 1, 3, 4, 5))
    return w.reshape(tp, q_local * head_dim, d_model).astype(PARAM_DTYPE)


def expand_gqa_kv(key, d_model: int, num_kv_heads: int, head_dim: int,
                  tp: int, scale=0.02):
    """K or V projection with replication: (tp, d_model, kv_local*head_dim).
    Replicas share identical weights (same KV content on each replica)."""
    _, _, kv_tp, kv_local = gqa_tp_layout(1 * num_kv_heads, num_kv_heads, tp)
    repl = tp // kv_tp
    w = scale * jax.random.normal(key, (kv_tp, d_model, kv_local * head_dim))
    w = jnp.broadcast_to(w[:, None], (kv_tp, repl, d_model, kv_local * head_dim))
    return w.reshape(tp, d_model, kv_local * head_dim).astype(PARAM_DTYPE)


def expand_replicated(key, shape, tp: int, scale=0.02):
    """Expanded param whose content is identical on every shard (e.g. Mamba
    B/C projections shared by all head groups)."""
    w = scale * jax.random.normal(key, shape)
    return jnp.broadcast_to(w[None], (tp,) + tuple(shape)).astype(PARAM_DTYPE)


# ------------------------------------------------------- inside-shard_map ops
def psum_tp(x, dist: Dist):
    return jax.lax.psum(x, dist.tp_axis)


def psum_dp(x, dist: Dist):
    return jax.lax.psum(x, dist.dp_axes)


def embed_lookup(tokens, table_local, dist: Dist):
    """Vocab-sharded embedding lookup (inside shard_map).

    tokens: (..., ) int32; table_local: (V_local, d). Returns (..., d)."""
    v_local = table_local.shape[0]
    shard = jax.lax.axis_index(dist.tp_axis)
    lo = shard * v_local
    idx = tokens - lo
    ok = (idx >= 0) & (idx < v_local)
    idx = jnp.clip(idx, 0, v_local - 1)
    out = jnp.take(table_local, idx, axis=0).astype(jnp.bfloat16)
    out = jnp.where(ok[..., None], out, 0)
    return psum_tp(out, dist)


def logits_local(x, table_local):
    """x: (..., d) -> vocab-sharded logits (..., V_local), fp32."""
    return jnp.einsum("...d,vd->...v", x, table_local.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def sharded_softmax_xent(logits_loc, targets, dist: Dist, mask=None):
    """Cross-entropy over vocab-sharded fp32 logits (..., V_local)."""
    v_local = logits_loc.shape[-1]
    shard = jax.lax.axis_index(dist.tp_axis)
    lo = shard * v_local
    # global max via all_gather (differentiable, unlike pmax); the shift is
    # stop_gradient'd — it cancels in d/dx logsumexp anyway.
    lmax = jnp.max(logits_loc, axis=-1)
    gmax = jnp.max(
        jax.lax.all_gather(jax.lax.stop_gradient(lmax), dist.tp_axis), axis=0)
    z = jnp.sum(jnp.exp(logits_loc - gmax[..., None]), axis=-1)
    z = psum_tp(z, dist)
    logz = jnp.log(z) + gmax
    idx = targets - lo
    ok = (idx >= 0) & (idx < v_local)
    idx = jnp.clip(idx, 0, v_local - 1)
    gold = jnp.take_along_axis(logits_loc, idx[..., None], axis=-1)[..., 0]
    gold = psum_tp(jnp.where(ok, gold, 0.0), dist)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def mask_pad_vocab(logits_loc, vocab_size: int, dist: Dist):
    """Mask pad-vocab columns (global id >= vocab_size) to -1e30.

    The unembed table is padded to V_local * tp rows whose logits are
    garbage (random init); the serve heads mask them here so BOTH the
    device sampler and the host sampler can operate on full v_pad rows
    identically — exp(-1e30) underflows to exactly 0 in a softmax and
    pads sort last under top-k, so no caller ever needs to slice
    [:vocab_size] again. Keep in sync with serving.sampler.NEG."""
    v_local = logits_loc.shape[-1]
    shard = jax.lax.axis_index(dist.tp_axis)
    gid = shard * v_local + jnp.arange(v_local)
    return jnp.where(gid < vocab_size, logits_loc, -1e30)


def gather_logits(logits_loc, dist: Dist):
    """(..., V_local) -> (..., V) via all-gather over the tp axis."""
    g = jax.lax.all_gather(logits_loc, dist.tp_axis, axis=-1, tiled=True)
    return g


def replica_info(num_heads: int, num_kv_heads: int, tp: int):
    q_pad, q_local, kv_tp, kv_local = gqa_tp_layout(num_heads, num_kv_heads, tp)
    repl = tp // kv_tp
    return dict(q_pad=q_pad, q_local=q_local, kv_tp=kv_tp,
                kv_local=kv_local, repl=repl)
