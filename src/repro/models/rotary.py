"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 1e6):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    inv = rope_freqs(x.shape[-1], theta)                     # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv     # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]                         # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def mrope_positions(positions, mrope_sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: 3 position streams (temporal, h, w).

    For the text-only / precomputed-embedding backbone the three streams
    coincide for text tokens; vision tokens would carry distinct (t,h,w).
    The stub frontend supplies a (3, seq) position array; for plain text we
    broadcast 1D positions to all three streams.
    """
    if positions.ndim == 2:  # (batch, seq) text-only
        return jnp.stack([positions] * 3, axis=0)
    return positions  # already (3, batch, seq)


def default_mrope_sections(head_dim: int):
    """Qwen2-VL proportions (16,24,24 for hd=128): 1/4 temporal, rest h/w."""
    half = head_dim // 2
    t = max(1, half // 4)
    h1 = (half - t) // 2
    return (t, h1, half - t - h1)


def apply_mrope(x, positions3, theta: float = 1e6, sections=None):
    """M-RoPE: the head_dim/2 frequency slots are split into ``sections``
    groups, each rotated by a different position stream.

    x: (batch, seq, heads, head_dim); positions3: (3, batch, seq).
    ``sections`` sums to head_dim//2 (Qwen2-VL: 16+24+24=64 for hd=128).
    """
    half = x.shape[-1] // 2
    if sections is None:
        sections = default_mrope_sections(x.shape[-1])
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(x.shape[-1], theta)                     # (half,)
    # per-frequency-slot stream selector
    sel = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                        # (half,)
    pos = positions3.astype(jnp.float32)                      # (3, B, S)
    # gather per-slot positions: (B, S, half)
    pos_slots = jnp.moveaxis(pos, 0, -1)[..., sel]            # (B, S, half)
    ang = pos_slots * inv                                    # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]                         # (B, S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int):
    """Whisper-style fixed sinusoidal embeddings."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
