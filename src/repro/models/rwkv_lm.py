"""RWKV6 ("Finch") language model: attention-free, data-dependent decay.

KV type: a single "rwkv" state spec (wkv matrix state + token-shift states
per layer). No token pages at all — the paper's 'state space' extreme."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..core.spec import KVCacheSpec, rwkv_spec
from . import attention as A
from . import blocks_seq as BS
from .common import rms_norm
from .lm import DecoderLM, DecodeBatch
from .params import PD
from .tp import (embed_lookup, expand_replicated, logits_local,
                 mask_pad_vocab, psum_dp, sharded_softmax_xent)

LORA_RANK = 32


class RWKVLM(DecoderLM):
    def __init__(self, cfg: ModelConfig, dist):
        self.cfg = cfg
        self.dist = dist
        tp = dist.tp
        self.v_local = -(-cfg.vocab_size // tp)
        self.v_pad = self.v_local * tp
        self.is_moe = False
        self.rd = BS.rwkv6_dims(cfg.d_model, cfg.rwkv_head_size, tp)
        self.ri = {"kv_local": 1}  # unused

    def kv_specs(self) -> Tuple[KVCacheSpec, ...]:
        cfg, rd = self.cfg, self.rd
        return (
            rwkv_spec("rwkv", num_layers=cfg.num_layers,
                      att_state_units=2 * rd["wkv_units"],
                      shift_state_units=2 * rd["shift_units"]),
        )

    def page_shapes(self) -> Dict[str, Tuple[int, ...]]:
        rd = self.rd
        return {"rwkv": (2 * (rd["wkv_units"] + rd["shift_units"]),)}

    def template(self):
        cfg, dist, rd = self.cfg, self.dist, self.rd
        tp = dist.tp
        d = cfg.d_model
        L = cfg.num_layers
        dal = rd["d_att_local"]
        hl, hs = rd["h_local"], cfg.rwkv_head_size
        assert cfg.d_ff % tp == 0 and d % tp == 0, (cfg.d_ff, d, tp)
        ffl = cfg.d_ff // tp
        dl = d // tp              # channel-mix output column shard
        sp = P(None, "model")

        def repl_stack(shape, scale=0.02):
            def fn(key):
                keys = jax.random.split(key, L)
                return jnp.stack(
                    [expand_replicated(k, shape, tp, scale) for k in keys])
            return fn

        layers = {
            "ln1": PD((L, d), P(), init="ones"),
            "ln2": PD((L, d), P(), init="ones"),
            "ln_x": PD((L, tp, dal), sp, init="ones"),
            # token-shift mixing coefficients (replicated)
            "mu_r": PD((L, d), P(), scale=0.5),
            "mu_k": PD((L, d), P(), scale=0.5),
            "mu_v": PD((L, d), P(), scale=0.5),
            "mu_g": PD((L, d), P(), scale=0.5),
            "mu_w": PD((L, d), P(), scale=0.5),
            "w_r": PD((L, tp, d, dal), sp),
            "w_k": PD((L, tp, d, dal), sp),
            "w_v": PD((L, tp, d, dal), sp),
            "w_g": PD((L, tp, d, dal), sp),
            "w_o": PD((L, tp, dal, d), sp, scale=0.02 / (2 * L) ** 0.5),
            # data-dependent decay lora (Finch): d -> rank -> d_att_local
            "w_lora_a": PD((L, tp, d, LORA_RANK), sp, init="custom",
                           fn=repl_stack((d, LORA_RANK))),
            "w_lora_b": PD((L, tp, LORA_RANK, dal), sp, scale=0.01),
            "w_base": PD((L, tp, dal), sp, init="custom",
                         fn=lambda key: jnp.broadcast_to(
                             jnp.full((dal,), 0.6), (L, tp, dal))),
            "u": PD((L, tp, hl, hs), sp, scale=0.5),
            # channel mix
            "cm_mu_k": PD((L, d), P(), scale=0.5),
            "cm_mu_r": PD((L, d), P(), scale=0.5),
            "cm_wk": PD((L, tp, d, ffl), sp),
            "cm_wv": PD((L, tp, ffl, dl), sp, scale=0.02 / (2 * L) ** 0.5),
            "cm_wr": PD((L, tp, d, dl), sp),
        }
        tmpl = {
            "embed": PD((tp, self.v_local, d), P("model")),
            "final_norm": PD((d,), P(), init="ones"),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            tmpl["unembed"] = PD((tp, self.v_local, d), P("model"))
        return tmpl

    # ------------------------------------------------------------------ run
    def _train_body(self, params, tokens, targets, *mm, has_mm=False):
        cfg, dist = self.cfg, self.dist
        params = self._squeeze_params(params)
        x = embed_lookup(tokens, params["embed"], dist)

        def body(x, pj):
            x, _ = BS.rwkv6_chunked(pj, x, dist, self.rd,
                                    head_size=cfg.rwkv_head_size,
                                    norm_eps=cfg.norm_eps)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = logits_local(x, self._unembed(params))
        loss = sharded_softmax_xent(logits, targets, dist)
        return psum_dp(loss, dist) / dist.dp

    def _serve_body(self, params, buffer, batch: DecodeBatch, *, prefill,
                    attention_impl="ref"):
        # attention_impl is accepted for serve_step signature parity but
        # unused: RWKV has no attention layers to dispatch
        cfg, dist = self.cfg, self.dist
        params = self._squeeze_params(params)
        buffer = buffer.reshape(buffer.shape[-1])
        x = embed_lookup(batch.tokens, params["embed"], dist)
        views = self._layer_views(buffer)
        state_eids = jnp.squeeze(batch.state_eids["rwkv"], axis=0)
        # ragged mixed batch: padded tokens must not enter the wkv state
        t = batch.tokens.shape[1]
        packed = batch.seg_ids is not None
        lidx = batch.last_idx
        lmask = (None if lidx is None else
                 jnp.arange(t)[None] <= lidx[:, None])
        seg_kw = {} if not packed else dict(
            seg_ids=batch.seg_ids[0], seg_start=batch.seg_start_tok[0],
            seg_last=batch.seg_last_tok)

        def body(carry, xs):
            x, buf = carry
            pj, layer = xs
            view = buf.reshape(views["rwkv"])
            st = A.read_state(view, layer, state_eids)
            if packed:
                x, st = BS.rwkv6_packed(pj, x, dist, self.rd,
                                        head_size=cfg.rwkv_head_size,
                                        norm_eps=cfg.norm_eps, init_state=st,
                                        **seg_kw)
            elif prefill:
                x, st = BS.rwkv6_chunked(pj, x, dist, self.rd,
                                         head_size=cfg.rwkv_head_size,
                                         norm_eps=cfg.norm_eps, init_state=st,
                                         length_mask=lmask, last_idx=lidx)
            else:
                x, st = BS.rwkv6_step(pj, x, st, dist, self.rd,
                                      head_size=cfg.rwkv_head_size,
                                      norm_eps=cfg.norm_eps)
            buf = A.write_state(buf, views["rwkv"], layer, state_eids, st)
            return (x, buf), None

        (x, buffer), _ = jax.lax.scan(
            body, (x, buffer),
            (params["layers"], jnp.arange(cfg.num_layers)))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if packed:
            x = jnp.take(x[0], batch.seg_last_tok, axis=0)[:, None]
        elif batch.last_idx is not None:
            x = jnp.take_along_axis(
                x, batch.last_idx[:, None, None].astype(jnp.int32), axis=1)
        else:
            x = x[:, -1:]
        logits = logits_local(x, self._unembed(params))[:, 0]
        logits = mask_pad_vocab(logits, cfg.vocab_size, dist)
        return logits, buffer.reshape(1, 1, -1)
