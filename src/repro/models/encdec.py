"""Whisper-style encoder-decoder backbone (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed log-mel frame embeddings (B, S_enc, d). The transformer backbone
is real: LayerNorm + GELU MLP + MHA, sinusoidal encoder positions, learned
decoder positions, causal decoder self-attention (paged at serve time) and
cross-attention over encoder KV (paged "cross_attn" type — the Llama-3.2-
Vision memory pattern of Jenga §3.2)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..core.spec import KVCacheSpec, attention_spec, cross_attention_spec
from . import attention as A
from .common import dense, layer_norm
from . import blocks_attn as BA
from .lm import DecoderLM, DecodeBatch, _dp_spec
from .params import PD
from .rotary import sinusoidal_positions
from .tp import (embed_lookup, expand_gqa_kv, expand_gqa_o, expand_gqa_q,
                 logits_local, mask_pad_vocab, psum_dp, psum_tp, replica_info,
                 shard_map, sharded_softmax_xent)

MAX_DEC_POS = 32768 + 8


class EncDecLM(DecoderLM):
    def __init__(self, cfg: ModelConfig, dist):
        self.cfg = cfg
        self.dist = dist
        tp = dist.tp
        self.ri = replica_info(cfg.num_heads, cfg.num_kv_heads, tp)
        self.v_local = -(-cfg.vocab_size // tp)
        self.v_pad = self.v_local * tp
        self.is_moe = False
        self.max_dec_pos = min(MAX_DEC_POS, 32768 + 8)

    def kv_specs(self) -> Tuple[KVCacheSpec, ...]:
        cfg = self.cfg
        return (
            attention_spec("full_attn", num_layers=cfg.num_layers,
                           kv_heads=self.ri["kv_local"], head_dim=cfg.head_dim,
                           tokens_per_page=cfg.tokens_per_page),
            cross_attention_spec("cross_attn", num_layers=cfg.num_layers,
                                 kv_heads=self.ri["kv_local"],
                                 head_dim=cfg.head_dim,
                                 tokens_per_page=cfg.tokens_per_page),
        )

    def page_shapes(self) -> Dict[str, Tuple[int, ...]]:
        cfg = self.cfg
        shp = (2, cfg.tokens_per_page, self.ri["kv_local"], cfg.head_dim)
        return {"full_attn": shp, "cross_attn": shp}

    # ----------------------------------------------------------- template
    def _attn_tmpl(self, n, with_kv=True):
        cfg, ri = self.cfg, self.ri
        tp = self.dist.tp
        d, hd = cfg.d_model, cfg.head_dim
        qfn = lambda k: expand_gqa_q(k, d, cfg.num_heads, cfg.num_kv_heads, hd, tp)
        kvfn = lambda k: expand_gqa_kv(k, d, cfg.num_kv_heads, hd, tp)
        ofn = lambda k: expand_gqa_o(k, d, cfg.num_heads, cfg.num_kv_heads, hd, tp)

        def stack(fn):
            def f(key):
                return jnp.stack([fn(k) for k in jax.random.split(key, n)])
            return f

        t = {
            "ln_w": PD((n, d), P(), init="ones"),
            "ln_b": PD((n, d), P(), init="zeros"),
            "q": PD((n, tp, d, ri["q_local"] * hd), P(None, "model"),
                    init="custom", fn=stack(qfn)),
            "q_bias": PD((n, tp, ri["q_local"] * hd), P(None, "model"),
                         init="zeros"),
            "o": PD((n, tp, ri["q_local"] * hd, d), P(None, "model"),
                    init="custom", fn=stack(ofn)),
            "o_bias": PD((n, d), P(), init="zeros"),
        }
        if with_kv:
            t["k"] = PD((n, tp, d, ri["kv_local"] * hd), P(None, "model"),
                        init="custom", fn=stack(kvfn))
            t["v"] = PD((n, tp, d, ri["kv_local"] * hd), P(None, "model"),
                        init="custom", fn=stack(kvfn))
            t["v_bias"] = PD((n, tp, ri["kv_local"] * hd), P(None, "model"),
                             init="zeros")
        return t

    def _mlp_tmpl(self, n):
        cfg = self.cfg
        tp = self.dist.tp
        d = cfg.d_model
        ffl = cfg.d_ff // tp
        return {
            "ln_w": PD((n, d), P(), init="ones"),
            "ln_b": PD((n, d), P(), init="zeros"),
            "w1": PD((n, tp, d, ffl), P(None, "model")),
            "b1": PD((n, tp, ffl), P(None, "model"), init="zeros"),
            "w2": PD((n, tp, ffl, d), P(None, "model"),
                     scale=0.02 / (2 * cfg.num_layers) ** 0.5),
            "b2": PD((n, d), P(), init="zeros"),
        }

    def template(self):
        cfg = self.cfg
        tp = self.dist.tp
        d = cfg.d_model
        Le, Ld = cfg.encoder_layers, cfg.num_layers
        tmpl = {
            "embed": PD((tp, self.v_local, d), P("model")),
            "dec_pos": PD((self.max_dec_pos, d), P(), scale=0.01),
            "enc": {"attn": self._attn_tmpl(Le), "mlp": self._mlp_tmpl(Le)},
            "enc_ln_post_w": PD((d,), P(), init="ones"),
            "enc_ln_post_b": PD((d,), P(), init="zeros"),
            "dec_self": self._attn_tmpl(Ld),
            "dec_cross": self._attn_tmpl(Ld),
            "dec_mlp": self._mlp_tmpl(Ld),
            "final_ln_w": PD((d,), P(), init="ones"),
            "final_ln_b": PD((d,), P(), init="zeros"),
        }
        return tmpl

    # ----------------------------------------------------------- building blocks
    def _mha(self, p, x, kv_src, *, causal, eps):
        """Plain MHA (train path / encoder): q from x, k/v from kv_src."""
        cfg, dist, ri = self.cfg, self.dist, self.ri
        b, t, d = x.shape
        xn = layer_norm(x, p["ln_w"], p["ln_b"], eps)
        kv_n = xn if kv_src is None else kv_src
        q = dense(xn, p["q"], p["q_bias"]).reshape(b, t, -1, cfg.head_dim)
        k = dense(kv_n, p["k"]).reshape(b, kv_n.shape[1], ri["kv_local"],
                                        cfg.head_dim)
        v = dense(kv_n, p["v"], p["v_bias"]).reshape(
            b, kv_n.shape[1], ri["kv_local"], cfg.head_dim)
        q = A.group_q(q, ri["kv_local"])
        out = A.flash_attention(q, k, v, causal=causal)
        out = out.reshape(b, t, -1)
        y = psum_tp(dense(out, p["o"]), self.dist)
        return x + y + p["o_bias"].astype(y.dtype)

    def _mlp(self, p, x, eps):
        xn = layer_norm(x, p["ln_w"], p["ln_b"], eps)
        h = dense(xn, p["w1"], p["b1"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        y = psum_tp(dense(h, p["w2"]), self.dist)
        return x + y + p["b2"].astype(y.dtype)

    def _encode(self, params, enc_embeds, eps):
        d = self.cfg.d_model
        x = enc_embeds.astype(jnp.bfloat16)
        x = x + sinusoidal_positions(x.shape[1], d).astype(x.dtype)[None]

        def body(x, pj):
            x = self._mha(pj["attn"], x, None, causal=False, eps=eps)
            x = self._mlp(pj["mlp"], x, eps)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
        return layer_norm(x, params["enc_ln_post_w"], params["enc_ln_post_b"],
                          eps)

    # ------------------------------------------------------------------ train
    def train_loss(self, params, tokens, targets, *, enc_embeds=None, **kw):
        dist = self.dist
        dp = _dp_spec(dist)
        fn = shard_map(
            self._train_body_ed, mesh=dist.mesh,
            in_specs=(self.specs(), P(dp), P(dp), P(dp)),
            out_specs=P(), check_vma=False)
        return fn(params, tokens, targets, enc_embeds)

    def _train_body_ed(self, params, tokens, targets, enc_embeds):
        cfg, dist = self.cfg, self.dist
        eps = cfg.norm_eps
        params = self._squeeze_params(params)
        enc_out = self._encode(params, enc_embeds, eps)
        b, t = tokens.shape
        x = embed_lookup(tokens, params["embed"], dist)
        x = x + params["dec_pos"][:t].astype(x.dtype)[None]

        def body(x, pj):
            ps, pc, pm = pj
            x = self._mha(ps, x, None, causal=True, eps=eps)
            x = self._mha(pc, x, enc_out, causal=False, eps=eps)
            x = self._mlp(pm, x, eps)
            return x, None

        x, _ = jax.lax.scan(
            jax.checkpoint(body), x,
            (params["dec_self"], params["dec_cross"], params["dec_mlp"]))
        x = layer_norm(x, params["final_ln_w"], params["final_ln_b"], eps)
        logits = logits_local(x, params["embed"])
        loss = sharded_softmax_xent(logits, targets, dist)
        return psum_dp(loss, dist) / dist.dp

    # ------------------------------------------------------------------ serve
    def _serve_body(self, params, buffer, batch: DecodeBatch, *, prefill,
                    attention_impl="ref"):
        cfg, dist, ri = self.cfg, self.dist, self.ri
        eps = cfg.norm_eps
        params = self._squeeze_params(params)
        buffer = buffer.reshape(buffer.shape[-1])
        views = self._layer_views(buffer)
        sq = lambda a: jnp.squeeze(a, axis=(0, 1))
        tables_sa = sq(batch.tables["full_attn"])
        page_pos_sa = sq(batch.page_pos["full_attn"])
        write_sa = sq(batch.write_eids["full_attn"])
        tables_ca = sq(batch.tables["cross_attn"])
        packed = batch.seg_ids is not None
        page_seg_sa = page_seg_ca = page_pos_ca = None
        if packed:
            page_seg_sa = sq(batch.page_seg["full_attn"])
            page_seg_ca = sq(batch.page_seg["cross_attn"])
            page_pos_ca = sq(batch.page_pos["cross_attn"])
        kv_groups = (None if ri["repl"] == 1 else
                     A.replica_groups(ri["kv_tp"], ri["repl"]))
        # kernel dispatch is packed + single-shard only (the Pallas call
        # returns normalized output; sharded partials keep the ref path)
        use_kernel = (attention_impl == "kernel" and packed
                      and kv_groups is None)

        if prefill and batch.enc_embeds is not None:
            # run encoder once; write per-layer cross KV pages
            enc_out = self._encode(params, batch.enc_embeds, eps)
            enc_write = sq(batch.enc_write_eids)

            def wr(buf, xs):
                pj, layer = xs
                vshape = views["cross_attn"]
                tpp = vshape[3]
                b_, s_, _ = enc_out.shape
                k = dense(enc_out, pj["k"]).reshape(
                    b_, s_, ri["kv_local"], cfg.head_dim)
                v = dense(enc_out, pj["v"], pj["v_bias"]).reshape(
                    b_, s_, ri["kv_local"], cfg.head_dim)
                slots = jnp.broadcast_to(
                    (jnp.arange(s_) % tpp)[None], (b_, s_))
                buf = A.write_token_kv(buf, vshape, layer, enc_write,
                                       slots, k, v)
                return buf, None

            buffer, _ = jax.lax.scan(
                wr, buffer,
                (params["dec_cross"], jnp.arange(cfg.num_layers)))

        tokens = batch.tokens
        b, t = tokens.shape
        positions = batch.positions
        x = embed_lookup(tokens, params["embed"], dist)
        pos_emb = jnp.take(params["dec_pos"],
                           jnp.clip(positions, 0, self.max_dec_pos - 1),
                           axis=0)
        x = x + pos_emb.astype(x.dtype)

        def body(carry, xs):
            x, buf = carry
            (ps, pc, pm), layer = xs
            # READ phase: gather self + cross pages before any write
            vshape = views["full_attn"]
            tpp = vshape[3]
            k_all, v_all, slot_pos, slot_seg = BA.attn_gather(
                buf, vshape, tables_sa, page_pos_sa, layer, page_seg_sa)
            if packed:
                kc, vc, slot_pos_ca, slot_seg_ca = BA.attn_gather(
                    buf, views["cross_attn"], tables_ca, page_pos_ca,
                    layer, page_seg_ca)
            else:
                cview = buf.reshape(views["cross_attn"])
                kc, vc = A.gather_pages(cview, tables_ca, layer)
            # --- causal self attention (paged, fresh KV merged from registers)
            xn = layer_norm(x, ps["ln_w"], ps["ln_b"], eps)
            q = dense(xn, ps["q"], ps["q_bias"]).reshape(b, t, -1, cfg.head_dim)
            k = dense(xn, ps["k"]).reshape(b, t, ri["kv_local"], cfg.head_dim)
            v = dense(xn, ps["v"], ps["v_bias"]).reshape(
                b, t, ri["kv_local"], cfg.head_dim)
            q = A.group_q(q, ri["kv_local"])
            s = k_all.shape[1]
            chunk_start = (batch.chunk_start if packed
                           else positions[:, :1])
            if use_kernel:
                out = BA.packed_kernel_attention(
                    q, k_all, v_all, slot_pos, slot_seg, k, v, positions,
                    batch.seg_ids, chunk_start)
                out = out.reshape(b, t, -1).astype(x.dtype)
            else:
                if prefill or packed:
                    from .blocks_attn import _prefill_flash
                    o, m, l = _prefill_flash(q, k_all, v_all, slot_pos,
                                             positions,
                                             chunk_start=chunk_start,
                                             window=0, q_seg=batch.seg_ids,
                                             kv_seg=slot_seg)
                else:
                    mask = slot_pos[:, None, :] < chunk_start[:, :, None]
                    o, m, l = A.attend_tokens(q, k_all, v_all, mask)
                if kv_groups is not None:
                    o, m, l = A.combine_partials(o, m, l, dist.tp_axis,
                                                 groups=kv_groups)
                # fresh intra-chunk part
                if packed:
                    mask_f = A.segment_mask(batch.seg_ids, positions,
                                            batch.seg_ids, positions)
                    of, mf, lf = A.attend_tokens(q, k, v, mask_f)
                elif t == 1:
                    mask_f = jnp.ones((b, 1, 1), bool)
                    of, mf, lf = A.attend_tokens(q, k, v, mask_f)
                elif t <= 256:
                    mask_f = positions[:, None, :] <= positions[:, :, None]
                    of, mf, lf = A.attend_tokens(q, k, v, mask_f)
                else:
                    of, mf, lf = A.flash_attention_partials(q, k, v,
                                                            causal=True)
                o, m, l = A.merge_partials(o, m, l, of, mf, lf)
                out = A.finalize_softmax(o, l).reshape(b, t, -1)
                out = out.astype(x.dtype)
            y = psum_tp(dense(out, ps["o"]), dist)
            x = x + y + ps["o_bias"].astype(y.dtype)
            # --- cross attention (pre-gathered encoder KV)
            xn = layer_norm(x, pc["ln_w"], pc["ln_b"], eps)
            q = dense(xn, pc["q"], pc["q_bias"]).reshape(b, t, -1, cfg.head_dim)
            q = A.group_q(q, ri["kv_local"])
            sc = kc.shape[1]
            if use_kernel:
                # kernel zeroes fully-masked rows (enc_lens == 0) exactly,
                # matching the explicit zero guard of the ref path below
                out = BA.packed_cross_attn_kernel(
                    q, kc, vc, slot_pos_ca, slot_seg_ca, batch.seg_ids,
                    batch.enc_lens)
                out = out.reshape(b, t, -1).astype(x.dtype)
            else:
                if packed:
                    # enc_lens is per TOKEN; slot_pos_ca carries each flat
                    # cross slot's encoder position, slot_seg_ca its segment
                    mask = (slot_seg_ca[:, None, :]
                            == batch.seg_ids[:, :, None]) \
                        & (slot_pos_ca[:, None, :]
                           < batch.enc_lens[:, :, None])
                else:
                    mask = jnp.broadcast_to(
                        (jnp.arange(sc)[None]
                         < batch.enc_lens[:, None])[:, None],
                        (b, t, sc))
                o, m, l = A.attend_tokens(q, kc, vc, mask)
                out = A.finalize_softmax(o, l).reshape(b, t, -1)
                out = out.astype(x.dtype)
                if packed:
                    # all-masked rows degenerate to a uniform average over
                    # the WHOLE flat slot stream (other segments' values);
                    # a padded row would average its own zeroed pages
                    # instead — zero no-encoder tokens explicitly so the
                    # layouts agree
                    out = out * (batch.enc_lens > 0)[..., None].astype(
                        out.dtype)
            y = psum_tp(dense(out, pc["o"]), dist)
            x = x + y + pc["o_bias"].astype(y.dtype)
            x = self._mlp(pm, x, eps)
            # WRITE phase: stream this step's self-attn KV
            buf = A.write_token_kv(buf, vshape, layer, write_sa,
                                   positions % tpp, k, v)
            return (x, buf), None

        (x, buffer), _ = jax.lax.scan(
            body, (x, buffer),
            ((params["dec_self"], params["dec_cross"], params["dec_mlp"]),
             jnp.arange(cfg.num_layers)))
        x = layer_norm(x, params["final_ln_w"], params["final_ln_b"], eps)
        if packed:
            x = jnp.take(x[0], batch.seg_last_tok, axis=0)[:, None]
        elif batch.last_idx is not None:
            x = jnp.take_along_axis(
                x, batch.last_idx[:, None, None].astype(jnp.int32), axis=1)
        else:
            x = x[:, -1:]
        logits = logits_local(x, params["embed"])[:, 0]
        logits = mask_pad_vocab(logits, cfg.vocab_size, dist)
        return logits, buffer.reshape(1, 1, -1)
