"""Transformer blocks (attention / MLP / MoE) — local code inside shard_map.

All functions take *local* param slices (leading tp dim already consumed by
shard_map's in_specs and squeezed by the caller) and replicated activations
(B, T, d); tensor-parallel reductions are explicit ``psum`` over the tp axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.kernel import flash_attention_varlen_tpu
from . import attention as A
from .common import dense, rms_norm
from .rotary import apply_mrope, apply_rope
from .tp import Dist, psum_tp

# Block-size caps for the segment-block-sparse packed attention schedule
# (MXU-friendly at scale; sparse_blocks scales them down for small streams).
Q_BLOCK = 128
KV_BLOCK = 512


def _pow2_floor(n: int) -> int:
    return 1 << (max(1, n).bit_length() - 1)


def sparse_blocks(t: int, s: int) -> tuple:
    """(q_block, kv_block) for the segment-block-sparse packed schedule.

    Page streams are segment-contiguous, so per-block segment-id intervals
    are tight and non-overlapping (q block, kv block) pairs can be
    skipped. Aim for ~4 query blocks and ~16 KV blocks so skipping has
    granularity to work with at serving sizes, clamped to the MXU-friendly
    maxima (128 x 512) at scale and TPU-tile minima (8 x 64) below.
    ``ModelRunner._attn_block_stats`` mirrors this sizing on the host for
    the StepMetrics work counters — keep the two in sync."""
    return (max(8, min(Q_BLOCK, _pow2_floor(t // 4))),
            max(64, min(KV_BLOCK, _pow2_floor(s // 16))))


# ---------------------------------------------------------------- attention
def qkv_proj(p, xn, *, kv_local: int, head_dim: int, positions,
             rope_theta: float, mrope_positions=None, use_rope=True):
    """Project + rope. Returns q (B,T,KVL,G,D), k, v (B,T,KVL,D)."""
    b, t, _ = xn.shape
    q = dense(xn, p["q"], p.get("q_bias"))
    k = dense(xn, p["k"], p.get("k_bias"))
    v = dense(xn, p["v"], p.get("v_bias"))
    q = q.reshape(b, t, -1, head_dim)
    k = k.reshape(b, t, kv_local, head_dim)
    v = v.reshape(b, t, kv_local, head_dim)
    if use_rope:
        if mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, rope_theta)
            k = apply_mrope(k, mrope_positions, rope_theta)
        else:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
    q = A.group_q(q, kv_local)
    return q, k, v


def attn_train(p, x, dist: Dist, *, kv_local, head_dim, window=0,
               rope_theta=1e6, positions=None, mrope_positions=None,
               causal=True, norm_eps=1e-5, q_block=1024):
    """Full/SWA self-attention for training (no cache)."""
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q, k, v = qkv_proj(p, xn, kv_local=kv_local, head_dim=head_dim,
                       positions=positions, rope_theta=rope_theta,
                       mrope_positions=mrope_positions)

    # outer scan over q chunks keeps the score tensor bounded
    nq = -(-t // q_block)
    pad = nq * q_block - t
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0))) if pad else q

    def qchunk(carry, inp):
        qc, off = inp
        out = A.flash_attention(qc, k, v, causal=causal, window=window,
                                q_offset=off)
        return carry, out

    qblocks = qp.reshape(b, nq, q_block, *q.shape[2:])
    offs = jnp.arange(nq) * q_block
    _, outs = jax.lax.scan(qchunk, None, (jnp.moveaxis(qblocks, 1, 0), offs))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_block, -1)
    out = out[:, :t]
    y = dense(out, p["o"])
    return x + psum_tp(y, dist)


def attn_gather(buf, view_shape, tables, page_pos, layer, page_seg=None):
    """Phase 1 (READ): gather this layer's old pages + absolute positions.
    Must run before any buffer write in the same scan iteration (in-place
    aliasing: see EXPERIMENTS.md 'buffer-copy' study).

    page_seg: (B, P) owning-segment id per page for PACKED layouts (all
    segments' pages share one flat table row); None for the padded
    row-per-sequence layout. Returns (k, v, slot_pos, slot_seg) with
    slot_seg None when page_seg is None."""
    view = buf.reshape(view_shape)
    k_all, v_all = A.gather_pages(view, tables, layer)
    b, p = tables.shape
    tpp = view_shape[3]
    s = k_all.shape[1]
    slot_pos = (page_pos[:, :, None] + jnp.arange(tpp)[None, None, :]
                ).reshape(b, s)
    slot_seg = None
    if page_seg is not None:
        slot_seg = jnp.broadcast_to(page_seg[:, :, None],
                                    (b, p, tpp)).reshape(b, s)
    return k_all, v_all, slot_pos, slot_seg


def attn_compute(p, x, gathered, dist: Dist, *, kv_local, head_dim,
                 positions, seq_lens, window=0, rope_theta=1e6,
                 mrope_positions=None, norm_eps=1e-5, prefill=False,
                 sp_axis: Optional[str] = None, kv_groups=None,
                 seg_ids=None, chunk_start=None, impl="ref"):
    """Phase 2 (COMPUTE): attention over gathered old pages + this step's
    fresh K/V (still in registers — the buffer write happens in phase 3).

    Old-page masking uses ``slot_pos < chunk_start`` (strictly before the
    chunk start): the chunk's own slots are not yet written. The fresh part
    is intra-chunk causal attention merged via partial-softmax, after the
    old part was combined across KV-replica groups / SP shards (the fresh
    part is replicated on all shards, so it merges locally exactly once).

    PACKED layout: ``seg_ids`` (B, T) carries per-token segment ids and
    ``chunk_start`` (B, T) each token's chunk-start position (several
    sequences share one stream row); both masks then additionally require
    segment equality, using the slot_seg returned by ``attn_gather``.

    impl="kernel" dispatches the packed layout through the Pallas varlen
    flash kernel (one block-sparse call over old++fresh KV, interpret
    mode off-TPU) instead of the jnp reference. Falls back to ref for
    non-packed layouts and for kv_groups/sp_axis sharding (the kernel
    returns normalized output, so cross-shard partial combining doesn't
    apply). Returns (x_out, k_fresh, v_fresh)."""
    k_all, v_all, slot_pos, slot_seg = gathered
    b, t, _ = x.shape
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q, k, v = qkv_proj(p, xn, kv_local=kv_local, head_dim=head_dim,
                       positions=positions, rope_theta=rope_theta,
                       mrope_positions=mrope_positions)
    packed = seg_ids is not None
    if chunk_start is None:
        chunk_start = positions[:, :1]                         # (B, 1)
    if (packed and impl == "kernel" and kv_groups is None
            and sp_axis is None):
        out = packed_kernel_attention(
            q, k_all, v_all, slot_pos, slot_seg, k, v, positions, seg_ids,
            chunk_start, window=window)
        out = out.reshape(b, t, -1).astype(x.dtype)
        y = dense(out, p["o"])
        return x + psum_tp(y, dist), k, v
    if prefill or packed:
        o, m, l = _prefill_flash(q, k_all, v_all, slot_pos, positions,
                                 chunk_start=chunk_start, window=window,
                                 q_seg=seg_ids, kv_seg=slot_seg)
    else:
        mask = slot_pos[:, None, :] < chunk_start[:, :, None]  # strict
        if window:
            mask &= slot_pos[:, None, :] > positions[:, :, None] - window
        o, m, l = A.attend_tokens(q, k_all, v_all, mask)
    if kv_groups is not None:
        o, m, l = A.combine_partials(o, m, l, dist.tp_axis, groups=kv_groups)
    if sp_axis is not None:
        o, m, l = A.combine_partials(o, m, l, sp_axis)
    # fresh (intra-chunk) part: causal within the chunk (and within the
    # token's own segment, for packed streams)
    if packed:
        mask_f = A.segment_mask(seg_ids, positions, seg_ids, positions,
                                window=window)
        of, mf, lf = A.attend_tokens(q, k, v, mask_f)
    elif t == 1:
        mask_f = jnp.ones((b, 1, 1), bool)
        of, mf, lf = A.attend_tokens(q, k, v, mask_f)
    elif t <= 256:
        mask_f = positions[:, None, :] <= positions[:, :, None]
        if window:
            mask_f &= positions[:, None, :] > positions[:, :, None] - window
        of, mf, lf = A.attend_tokens(q, k, v, mask_f)
    else:
        of, mf, lf = A.flash_attention_partials(
            q, k, v, causal=True, window=window)
    o, m, l = A.merge_partials(o, m, l, of, mf, lf)
    out = A.finalize_softmax(o, l).reshape(b, t, -1).astype(x.dtype)
    y = dense(out, p["o"])
    return x + psum_tp(y, dist), k, v


def attn_write(buf, view_shape, layer, write_eids, positions, k, v):
    """Phase 3 (WRITE): stream this step's K/V into its pages."""
    tpp = view_shape[3]
    return A.write_token_kv(buf, view_shape, layer, write_eids,
                            positions % tpp, k, v)


def attn_cached(p, x, buf, view_shape, dist: Dist, *, layer, kv_local,
                head_dim, tables, page_pos, write_eids, positions, seq_lens,
                window=0, rope_theta=1e6, mrope_positions=None,
                norm_eps=1e-5, prefill=False, sp_axis: Optional[str] = None,
                kv_groups=None):
    """Convenience gather->compute->write for one attention layer per scan
    iteration. Models with several attention layers per iteration must call
    the phases separately (all gathers before any write)."""
    gathered = attn_gather(buf, view_shape, tables, page_pos, layer)
    x, k, v = attn_compute(
        p, x, gathered, dist, kv_local=kv_local, head_dim=head_dim,
        positions=positions, seq_lens=seq_lens, window=window,
        rope_theta=rope_theta, mrope_positions=mrope_positions,
        norm_eps=norm_eps, prefill=prefill, sp_axis=sp_axis,
        kv_groups=kv_groups)
    buf = attn_write(buf, view_shape, layer, write_eids, positions, k, v)
    return x, buf


def _prefill_flash(q, k, v, slot_pos, q_pos, *, window, chunk_start=None,
                   block=512, q_seg=None, kv_seg=None):
    """Flash attention over OLD pages for a prefill chunk.
    Returns un-normalized partials (acc, m, l) for cross-shard combining.

    chunk_start: (B,1) per row — or (B,T) per token for PACKED streams —
    old slots are valid iff slot_pos < chunk_start (the chunk itself
    attends via the fresh-KV path). q_seg (B,T) / kv_seg (B,S): packed
    segment ids; when given, the mask additionally requires
    kv_seg == q_seg so no token reads another sequence's pages.
    q: (B,T,KVL,G,D); k/v: (B,S,KVL,D); slot_pos: (B,S); q_pos: (B,T).

    PACKED streams (q_seg/kv_seg given, B == 1) run a segment-block-sparse
    schedule: queries are blocked too, and (q block, kv block) pairs whose
    segment-id intervals don't overlap are skipped entirely via lax.cond —
    the page stream is segment-contiguous, so per-token KV work tracks the
    token's own context length instead of the whole batch's S_flat. The
    skip is exact: a non-overlapping block's mask is all-false, and an
    all-masked block update is the identity (corr=1, pexp=0)."""
    b, t, kvl, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    qf = q * scale
    sparse = (q_seg is not None and kv_seg is not None
              and chunk_start is not None and b == 1)
    if sparse:
        q_block, block = sparse_blocks(t, s)
    nblk = -(-s // block)
    pad = nblk * block - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        slot_pos = jnp.pad(slot_pos, ((0, 0), (0, pad)),
                           constant_values=jnp.iinfo(jnp.int32).max // 2)
        if kv_seg is not None:
            kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)),
                             constant_values=-2)
    kb = k.reshape(b, nblk, block, kvl, d)
    vb = v.reshape(b, nblk, block, kvl, d)
    pb = slot_pos.reshape(b, nblk, block)
    sb = None if kv_seg is None else kv_seg.reshape(b, nblk, block)

    if sparse:
        return _prefill_flash_sparse(
            qf, kb, vb, pb, sb, q_seg, q_pos, chunk_start,
            window=window, q_block=q_block)

    def body(carry, blk):
        m, l, acc = carry
        if sb is None:
            kblk, vblk, pblk = blk
            sblk = None
        else:
            kblk, vblk, pblk, sblk = blk
        logit = jnp.einsum("btkgd,bjkd->bkgtj", qf, kblk,
                           preferred_element_type=jnp.float32)
        if chunk_start is not None:
            mask = jnp.broadcast_to(
                pblk[:, None, :] < chunk_start[:, :, None],
                (pblk.shape[0], q_pos.shape[1], pblk.shape[1]))
        else:
            mask = pblk[:, None, :] <= q_pos[:, :, None]       # (B,T,blk)
        if window:
            mask &= pblk[:, None, :] > q_pos[:, :, None] - window
        if sblk is not None:
            mask &= sblk[:, None, :] == q_seg[:, :, None]
        mask = mask[:, None, None]                             # (B,1,1,T,blk)
        logit = jnp.where(mask, logit, A.NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logit, axis=-1))
        pexp = jnp.exp(logit - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pexp, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgtj,bjkd->bkgtd", pexp.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvl, g, t), A.NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvl, g, t), jnp.float32)
    a0 = jnp.zeros((b, kvl, g, t, d), jnp.float32)
    xs = [jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
          jnp.moveaxis(pb, 1, 0)]
    if sb is not None:
        xs.append(jnp.moveaxis(sb, 1, 0))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), tuple(xs))
    return acc, m, l


def _prefill_flash_sparse(qf, kb, vb, pb, sb, q_seg, q_pos, chunk_start, *,
                          window, q_block):
    """Segment-block-sparse inner schedule for _prefill_flash (B == 1).

    qf: pre-scaled queries (1,T,KVL,G,D); kb/vb/pb/sb: KV blocked
    (1,nblk,block,...). Outer scan over query blocks, inner scan over KV
    blocks; a lax.cond skips the whole tile when the blocks' segment-id
    intervals don't overlap. Returns partials (acc, m, l) shaped exactly
    like the dense path."""
    _, t, kvl, g, d = qf.shape
    nblk, block = kb.shape[1], kb.shape[2]
    nqb = -(-t // q_block)
    pad_q = nqb * q_block - t
    qfp, qsp, qpp = qf[0], q_seg[0], q_pos[0]
    csp = jnp.broadcast_to(chunk_start, (1, t))[0]
    if pad_q:
        qfp = jnp.pad(qfp, ((0, pad_q), (0, 0), (0, 0), (0, 0)))
        qsp = jnp.pad(qsp, (0, pad_q), constant_values=-1)
        qpp = jnp.pad(qpp, (0, pad_q))
        csp = jnp.pad(csp, (0, pad_q))
    qfb = qfp.reshape(nqb, q_block, kvl, g, d)
    qsb = qsp.reshape(nqb, q_block)
    qpb = qpp.reshape(nqb, q_block)
    csb = csp.reshape(nqb, q_block)
    kbr, vbr, pbr, sbr = kb[0], vb[0], pb[0], sb[0]

    # per-block segment-id intervals (pads excluded: q pads -1, kv -2)
    big = jnp.int32(1 << 30)
    k_lo = jnp.min(jnp.where(sbr >= 0, sbr, big), axis=1)      # (nblk,)
    k_hi = jnp.max(jnp.where(sbr >= 0, sbr, -big), axis=1)
    q_lo = jnp.min(jnp.where(qsb >= 0, qsb, big), axis=1)      # (nqb,)
    q_hi = jnp.max(jnp.where(qsb >= 0, qsb, -big), axis=1)

    def qblock(_, qx):
        qfb_i, qsb_i, qpb_i, csb_i, qlo_i, qhi_i = qx

        def kvblock(carry, kx):
            kblk, vblk, pblk, sblk, klo_j, khi_j = kx
            hit = (klo_j <= qhi_i) & (khi_j >= qlo_i)

            def update(c):
                m, l, acc = c
                logit = jnp.einsum("tkgd,jkd->kgtj", qfb_i, kblk,
                                   preferred_element_type=jnp.float32)
                mask = pblk[None, :] < csb_i[:, None]          # (qb, blk)
                if window:
                    mask &= pblk[None, :] > qpb_i[:, None] - window
                mask &= sblk[None, :] == qsb_i[:, None]
                logit_m = jnp.where(mask[None, None], logit, A.NEG_INF)
                m_new = jnp.maximum(m, jnp.max(logit_m, axis=-1))
                pexp = jnp.exp(logit_m - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(pexp, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "kgtj,jkd->kgtd", pexp.astype(vblk.dtype), vblk,
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc_new

            return jax.lax.cond(hit, update, lambda c: c, carry), None

        m0 = jnp.full((kvl, g, q_block), A.NEG_INF, jnp.float32)
        l0 = jnp.zeros((kvl, g, q_block), jnp.float32)
        a0 = jnp.zeros((kvl, g, q_block, d), jnp.float32)
        out, _ = jax.lax.scan(kvblock, (m0, l0, a0),
                              (kbr, vbr, pbr, sbr, k_lo, k_hi))
        return None, out

    _, (ms, ls, accs) = jax.lax.scan(
        qblock, None, (qfb, qsb, qpb, csb, q_lo, q_hi))
    m = jnp.moveaxis(ms, 0, 2).reshape(kvl, g, nqb * q_block)[..., :t][None]
    l = jnp.moveaxis(ls, 0, 2).reshape(kvl, g, nqb * q_block)[..., :t][None]
    acc = jnp.moveaxis(accs, 0, 2).reshape(
        kvl, g, nqb * q_block, d)[:, :, :t][None]
    return acc, m, l


def _bh_streams(q, k, v, groups):
    """(1,T,KVL,G,D) q + (1,S,KVL,D) k/v -> (BH,·,D) head streams for the
    Pallas varlen kernel, kv heads repeated per q group (kv head h serves
    q heads h*g .. h*g+g-1, matching the (KVL, G) flattening order)."""
    b, t, kvl, g, d = q.shape
    qbh = q[0].transpose(1, 2, 0, 3).reshape(kvl * g, t, d)
    kbh = jnp.repeat(k[0].transpose(1, 0, 2), g, axis=0)
    vbh = jnp.repeat(v[0].transpose(1, 0, 2), g, axis=0)
    return qbh, kbh, vbh


def _bh_out(out, kvl, g):
    """(BH,T,D) kernel output back to (1,T,KVL,G,D)."""
    bh, t, d = out.shape
    return out.reshape(kvl, g, t, d).transpose(2, 0, 1, 3)[None]


def packed_kernel_attention(q, k_old, v_old, slot_pos, slot_seg, k_fresh,
                            v_fresh, positions, seg_ids, chunk_start, *,
                            window=0):
    """Packed serve attention via the Pallas varlen kernel: ONE
    segment-block-sparse flash call over [old page slots ++ fresh chunk
    K/V], replacing the ref path's two-part partials merge.

    Old slots are gated by their segment's chunk start (parity with the
    ref's strict ``slot_pos < chunk_start`` mask): a scatter-max over the
    token stream recovers each segment's chunk start, and slots at or past
    it — plus dead/pad slots (seg -2) — are re-tagged seg -2 so they never
    match. Fresh tokens ride with kv_pos = positions, so the kernel's
    ``kpos <= qpos`` rule reproduces the ref's intra-chunk causal mask;
    old valid slots always have pos < chunk_start <= qpos, so the same
    rule is a no-op for them.

    Single-shard only (the kernel returns normalized output; callers with
    kv_groups/sp_axis keep the ref partials path). q: (1,T,KVL,G,D);
    k_old/v_old: (1,S,KVL,D); k_fresh/v_fresh: (1,T,KVL,D). Returns
    (1,T,KVL,G,D) in q.dtype; rows with no visible KV come out zero."""
    b, t, kvl, g, d = q.shape
    s = k_old.shape[1]
    sid = seg_ids[0]
    cs = jnp.broadcast_to(chunk_start, (b, t))[0]
    seg_cs = jnp.full((t,), -1, jnp.int32).at[jnp.clip(sid, 0, t - 1)].max(
        jnp.where(sid >= 0, cs, -1))
    slot_cs = jnp.take(seg_cs, jnp.clip(slot_seg[0], 0, t - 1))
    live = (slot_seg[0] >= 0) & (slot_pos[0] < slot_cs)
    kv_seg = jnp.concatenate([jnp.where(live, slot_seg[0], -2), sid])
    kv_pos = jnp.concatenate([slot_pos[0], positions[0]])
    kk = jnp.concatenate([k_old, k_fresh], axis=1)
    vv = jnp.concatenate([v_old, v_fresh], axis=1)
    qbh, kbh, vbh = _bh_streams(q, kk, vv, g)
    blk_q, blk_k = sparse_blocks(t, s + t)
    out = flash_attention_varlen_tpu(
        qbh, kbh, vbh, sid, kv_seg, positions[0], kv_pos, window=window,
        blk_q=blk_q, blk_k=blk_k,
        interpret=jax.default_backend() != "tpu")
    return _bh_out(out, kvl, g)


def packed_cross_attn_kernel(q, k_all, v_all, slot_pos, slot_seg, seg_ids,
                             enc_lens):
    """Packed cross-attention via the varlen kernel: each decoder token
    attends every encoder slot of its own segment with slot_pos <
    enc_lens, encoded as the kernel's ``kpos <= qpos`` rule with
    q_pos := enc_lens - 1. Text-only rows (enc_lens == 0) get q_pos -1,
    match nothing, and come out exactly zero — the ref path's explicit
    zero guard. Returns (1,T,KVL,G,D) normalized output in q.dtype."""
    b, t, kvl, g, d = q.shape
    qbh, kbh, vbh = _bh_streams(q, k_all, v_all, g)
    blk_q, blk_k = sparse_blocks(t, k_all.shape[1])
    out = flash_attention_varlen_tpu(
        qbh, kbh, vbh, seg_ids[0], slot_seg[0], enc_lens[0] - 1,
        slot_pos[0], blk_q=blk_q, blk_k=blk_k,
        interpret=jax.default_backend() != "tpu")
    return _bh_out(out, kvl, g)


def cross_attn_cached(p, x, view, dist: Dist, *, layer, kv_local, head_dim,
                      tables, enc_lens, norm_eps=1e-5):
    """Cross-attention reading encoder KV from cross-attn pages (read-only;
    caller passes the reshape view)."""
    b, t, _ = x.shape
    tpp = view.shape[3]
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q = dense(xn, p["q"]).reshape(b, t, -1, head_dim)
    q = A.group_q(q, kv_local)
    k_all, v_all = A.gather_pages(view, tables, layer)
    s = k_all.shape[1]
    slot_idx = jnp.arange(s)[None]                             # (1, S)
    mask = jnp.broadcast_to(slot_idx < enc_lens[:, None], (b, s))
    mask = jnp.broadcast_to(mask[:, None, :], (b, t, s))
    o, m, l = A.attend_tokens(q, k_all, v_all, mask)
    out = A.finalize_softmax(o, l).reshape(b, t, -1).astype(x.dtype)
    y = dense(out, p["o"])
    return x + psum_tp(y, dist)


def write_cross_kv(p, enc_out, buf, view_shape, *, layer, kv_local,
                   head_dim, write_eids):
    """Project encoder output and write K/V into cross-attn pages.
    enc_out: (B, S_enc, d); write_eids: (B, S_enc)."""
    b, s, _ = enc_out.shape
    tpp = view_shape[3]
    k = dense(enc_out, p["k"]).reshape(b, s, kv_local, head_dim)
    v = dense(enc_out, p["v"]).reshape(b, s, kv_local, head_dim)
    slots = jnp.broadcast_to(jnp.arange(s)[None] % tpp, (b, s))
    return A.write_token_kv(buf, view_shape, layer, write_eids, slots, k, v)


def cross_attn_train(p, x, enc_out, dist: Dist, *, kv_local, head_dim,
                     norm_eps=1e-5):
    b, t, _ = x.shape
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q = dense(xn, p["q"]).reshape(b, t, -1, head_dim)
    q = A.group_q(q, kv_local)
    k = dense(enc_out, p["k"]).reshape(b, enc_out.shape[1], kv_local, head_dim)
    v = dense(enc_out, p["v"]).reshape(b, enc_out.shape[1], kv_local, head_dim)
    s = k.shape[1]
    mask = jnp.ones((b, t, s), bool)
    o, m, l = A.attend_tokens(q, k, v, mask)
    out = A.finalize_softmax(o, l).reshape(b, t, -1).astype(x.dtype)
    return x + psum_tp(dense(out, p["o"]), dist)


# ---------------------------------------------------------------------- MLP
def mlp_block(p, x, dist: Dist, norm_eps=1e-5):
    xn = rms_norm(x, p["mlp_norm"], norm_eps)
    g = dense(xn, p["gate"])
    u = dense(xn, p["up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    y = dense(h, p["down"])
    return x + psum_tp(y, dist)


# ---------------------------------------------------------------------- MoE
def moe_block(p, x, dist: Dist, *, num_experts, top_k, capacity_factor=1.25,
              norm_eps=1e-5, aux_weight=0.01, ep_axis: str = "data"):
    """GShard-style MoE with a 2-D expert sharding (big-model scale):
    experts over ``ep_axis`` (EP, all_to_all dispatch) x per-expert FFN dim
    over the tp axis (expert-TP, psum after down-proj). Pods replicate
    experts, so the all_to_all never crosses the DCN.

    Expert weights local: (E_local, d, ffe_local). Returns (x_out, aux)."""
    b, t, d = x.shape
    e = num_experts
    ep = dist.mesh.shape[ep_axis]
    e_local = p["moe_gate"].shape[0]
    assert e_local * ep == e, (e_local, ep, e)
    xn = rms_norm(x, p["mlp_norm"], norm_eps)
    tok = xn.reshape(b * t, d)
    n = tok.shape[0]

    router = jnp.einsum("nd,de->ne", tok.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router, axis=-1)                    # (N, E)
    gate_vals, idx = jax.lax.top_k(probs, top_k)               # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (n * top_k)
    aux = aux_weight * e * jnp.sum(me * ce)

    cap = int(max(1, round(n * top_k / e * capacity_factor)))
    # position of each (token, k) copy within its expert queue
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)           # (N, K, E)
    flat = onehot.reshape(n * top_k, e)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                  # (N*K, E)
    pos_in_e = jnp.max(pos, axis=-1)                           # (N*K,)
    e_flat = idx.reshape(-1)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_flat * cap + pos_in_e, e * cap)   # drop -> OOB

    dispatch = jnp.zeros((e * cap + 1, d), tok.dtype)
    src = jnp.repeat(tok, top_k, axis=0)                       # (N*K, d)
    dispatch = dispatch.at[slot].set(src, mode="drop")
    dispatch = dispatch[:-1].reshape(e, cap, d)

    # EP all_to_all: (E, C, d) -> (E_local, ep*C, d)
    shuffled = jax.lax.all_to_all(
        dispatch.reshape(ep, e_local, cap, d), ep_axis,
        split_axis=0, concat_axis=0, tiled=False)              # (ep, e_local, C, d)
    shuffled = jnp.moveaxis(shuffled, 0, 1).reshape(e_local, ep * cap, d)

    # expert-TP: ffe sharded over tp; psum after down-proj
    g = jnp.einsum("ecd,edf->ecf", shuffled, p["moe_gate"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", shuffled, p["moe_up"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["moe_down"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    y = psum_tp(y, dist).astype(x.dtype)

    # return path
    y = jnp.moveaxis(y.reshape(e_local, ep, cap, d), 1, 0)     # (ep, e_local, C, d)
    back = jax.lax.all_to_all(y, ep_axis, split_axis=0,
                              concat_axis=0, tiled=False)
    back = back.reshape(e * cap, d)
    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)

    gathered = jnp.take(back, jnp.where(keep, slot, e * cap), axis=0)
    gathered = gathered.reshape(n, top_k, d)
    out = jnp.sum(gathered.astype(jnp.float32)
                  * gate_vals[..., None], axis=1).astype(x.dtype)
    return x + out.reshape(b, t, d), aux
