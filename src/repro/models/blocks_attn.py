"""Transformer blocks (attention / MLP / MoE) — local code inside shard_map.

All functions take *local* param slices (leading tp dim already consumed by
shard_map's in_specs and squeezed by the caller) and replicated activations
(B, T, d); tensor-parallel reductions are explicit ``psum`` over the tp axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as A
from .common import dense, rms_norm
from .rotary import apply_mrope, apply_rope
from .tp import Dist, psum_tp


# ---------------------------------------------------------------- attention
def qkv_proj(p, xn, *, kv_local: int, head_dim: int, positions,
             rope_theta: float, mrope_positions=None, use_rope=True):
    """Project + rope. Returns q (B,T,KVL,G,D), k, v (B,T,KVL,D)."""
    b, t, _ = xn.shape
    q = dense(xn, p["q"], p.get("q_bias"))
    k = dense(xn, p["k"], p.get("k_bias"))
    v = dense(xn, p["v"], p.get("v_bias"))
    q = q.reshape(b, t, -1, head_dim)
    k = k.reshape(b, t, kv_local, head_dim)
    v = v.reshape(b, t, kv_local, head_dim)
    if use_rope:
        if mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, rope_theta)
            k = apply_mrope(k, mrope_positions, rope_theta)
        else:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
    q = A.group_q(q, kv_local)
    return q, k, v


def attn_train(p, x, dist: Dist, *, kv_local, head_dim, window=0,
               rope_theta=1e6, positions=None, mrope_positions=None,
               causal=True, norm_eps=1e-5, q_block=1024):
    """Full/SWA self-attention for training (no cache)."""
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q, k, v = qkv_proj(p, xn, kv_local=kv_local, head_dim=head_dim,
                       positions=positions, rope_theta=rope_theta,
                       mrope_positions=mrope_positions)

    # outer scan over q chunks keeps the score tensor bounded
    nq = -(-t // q_block)
    pad = nq * q_block - t
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0))) if pad else q

    def qchunk(carry, inp):
        qc, off = inp
        out = A.flash_attention(qc, k, v, causal=causal, window=window,
                                q_offset=off)
        return carry, out

    qblocks = qp.reshape(b, nq, q_block, *q.shape[2:])
    offs = jnp.arange(nq) * q_block
    _, outs = jax.lax.scan(qchunk, None, (jnp.moveaxis(qblocks, 1, 0), offs))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_block, -1)
    out = out[:, :t]
    y = dense(out, p["o"])
    return x + psum_tp(y, dist)


def attn_gather(buf, view_shape, tables, page_pos, layer, page_seg=None):
    """Phase 1 (READ): gather this layer's old pages + absolute positions.
    Must run before any buffer write in the same scan iteration (in-place
    aliasing: see EXPERIMENTS.md 'buffer-copy' study).

    page_seg: (B, P) owning-segment id per page for PACKED layouts (all
    segments' pages share one flat table row); None for the padded
    row-per-sequence layout. Returns (k, v, slot_pos, slot_seg) with
    slot_seg None when page_seg is None."""
    view = buf.reshape(view_shape)
    k_all, v_all = A.gather_pages(view, tables, layer)
    b, p = tables.shape
    tpp = view_shape[3]
    s = k_all.shape[1]
    slot_pos = (page_pos[:, :, None] + jnp.arange(tpp)[None, None, :]
                ).reshape(b, s)
    slot_seg = None
    if page_seg is not None:
        slot_seg = jnp.broadcast_to(page_seg[:, :, None],
                                    (b, p, tpp)).reshape(b, s)
    return k_all, v_all, slot_pos, slot_seg


def attn_compute(p, x, gathered, dist: Dist, *, kv_local, head_dim,
                 positions, seq_lens, window=0, rope_theta=1e6,
                 mrope_positions=None, norm_eps=1e-5, prefill=False,
                 sp_axis: Optional[str] = None, kv_groups=None,
                 seg_ids=None, chunk_start=None):
    """Phase 2 (COMPUTE): attention over gathered old pages + this step's
    fresh K/V (still in registers — the buffer write happens in phase 3).

    Old-page masking uses ``slot_pos < chunk_start`` (strictly before the
    chunk start): the chunk's own slots are not yet written. The fresh part
    is intra-chunk causal attention merged via partial-softmax, after the
    old part was combined across KV-replica groups / SP shards (the fresh
    part is replicated on all shards, so it merges locally exactly once).

    PACKED layout: ``seg_ids`` (B, T) carries per-token segment ids and
    ``chunk_start`` (B, T) each token's chunk-start position (several
    sequences share one stream row); both masks then additionally require
    segment equality, using the slot_seg returned by ``attn_gather``.
    Returns (x_out, k_fresh, v_fresh)."""
    k_all, v_all, slot_pos, slot_seg = gathered
    b, t, _ = x.shape
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q, k, v = qkv_proj(p, xn, kv_local=kv_local, head_dim=head_dim,
                       positions=positions, rope_theta=rope_theta,
                       mrope_positions=mrope_positions)
    packed = seg_ids is not None
    if chunk_start is None:
        chunk_start = positions[:, :1]                         # (B, 1)
    if prefill or packed:
        o, m, l = _prefill_flash(q, k_all, v_all, slot_pos, positions,
                                 chunk_start=chunk_start, window=window,
                                 q_seg=seg_ids, kv_seg=slot_seg)
    else:
        mask = slot_pos[:, None, :] < chunk_start[:, :, None]  # strict
        if window:
            mask &= slot_pos[:, None, :] > positions[:, :, None] - window
        o, m, l = A.attend_tokens(q, k_all, v_all, mask)
    if kv_groups is not None:
        o, m, l = A.combine_partials(o, m, l, dist.tp_axis, groups=kv_groups)
    if sp_axis is not None:
        o, m, l = A.combine_partials(o, m, l, sp_axis)
    # fresh (intra-chunk) part: causal within the chunk (and within the
    # token's own segment, for packed streams)
    if packed:
        mask_f = A.segment_mask(seg_ids, positions, seg_ids, positions,
                                window=window)
        of, mf, lf = A.attend_tokens(q, k, v, mask_f)
    elif t == 1:
        mask_f = jnp.ones((b, 1, 1), bool)
        of, mf, lf = A.attend_tokens(q, k, v, mask_f)
    elif t <= 256:
        mask_f = positions[:, None, :] <= positions[:, :, None]
        if window:
            mask_f &= positions[:, None, :] > positions[:, :, None] - window
        of, mf, lf = A.attend_tokens(q, k, v, mask_f)
    else:
        of, mf, lf = A.flash_attention_partials(
            q, k, v, causal=True, window=window)
    o, m, l = A.merge_partials(o, m, l, of, mf, lf)
    out = A.finalize_softmax(o, l).reshape(b, t, -1).astype(x.dtype)
    y = dense(out, p["o"])
    return x + psum_tp(y, dist), k, v


def attn_write(buf, view_shape, layer, write_eids, positions, k, v):
    """Phase 3 (WRITE): stream this step's K/V into its pages."""
    tpp = view_shape[3]
    return A.write_token_kv(buf, view_shape, layer, write_eids,
                            positions % tpp, k, v)


def attn_cached(p, x, buf, view_shape, dist: Dist, *, layer, kv_local,
                head_dim, tables, page_pos, write_eids, positions, seq_lens,
                window=0, rope_theta=1e6, mrope_positions=None,
                norm_eps=1e-5, prefill=False, sp_axis: Optional[str] = None,
                kv_groups=None):
    """Convenience gather->compute->write for one attention layer per scan
    iteration. Models with several attention layers per iteration must call
    the phases separately (all gathers before any write)."""
    gathered = attn_gather(buf, view_shape, tables, page_pos, layer)
    x, k, v = attn_compute(
        p, x, gathered, dist, kv_local=kv_local, head_dim=head_dim,
        positions=positions, seq_lens=seq_lens, window=window,
        rope_theta=rope_theta, mrope_positions=mrope_positions,
        norm_eps=norm_eps, prefill=prefill, sp_axis=sp_axis,
        kv_groups=kv_groups)
    buf = attn_write(buf, view_shape, layer, write_eids, positions, k, v)
    return x, buf


def _prefill_flash(q, k, v, slot_pos, q_pos, *, window, chunk_start=None,
                   block=512, q_seg=None, kv_seg=None):
    """Flash attention over OLD pages for a prefill chunk.
    Returns un-normalized partials (acc, m, l) for cross-shard combining.

    chunk_start: (B,1) per row — or (B,T) per token for PACKED streams —
    old slots are valid iff slot_pos < chunk_start (the chunk itself
    attends via the fresh-KV path). q_seg (B,T) / kv_seg (B,S): packed
    segment ids; when given, the mask additionally requires
    kv_seg == q_seg so no token reads another sequence's pages.
    q: (B,T,KVL,G,D); k/v: (B,S,KVL,D); slot_pos: (B,S); q_pos: (B,T)."""
    b, t, kvl, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    qf = q * scale
    nblk = -(-s // block)
    pad = nblk * block - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        slot_pos = jnp.pad(slot_pos, ((0, 0), (0, pad)),
                           constant_values=jnp.iinfo(jnp.int32).max // 2)
        if kv_seg is not None:
            kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)),
                             constant_values=-2)
    kb = k.reshape(b, nblk, block, kvl, d)
    vb = v.reshape(b, nblk, block, kvl, d)
    pb = slot_pos.reshape(b, nblk, block)
    sb = None if kv_seg is None else kv_seg.reshape(b, nblk, block)

    def body(carry, blk):
        m, l, acc = carry
        if sb is None:
            kblk, vblk, pblk = blk
            sblk = None
        else:
            kblk, vblk, pblk, sblk = blk
        logit = jnp.einsum("btkgd,bjkd->bkgtj", qf, kblk,
                           preferred_element_type=jnp.float32)
        if chunk_start is not None:
            mask = jnp.broadcast_to(
                pblk[:, None, :] < chunk_start[:, :, None],
                (pblk.shape[0], q_pos.shape[1], pblk.shape[1]))
        else:
            mask = pblk[:, None, :] <= q_pos[:, :, None]       # (B,T,blk)
        if window:
            mask &= pblk[:, None, :] > q_pos[:, :, None] - window
        if sblk is not None:
            mask &= sblk[:, None, :] == q_seg[:, :, None]
        mask = mask[:, None, None]                             # (B,1,1,T,blk)
        logit = jnp.where(mask, logit, A.NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logit, axis=-1))
        pexp = jnp.exp(logit - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pexp, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgtj,bjkd->bkgtd", pexp.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvl, g, t), A.NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvl, g, t), jnp.float32)
    a0 = jnp.zeros((b, kvl, g, t, d), jnp.float32)
    xs = [jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
          jnp.moveaxis(pb, 1, 0)]
    if sb is not None:
        xs.append(jnp.moveaxis(sb, 1, 0))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), tuple(xs))
    return acc, m, l


def cross_attn_cached(p, x, view, dist: Dist, *, layer, kv_local, head_dim,
                      tables, enc_lens, norm_eps=1e-5):
    """Cross-attention reading encoder KV from cross-attn pages (read-only;
    caller passes the reshape view)."""
    b, t, _ = x.shape
    tpp = view.shape[3]
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q = dense(xn, p["q"]).reshape(b, t, -1, head_dim)
    q = A.group_q(q, kv_local)
    k_all, v_all = A.gather_pages(view, tables, layer)
    s = k_all.shape[1]
    slot_idx = jnp.arange(s)[None]                             # (1, S)
    mask = jnp.broadcast_to(slot_idx < enc_lens[:, None], (b, s))
    mask = jnp.broadcast_to(mask[:, None, :], (b, t, s))
    o, m, l = A.attend_tokens(q, k_all, v_all, mask)
    out = A.finalize_softmax(o, l).reshape(b, t, -1).astype(x.dtype)
    y = dense(out, p["o"])
    return x + psum_tp(y, dist)


def write_cross_kv(p, enc_out, buf, view_shape, *, layer, kv_local,
                   head_dim, write_eids):
    """Project encoder output and write K/V into cross-attn pages.
    enc_out: (B, S_enc, d); write_eids: (B, S_enc)."""
    b, s, _ = enc_out.shape
    tpp = view_shape[3]
    k = dense(enc_out, p["k"]).reshape(b, s, kv_local, head_dim)
    v = dense(enc_out, p["v"]).reshape(b, s, kv_local, head_dim)
    slots = jnp.broadcast_to(jnp.arange(s)[None] % tpp, (b, s))
    return A.write_token_kv(buf, view_shape, layer, write_eids, slots, k, v)


def cross_attn_train(p, x, enc_out, dist: Dist, *, kv_local, head_dim,
                     norm_eps=1e-5):
    b, t, _ = x.shape
    xn = rms_norm(x, p["attn_norm"], norm_eps)
    q = dense(xn, p["q"]).reshape(b, t, -1, head_dim)
    q = A.group_q(q, kv_local)
    k = dense(enc_out, p["k"]).reshape(b, enc_out.shape[1], kv_local, head_dim)
    v = dense(enc_out, p["v"]).reshape(b, enc_out.shape[1], kv_local, head_dim)
    s = k.shape[1]
    mask = jnp.ones((b, t, s), bool)
    o, m, l = A.attend_tokens(q, k, v, mask)
    out = A.finalize_softmax(o, l).reshape(b, t, -1).astype(x.dtype)
    return x + psum_tp(dense(out, p["o"]), dist)


# ---------------------------------------------------------------------- MLP
def mlp_block(p, x, dist: Dist, norm_eps=1e-5):
    xn = rms_norm(x, p["mlp_norm"], norm_eps)
    g = dense(xn, p["gate"])
    u = dense(xn, p["up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    y = dense(h, p["down"])
    return x + psum_tp(y, dist)


# ---------------------------------------------------------------------- MoE
def moe_block(p, x, dist: Dist, *, num_experts, top_k, capacity_factor=1.25,
              norm_eps=1e-5, aux_weight=0.01, ep_axis: str = "data"):
    """GShard-style MoE with a 2-D expert sharding (big-model scale):
    experts over ``ep_axis`` (EP, all_to_all dispatch) x per-expert FFN dim
    over the tp axis (expert-TP, psum after down-proj). Pods replicate
    experts, so the all_to_all never crosses the DCN.

    Expert weights local: (E_local, d, ffe_local). Returns (x_out, aux)."""
    b, t, d = x.shape
    e = num_experts
    ep = dist.mesh.shape[ep_axis]
    e_local = p["moe_gate"].shape[0]
    assert e_local * ep == e, (e_local, ep, e)
    xn = rms_norm(x, p["mlp_norm"], norm_eps)
    tok = xn.reshape(b * t, d)
    n = tok.shape[0]

    router = jnp.einsum("nd,de->ne", tok.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router, axis=-1)                    # (N, E)
    gate_vals, idx = jax.lax.top_k(probs, top_k)               # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (n * top_k)
    aux = aux_weight * e * jnp.sum(me * ce)

    cap = int(max(1, round(n * top_k / e * capacity_factor)))
    # position of each (token, k) copy within its expert queue
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)           # (N, K, E)
    flat = onehot.reshape(n * top_k, e)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                  # (N*K, E)
    pos_in_e = jnp.max(pos, axis=-1)                           # (N*K,)
    e_flat = idx.reshape(-1)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_flat * cap + pos_in_e, e * cap)   # drop -> OOB

    dispatch = jnp.zeros((e * cap + 1, d), tok.dtype)
    src = jnp.repeat(tok, top_k, axis=0)                       # (N*K, d)
    dispatch = dispatch.at[slot].set(src, mode="drop")
    dispatch = dispatch[:-1].reshape(e, cap, d)

    # EP all_to_all: (E, C, d) -> (E_local, ep*C, d)
    shuffled = jax.lax.all_to_all(
        dispatch.reshape(ep, e_local, cap, d), ep_axis,
        split_axis=0, concat_axis=0, tiled=False)              # (ep, e_local, C, d)
    shuffled = jnp.moveaxis(shuffled, 0, 1).reshape(e_local, ep * cap, d)

    # expert-TP: ffe sharded over tp; psum after down-proj
    g = jnp.einsum("ecd,edf->ecf", shuffled, p["moe_gate"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", shuffled, p["moe_up"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["moe_down"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    y = psum_tp(y, dist).astype(x.dtype)

    # return path
    y = jnp.moveaxis(y.reshape(e_local, ep, cap, d), 1, 0)     # (ep, e_local, C, d)
    back = jax.lax.all_to_all(y, ep_axis, split_axis=0,
                              concat_axis=0, tiled=False)
    back = back.reshape(e * cap, d)
    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)

    gathered = jnp.take(back, jnp.where(keep, slot, e * cap), axis=0)
    gathered = gathered.reshape(n, top_k, d)
    out = jnp.sum(gathered.astype(jnp.float32)
                  * gate_vals[..., None], axis=1).astype(x.dtype)
    return x + out.reshape(b, t, d), aux
