"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``attn_every`` mamba blocks (weight sharing — the Zamba2
signature). KV types: one Mamba state spec covering all mamba layers + one
full-attn spec with a cache layer per shared-block invocation."""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..core.spec import KVCacheSpec, attention_spec, mamba_spec
from . import attention as A
from . import blocks_attn as BA
from . import blocks_seq as BS
from .common import rms_norm
from .lm import DecoderLM, DecodeBatch, _dp_spec
from .params import PD
from .tp import (embed_lookup, expand_gqa_kv, expand_gqa_o, expand_gqa_q,
                 logits_local, mask_pad_vocab, psum_dp, sharded_softmax_xent)


class HybridLM(DecoderLM):
    def __init__(self, cfg: ModelConfig, dist):
        # bypass DecoderLM pattern machinery; reuse its vocab/ri helpers
        cfg.validate()
        self.cfg = cfg
        self.dist = dist
        tp = dist.tp
        from .tp import replica_info
        self.ri = replica_info(cfg.num_heads, cfg.num_kv_heads, tp)
        self.v_local = -(-cfg.vocab_size // tp)
        self.v_pad = self.v_local * tp
        self.is_moe = False
        assert cfg.attn_every > 0
        self.n_super = cfg.num_layers // cfg.attn_every
        self.n_tail = cfg.num_layers % cfg.attn_every
        self.md = BS.mamba2_dims(cfg.d_model, cfg.mamba_expand,
                                 cfg.mamba_headdim, cfg.mamba_d_state,
                                 cfg.mamba_conv_width, tp)

    # ------------------------------------------------------------ kv specs
    def kv_specs(self) -> Tuple[KVCacheSpec, ...]:
        cfg, md = self.cfg, self.md
        return (
            attention_spec(
                "full_attn", num_layers=self.n_super,
                kv_heads=self.ri["kv_local"], head_dim=cfg.head_dim,
                tokens_per_page=cfg.tokens_per_page),
            # fp32 state stored as bf16 pairs -> x2 units
            mamba_spec("mamba", num_layers=cfg.num_layers,
                       conv_units=2 * md["conv_units"],
                       ssm_units=2 * md["ssm_units"]),
        )

    def page_shapes(self) -> Dict[str, Tuple[int, ...]]:
        cfg, md = self.cfg, self.md
        return {
            "full_attn": (2, cfg.tokens_per_page, self.ri["kv_local"],
                          cfg.head_dim),
            "mamba": (2 * (md["ssm_units"] + md["conv_units"]),),
        }

    # ----------------------------------------------------------- template
    def _mamba_layer_tmpl(self, n: int):
        cfg, dist, md = self.cfg, self.dist, self.md
        tp = dist.tp
        d = cfg.d_model
        dil = md["d_in_local"]
        hl = md["h_local"]
        N = cfg.mamba_d_state
        W = cfg.mamba_conv_width
        sp = P(None, "model")
        from .tp import expand_replicated

        def repl_stack(shape):
            def fn(key):
                keys = jax.random.split(key, n)
                return jnp.stack(
                    [expand_replicated(k, shape, tp) for k in keys])
            return fn

        return {
            "norm": PD((n, d), P(), init="ones"),
            "w_z": PD((n, tp, d, dil), sp),
            "w_x": PD((n, tp, d, dil), sp),
            # B/C are shared across head groups -> identical on every shard
            "w_B": PD((n, tp, d, N), sp, init="custom",
                      fn=repl_stack((d, N))),
            "w_C": PD((n, tp, d, N), sp, init="custom",
                      fn=repl_stack((d, N))),
            "w_dt": PD((n, tp, d, hl), sp),
            "dt_bias": PD((n, tp, hl), sp, init="zeros"),
            "A_log": PD((n, tp, hl), sp, init="zeros"),
            "D": PD((n, tp, hl), sp, init="ones"),
            "conv_w": PD((n, tp, W, dil + 2 * N), sp, scale=0.2),
            "out_norm": PD((n, tp, dil), sp, init="ones"),
            "w_out": PD((n, tp, dil, d), sp,
                        scale=0.02 / (2 * cfg.num_layers) ** 0.5),
        }

    def template(self):
        cfg, dist, ri = self.cfg, self.dist, self.ri
        tp = dist.tp
        d, hd = cfg.d_model, cfg.head_dim
        ffl = cfg.d_ff // tp
        qfn = lambda k: expand_gqa_q(k, d, cfg.num_heads, cfg.num_kv_heads, hd, tp)
        kvfn = lambda k: expand_gqa_kv(k, d, cfg.num_kv_heads, hd, tp)
        ofn = lambda k: expand_gqa_o(k, d, cfg.num_heads, cfg.num_kv_heads, hd, tp)
        shared = {
            "attn_norm": PD((d,), P(), init="ones"),
            "q": PD((tp, d, ri["q_local"] * hd), P("model"), init="custom", fn=qfn),
            "k": PD((tp, d, ri["kv_local"] * hd), P("model"), init="custom", fn=kvfn),
            "v": PD((tp, d, ri["kv_local"] * hd), P("model"), init="custom", fn=kvfn),
            "o": PD((tp, ri["q_local"] * hd, d), P("model"), init="custom", fn=ofn),
            "mlp_norm": PD((d,), P(), init="ones"),
            "gate": PD((tp, d, ffl), P("model")),
            "up": PD((tp, d, ffl), P("model")),
            "down": PD((tp, ffl, d), P("model")),
        }
        tmpl = {
            "embed": PD((tp, self.v_local, d), P("model")),
            "final_norm": PD((d,), P(), init="ones"),
            "mamba_main": self._mamba_layer_tmpl(self.n_super * cfg.attn_every),
            "shared_attn": shared,
        }
        if self.n_tail:
            tmpl["mamba_tail"] = self._mamba_layer_tmpl(self.n_tail)
        if not cfg.tie_embeddings:
            tmpl["unembed"] = PD((tp, self.v_local, d), P("model"))
        return tmpl

    # ----------------------------------------------------------------- run
    def _mamba_kw(self):
        cfg = self.cfg
        return dict(d_state=cfg.mamba_d_state, headdim=cfg.mamba_headdim,
                    conv_width=cfg.mamba_conv_width, norm_eps=cfg.norm_eps)

    def _train_body(self, params, tokens, targets, *mm, has_mm=False):
        cfg, dist = self.cfg, self.dist
        params = self._squeeze_params(params)
        b, t = tokens.shape
        x = embed_lookup(tokens, params["embed"], dist)
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        ae = cfg.attn_every
        main = jax.tree.map(
            lambda a: a.reshape(self.n_super, ae, *a.shape[1:]),
            params["mamba_main"])
        shared = params["shared_attn"]
        mkw = self._mamba_kw()

        def super_body(x, xs):
            mp = xs
            for j in range(ae):
                pj = jax.tree.map(lambda a: a[j], mp)
                x, _ = BS.mamba2_chunked(pj, x, dist, self.md, **mkw)
            x = BA.attn_train(shared, x, dist, kv_local=self.ri["kv_local"],
                              head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                              positions=positions, norm_eps=cfg.norm_eps)
            x = BA.mlp_block(shared, x, dist, cfg.norm_eps)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(super_body), x, main)
        if self.n_tail:
            def tail_body(x, pj):
                x, _ = BS.mamba2_chunked(pj, x, dist, self.md, **mkw)
                return x, None
            x, _ = jax.lax.scan(jax.checkpoint(tail_body), x,
                                params["mamba_tail"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = logits_local(x, self._unembed(params))
        loss = sharded_softmax_xent(logits, targets, dist)
        return psum_dp(loss, dist) / dist.dp

    def _serve_body(self, params, buffer, batch: DecodeBatch, *, prefill,
                    attention_impl="ref"):
        cfg, dist = self.cfg, self.dist
        params = self._squeeze_params(params)
        buffer = buffer.reshape(buffer.shape[-1])
        tokens = batch.tokens
        b, t = tokens.shape
        positions = batch.positions
        x = embed_lookup(tokens, params["embed"], dist)
        views = self._layer_views(buffer)
        sq = lambda a: jnp.squeeze(a, axis=(0, 1))
        tables = sq(batch.tables["full_attn"])
        page_pos = sq(batch.page_pos["full_attn"])
        write_eids = sq(batch.write_eids["full_attn"])
        state_eids = jnp.squeeze(batch.state_eids["mamba"], axis=0)
        packed = batch.seg_ids is not None
        page_seg = sq(batch.page_seg["full_attn"]) if packed else None
        kv_groups = (None if self.ri["repl"] == 1 else
                     A.replica_groups(self.ri["kv_tp"], self.ri["repl"]))
        ae = cfg.attn_every
        main = jax.tree.map(
            lambda a: a.reshape(self.n_super, ae, *a.shape[1:]),
            params["mamba_main"])
        shared = params["shared_attn"]
        mkw = self._mamba_kw()
        sp_axis = "data" if dist.sp else None

        # ragged mixed batch: rows may have fewer valid tokens than T; the
        # chunked scan must not fold padded tokens into the carried state
        lidx = batch.last_idx
        lmask = (None if lidx is None else
                 jnp.arange(t)[None] <= lidx[:, None])
        seg_kw = {} if not packed else dict(
            seg_ids=batch.seg_ids[0], seg_start=batch.seg_start_tok[0],
            seg_last=batch.seg_last_tok)

        def run_mamba(pj, x, buf, layer_idx):
            view = buf.reshape(views["mamba"])
            st = A.read_state(view, layer_idx, state_eids)
            if packed:
                x, st = BS.mamba2_packed(pj, x, dist, self.md,
                                         init_state=st, **seg_kw, **mkw)
            elif prefill:
                x, st = BS.mamba2_chunked(pj, x, dist, self.md,
                                          init_state=st, length_mask=lmask,
                                          last_idx=lidx, **mkw)
            else:
                x, st = BS.mamba2_step(pj, x, st, dist, self.md, **mkw)
            buf = A.write_state(buf, views["mamba"], layer_idx,
                                state_eids, st)
            return x, buf

        def super_body(carry, xs):
            x, buf = carry
            mp, cyc = xs
            # READ phase first: gather the shared-attn pages before any of
            # this iteration's buffer writes (in-place aliasing)
            gathered = BA.attn_gather(buf, views["full_attn"], tables,
                                      page_pos, cyc, page_seg)
            # inner scan: one mamba block per iteration (read own state,
            # then write it -> read-before-write per inner iteration)
            def mamba_iter(carry, xs2):
                x, buf = carry
                pj, j = xs2
                x, buf = run_mamba(pj, x, buf, cyc * ae + j)
                return (x, buf), None
            (x, buf), _ = jax.lax.scan(
                mamba_iter, (x, buf), (mp, jnp.arange(ae)))
            x, k, v = BA.attn_compute(
                shared, x, gathered, dist,
                kv_local=self.ri["kv_local"], head_dim=cfg.head_dim,
                positions=positions, seq_lens=batch.seq_lens,
                rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                prefill=prefill, sp_axis=sp_axis, kv_groups=kv_groups,
                seg_ids=batch.seg_ids, chunk_start=batch.chunk_start,
                impl=attention_impl)
            x = BA.mlp_block(shared, x, dist, cfg.norm_eps)
            buf = BA.attn_write(buf, views["full_attn"], cyc, write_eids,
                                positions, k, v)
            return (x, buf), None

        (x, buffer), _ = jax.lax.scan(
            super_body, (x, buffer), (main, jnp.arange(self.n_super)))
        if self.n_tail:
            tail = params["mamba_tail"]
            base = self.n_super * ae

            def tail_body(carry, xs):
                x, buf = carry
                pj, k = xs
                x, buf = run_mamba(pj, x, buf, base + k)
                return (x, buf), None

            (x, buffer), _ = jax.lax.scan(
                tail_body, (x, buffer), (tail, jnp.arange(self.n_tail)))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if packed:
            x = jnp.take(x[0], batch.seg_last_tok, axis=0)[:, None]
        elif batch.last_idx is not None:
            x = jnp.take_along_axis(
                x, batch.last_idx[:, None, None].astype(jnp.int32), axis=1)
        else:
            x = x[:, -1:]
        logits = logits_local(x, self._unembed(params))[:, 0]
        logits = mask_pad_vocab(logits, cfg.vocab_size, dist)
        return logits, buffer.reshape(1, 1, -1)
