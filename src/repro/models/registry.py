"""Model registry: config -> model instance."""
from __future__ import annotations

from ..configs.base import ModelConfig
from .encdec import EncDecLM
from .hybrid import HybridLM
from .lm import DecoderLM
from .rwkv_lm import RWKVLM
from .tp import Dist


def build_model(cfg: ModelConfig, dist: Dist):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, dist)
    if cfg.family == "hybrid":
        return HybridLM(cfg, dist)
    if cfg.family == "ssm":
        return RWKVLM(cfg, dist)
    if cfg.family == "encdec":
        return EncDecLM(cfg, dist)
    raise ValueError(f"unknown family {cfg.family!r}")
