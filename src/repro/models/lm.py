"""Decoder-only LM (dense / MoE / SWA-mix / VLM backbones).

One shard_map over the whole mesh per step function (train / prefill /
decode); layers run under ``lax.scan`` over stacked params (one compiled
layer body regardless of depth — essential for 512-device dry-run compiles).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..core.spec import KVCacheSpec, attention_spec
from . import attention as A
from . import blocks_attn as BA
from .common import rms_norm
from .params import PD, init_params, param_specs, param_struct
from .rotary import mrope_positions as _mrope3
from .tp import (Dist, embed_lookup, expand_gqa_kv, expand_gqa_o,
                 expand_gqa_q, gather_logits, logits_local, mask_pad_vocab,
                 psum_dp, psum_tp, replica_info, shard_map,
                 sharded_softmax_xent)


@dataclasses.dataclass
class DecodeBatch:
    """One serving step's device inputs. Two layouts share this container:

    * padded — one row per sequence, (B, T) padded to the longest chunk;
      the packed fields below are all None.
    * packed — ALL sequences flattened into one (1, TT) token stream with
      per-token segment ids; rows of per-type page tables are likewise
      flattened into one stream with per-page owning segments. T/TT are
      interchangeable in the shape comments below.
    """
    tokens: Any            # (B, T) i32
    positions: Any         # (B, T) i32 absolute positions of the new tokens
    seq_lens: Any          # (B,) i32 total kv length after this step
    tables: Dict[str, Any]       # type -> (S, B_loc, P) i32
    page_pos: Dict[str, Any]     # type -> (S, B_loc, P) i32
    write_eids: Dict[str, Any]   # type -> (S, B_loc, T) i32 (<0 drop)
    state_eids: Dict[str, Any]   # type -> (S, B_loc) i32
    mm_embeds: Any = None        # (B, T, d) prefilled vision embeddings
    mm_mask: Any = None          # (B, T) bool
    mrope_pos: Any = None        # (3, B, T)
    last_idx: Any = None         # (B,) index of last valid token (prefill)
    enc_embeds: Any = None       # (B, S_enc, d) enc-dec stub frontend
    enc_write_eids: Any = None   # (S, B_loc, S_enc)
    enc_lens: Any = None         # (B,) — packed: (1, TT) per token
    # ---- packed-stream fields (None in the padded layout) ----
    seg_ids: Any = None          # (1, TT) i32 segment id per token (-1 pad)
    chunk_start: Any = None      # (1, TT) i32 chunk-start position per token
    seg_start_tok: Any = None    # (1, TT) i32 stream idx of segment's first tok
    seg_last_tok: Any = None     # (N_seg,) i32 stream idx of segment's last tok
    page_seg: Any = None         # type -> (S, B_loc, P) i32 owning segment


jax.tree_util.register_dataclass(
    DecodeBatch,
    data_fields=["tokens", "positions", "seq_lens", "tables", "page_pos",
                 "write_eids", "state_eids", "mm_embeds", "mm_mask",
                 "mrope_pos", "last_idx", "enc_embeds", "enc_write_eids",
                 "enc_lens", "seg_ids", "chunk_start", "seg_start_tok",
                 "seg_last_tok", "page_seg"],
    meta_fields=[])


def _dp_spec(dist: Dist):
    return tuple(dist.dp_axes) if len(dist.dp_axes) > 1 else dist.dp_axes[0]


class DecoderLM:
    family_handles = ("dense", "moe", "vlm")

    def __init__(self, cfg: ModelConfig, dist: Dist):
        cfg.validate()
        self.cfg = cfg
        self.dist = dist
        tp = dist.tp
        self.ri = replica_info(cfg.num_heads, cfg.num_kv_heads, tp)
        self.v_local = -(-cfg.vocab_size // tp)
        self.v_pad = self.v_local * tp
        self.period = len(cfg.attn_pattern)
        assert cfg.num_layers % self.period == 0, (cfg.num_layers, self.period)
        self.cycles = cfg.num_layers // self.period
        kinds = cfg.attn_kind_per_layer
        self.period_kinds = kinds[: self.period]
        self.cnt = {
            "full": self.period_kinds.count("full"),
            "swa": self.period_kinds.count("swa"),
        }
        # rank of each period slot within its kind
        self.rank_in_period = []
        seen = {"full": 0, "swa": 0}
        for k in self.period_kinds:
            self.rank_in_period.append(seen[k])
            seen[k] += 1
        self.is_moe = cfg.num_experts > 0
        # FSDP: shard stacked layer weights over "data"; per-layer all_gather
        # in the scan body (transpose = reduce_scatter of grads = ZeRO-2).
        self.fsdp = bool(dist.fsdp) and dist.mesh.shape["data"] > 1
        self._fsdp_dims: Dict[str, int] = {}

    # ----------------------------------------------------------- kv specs
    # Prefix for KV type names — lets several models (speculative decoding
    # draft + target, §6.1) share one Jenga pool without name collisions.
    kv_prefix = ""

    def kv_type_of_kind(self, kind: str) -> str:
        return self.kv_prefix + ("full_attn" if kind == "full" else "swa")

    def kv_specs(self) -> Tuple[KVCacheSpec, ...]:
        cfg, ri = self.cfg, self.ri
        out = []
        n_full = self.cnt["full"] * self.cycles
        n_swa = self.cnt["swa"] * self.cycles
        if n_full:
            out.append(attention_spec(
                self.kv_prefix + "full_attn", num_layers=n_full,
                kv_heads=ri["kv_local"], head_dim=cfg.head_dim,
                tokens_per_page=cfg.tokens_per_page))
        if n_swa:
            out.append(attention_spec(
                self.kv_prefix + "swa", num_layers=n_swa,
                kv_heads=ri["kv_local"], head_dim=cfg.head_dim,
                tokens_per_page=cfg.tokens_per_page,
                kind="swa", sliding_window=cfg.sliding_window))
        return tuple(out)

    def page_shapes(self) -> Dict[str, Tuple[int, ...]]:
        cfg, ri = self.cfg, self.ri
        shp = (2, cfg.tokens_per_page, ri["kv_local"], cfg.head_dim)
        out = {}
        if self.cnt["full"]:
            out[self.kv_prefix + "full_attn"] = shp
        if self.cnt["swa"]:
            out[self.kv_prefix + "swa"] = shp
        return out

    # ----------------------------------------------------------- template
    def template(self):
        cfg, dist, ri = self.cfg, self.dist, self.ri
        tp = dist.tp
        d, hd = cfg.d_model, cfg.head_dim
        L = cfg.num_layers
        ffl = cfg.d_ff // tp

        def stack(key_shape_fn, n=L):
            """Layer-stacked custom init."""
            def fn(key):
                keys = jax.random.split(key, n)
                return jnp.stack([key_shape_fn(k) for k in keys])
            return fn

        qfn = lambda k: expand_gqa_q(k, d, cfg.num_heads, cfg.num_kv_heads, hd, tp)
        kvfn = lambda k: expand_gqa_kv(k, d, cfg.num_kv_heads, hd, tp)
        ofn = lambda k: expand_gqa_o(k, d, cfg.num_heads, cfg.num_kv_heads, hd, tp,
                                     scale=0.02 / (2 * L) ** 0.5)
        layers = {
            "attn_norm": PD((L, d), P(), init="ones"),
            "q": PD((L, tp, d, ri["q_local"] * hd), P(None, "model"),
                    init="custom", fn=stack(qfn)),
            "k": PD((L, tp, d, ri["kv_local"] * hd), P(None, "model"),
                    init="custom", fn=stack(kvfn)),
            "v": PD((L, tp, d, ri["kv_local"] * hd), P(None, "model"),
                    init="custom", fn=stack(kvfn)),
            "o": PD((L, tp, ri["q_local"] * hd, d), P(None, "model"),
                    init="custom", fn=stack(ofn)),
            "mlp_norm": PD((L, d), P(), init="ones"),
        }
        if cfg.qkv_bias:
            layers["q_bias"] = PD((L, tp, ri["q_local"] * hd), P(None, "model"),
                                  init="zeros")
            layers["k_bias"] = PD((L, tp, ri["kv_local"] * hd), P(None, "model"),
                                  init="zeros")
            layers["v_bias"] = PD((L, tp, ri["kv_local"] * hd), P(None, "model"),
                                  init="zeros")
        if self.is_moe:
            # 2-D expert sharding: experts over "data" (EP all_to_all),
            # per-expert FFN over "model" (expert-TP) — fits 100B+ MoEs.
            ffe = cfg.moe_d_ff
            ep_spec = P(None, "data", None, "model")
            layers.update({
                "router": PD((L, d, cfg.num_experts), P()),
                "moe_gate": PD((L, cfg.num_experts, d, ffe), ep_spec),
                "moe_up": PD((L, cfg.num_experts, d, ffe), ep_spec),
                "moe_down": PD((L, cfg.num_experts, ffe, d),
                               P(None, "data", "model"),
                               scale=0.02 / (2 * L) ** 0.5),
            })
        else:
            layers.update({
                "gate": PD((L, tp, d, ffl), P(None, "model")),
                "up": PD((L, tp, d, ffl), P(None, "model")),
                "down": PD((L, tp, ffl, d), P(None, "model"),
                           scale=0.02 / (2 * L) ** 0.5),
            })
        if self.fsdp:
            data = self.dist.mesh.shape["data"]
            for name, pd in layers.items():
                if len(pd.spec) >= 2 and pd.spec[1] == "model" and \
                        len(pd.shape) >= 3:
                    for i in range(2, len(pd.shape)):
                        if pd.shape[i] % data == 0 and pd.shape[i] >= data:
                            spec = list(pd.spec) + [None] * (
                                len(pd.shape) - len(pd.spec))
                            spec[i] = "data"
                            layers[name] = dataclasses.replace(
                                pd, spec=P(*spec))
                            # dim index after scan-slice (drop L) + tp squeeze
                            self._fsdp_dims[name] = i - 2
                            break
        tmpl = {
            "embed": PD((tp, self.v_local, d), P("model")),
            "final_norm": PD((d,), P(), init="ones"),
            "layers": layers,
        }
        if not self.cfg.tie_embeddings:
            tmpl["unembed"] = PD((tp, self.v_local, d), P("model"))
        return tmpl

    # Optional dtype override for float params (serving uses bf16 weights;
    # training keeps fp32 masters). Set via ``model.param_dtype = ...``.
    param_dtype = None

    def _retype(self, tmpl):
        if self.param_dtype is None:
            return tmpl
        from .common import PARAM_DTYPE
        from .params import is_pd

        def go(pd):
            if pd.dtype == PARAM_DTYPE:
                return dataclasses.replace(pd, dtype=self.param_dtype)
            return pd

        return jax.tree.map(go, tmpl, is_leaf=is_pd)

    def init(self, seed=0):
        return init_params(self._retype(self.template()), seed)

    def struct(self):
        return param_struct(self._retype(self.template()))

    def specs(self):
        return param_specs(self.template())

    # ------------------------------------------------------------ helpers
    def _squeeze_params(self, params):
        """Drop the (local size-1) tp dim from expanded-layout params.
        MoE / FSDP leaves shard real dims over "model"/"data" — those local
        dims are > 1 and stay."""
        specs = self.specs()

        def go(a, s):
            for i, ax in enumerate(s):
                if ax == "model" and a.shape[i] == 1:
                    return jnp.squeeze(a, axis=i)
            return a

        return jax.tree.map(go, params, specs)

    def _fsdp_gather(self, pj):
        """FSDP: all_gather the weight shards of one layer before use.

        Perf hillclimb (EXPERIMENTS.md #A1): gather in bf16 — compute casts
        weights to bf16 anyway, so casting BEFORE the gather is lossless for
        the step math and halves FSDP's dominant collective bytes."""
        if not self._fsdp_dims:
            return pj
        out = dict(pj)
        for name, dim in self._fsdp_dims.items():
            if name in out:
                w = out[name]
                if w.dtype == jnp.float32:
                    w = w.astype(jnp.bfloat16)
                out[name] = jax.lax.all_gather(w, "data", axis=dim,
                                               tiled=True)
        return out

    def _unembed(self, params):
        return params.get("unembed", params["embed"])

    def _stacked(self, p_layers):
        """(L, ...) -> (cycles, period, ...) for scan xs."""
        return jax.tree.map(
            lambda a: a.reshape(self.cycles, self.period, *a.shape[1:]),
            p_layers)

    # --------------------------------------------------------------- train
    def train_loss(self, params, tokens, targets, *, mm_embeds=None,
                   mm_mask=None, mrope_pos=None):
        """Global arrays in; replicated scalar loss out."""
        cfg, dist = self.cfg, self.dist
        dp = _dp_spec(dist)
        in_specs = (self.specs(), P(dp), P(dp))
        args = [params, tokens, targets]
        extra_specs = []
        if cfg.family == "vlm" and mm_embeds is not None:
            extra_specs = [P(dp), P(dp), P(None, dp)]
            args += [mm_embeds, mm_mask, mrope_pos]
        fn = shard_map(
            partial(self._train_body, has_mm=bool(extra_specs)),
            mesh=dist.mesh,
            in_specs=tuple(in_specs) + tuple(extra_specs),
            out_specs=P(),
            check_vma=False,
        )
        return fn(*args)

    def _train_body(self, params, tokens, targets, *mm, has_mm=False):
        cfg, dist = self.cfg, self.dist
        params = self._squeeze_params(params)
        b, t = tokens.shape
        x = embed_lookup(tokens, params["embed"], dist)
        mrope_pos = None
        if has_mm:
            mm_embeds, mm_mask, mrope_pos = mm
            x = jnp.where(mm_mask[..., None], mm_embeds.astype(x.dtype), x)
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        stacked = self._stacked(params["layers"])

        def cycle_body(carry, xs):
            x, aux = carry
            layer_params = xs
            for j, kind in enumerate(self.period_kinds):
                pj = self._fsdp_gather(jax.tree.map(lambda a: a[j],
                                                    layer_params))
                window = cfg.sliding_window if kind == "swa" else 0
                x = BA.attn_train(
                    pj, x, dist, kv_local=self.ri["kv_local"],
                    head_dim=cfg.head_dim, window=window,
                    rope_theta=cfg.rope_theta, positions=positions,
                    mrope_positions=mrope_pos, norm_eps=cfg.norm_eps)
                if self.is_moe:
                    x, a = BA.moe_block(
                        pj, x, dist, num_experts=cfg.num_experts,
                        top_k=cfg.experts_per_token,
                        capacity_factor=cfg.capacity_factor,
                        norm_eps=cfg.norm_eps,
                        aux_weight=cfg.router_aux_weight)
                    aux = aux + a
                else:
                    x = BA.mlp_block(pj, x, dist, cfg.norm_eps)
            return (x, aux), None

        cycle_body = jax.checkpoint(cycle_body)
        (x, aux), _ = jax.lax.scan(cycle_body, (x, jnp.float32(0.0)), stacked)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = logits_local(x, self._unembed(params))
        loss = sharded_softmax_xent(logits, targets, dist)
        loss = psum_dp(loss, dist) / dist.dp
        aux = psum_dp(aux / max(1, self.cycles), dist) / dist.dp
        return loss + aux

    # --------------------------------------------------------------- serve
    def serve_step(self, params, buffer, batch: DecodeBatch, *,
                   prefill: bool, attention_impl: str = "ref"):
        """One serving step over a MIXED batch: rows are independent
        sequences with ragged per-row token counts (concurrent prefill
        chunks and single-token decodes share the dispatch). Correctness is
        carried by per-row data, not a global phase: absolute ``positions``
        (SENTINEL at padded slots — never attended), per-row chunk starts
        for the old-page mask, ``last_idx`` to pick each row's logits, and
        negative ``write_eids`` to drop padded writes. The ``prefill`` flag
        only selects the kernel schedule (chunked flash vs materialized
        T=1 decode), never the masking semantics.

        PACKED layout (``batch.seg_ids`` is not None): the whole step is one
        (1, TT) token stream; per-token/per-segment arrays are replicated
        across the dp axis and logits come back one row PER SEGMENT (in
        plan order) instead of per batch row.

        Returns (logits (B or N_seg, V_pad), buffer)."""
        cfg, dist = self.cfg, self.dist
        dp = _dp_spec(dist)
        sp = dist.sp
        packed = batch.seg_ids is not None
        bspec = P(None) if (sp or packed) else P(dp)
        shard_dim_spec = "data" if sp else dp
        batch_specs = DecodeBatch(
            tokens=bspec, positions=bspec, seq_lens=bspec,
            tables={k: P(shard_dim_spec, "model") for k in batch.tables},
            page_pos={k: P(shard_dim_spec, "model") for k in batch.page_pos},
            write_eids={k: P(shard_dim_spec, "model")
                        for k in batch.write_eids},
            state_eids={k: P(shard_dim_spec) for k in batch.state_eids},
            mm_embeds=bspec if batch.mm_embeds is not None else None,
            mm_mask=bspec if batch.mm_mask is not None else None,
            mrope_pos=P(None, *([None] if (sp or packed) else [dp])) if batch.mrope_pos is not None else None,
            last_idx=bspec if batch.last_idx is not None else None,
            enc_embeds=bspec if batch.enc_embeds is not None else None,
            enc_write_eids=(P(shard_dim_spec, "model")
                            if batch.enc_write_eids is not None else None),
            enc_lens=bspec if batch.enc_lens is not None else None,
            seg_ids=bspec if packed else None,
            chunk_start=bspec if packed else None,
            seg_start_tok=bspec if packed else None,
            seg_last_tok=P(None) if packed else None,
            page_seg=({k: P(shard_dim_spec, "model") for k in batch.page_seg}
                      if packed else None),
        )
        buf_spec = P(shard_dim_spec, "model")
        out_logit_spec = (P(None, "model") if (sp or packed)
                          else P(dp, "model"))
        fn = shard_map(
            partial(self._serve_body, prefill=prefill,
                    attention_impl=attention_impl),
            mesh=dist.mesh,
            in_specs=(self.specs(), buf_spec, batch_specs),
            out_specs=(out_logit_spec, buf_spec),
            check_vma=False,
        )
        return fn(params, buffer, batch)

    def _layer_views(self, buffer_flat):
        """Per-type reshape views of the unified buffer (paper Fig. 7c):
        type t sees (total_units // S_t, num_layers_t, *page_shape)."""
        specs = self.kv_specs()
        shapes = self.page_shapes()
        total = buffer_flat.shape[-1]
        views = {}
        for s in specs:
            assert total % s.page_units == 0, (
                f"buffer ({total}u) must be a multiple of every small-page "
                f"size (LCM geometry); {s.name} page = {s.page_units}u")
            vp = total // s.page_units
            views[s.name] = (vp, s.num_layers) + shapes[s.name]
        return views

    def _serve_body(self, params, buffer, batch: DecodeBatch, *, prefill,
                    attention_impl="ref"):
        cfg, dist = self.cfg, self.dist
        params = self._squeeze_params(params)
        buffer = buffer.reshape(buffer.shape[-1])          # local flat units
        tokens = batch.tokens
        b, t = tokens.shape
        positions = batch.positions
        x = embed_lookup(tokens, params["embed"], dist)
        mrope_pos = batch.mrope_pos
        if batch.mm_embeds is not None:
            x = jnp.where(batch.mm_mask[..., None],
                          batch.mm_embeds.astype(x.dtype), x)
        views = self._layer_views(buffer)
        stacked = self._stacked(params["layers"])
        sq = lambda a: jnp.squeeze(a, axis=(0, 1))         # drop shard dims
        tables = {k: sq(v) for k, v in batch.tables.items()}
        page_pos = {k: sq(v) for k, v in batch.page_pos.items()}
        write_eids = {k: sq(v) for k, v in batch.write_eids.items()}
        packed = batch.seg_ids is not None
        page_seg = ({k: sq(v) for k, v in batch.page_seg.items()}
                    if packed else {})
        sp_axis = "data" if dist.sp else None
        ri = self.ri
        kv_groups = (None if ri["repl"] == 1 else
                     A.replica_groups(ri["kv_tp"], ri["repl"]))

        def cycle_body(carry, xs):
            x, buf = carry
            layer_params, cycle = xs
            # phase 1: ALL gathers (buffer reads) before any write — keeps
            # the pool carry in-place (EXPERIMENTS.md buffer-copy study)
            gathered = []
            for j, kind in enumerate(self.period_kinds):
                tname = self.kv_type_of_kind(kind)
                layer_in_type = cycle * self.cnt[kind] + self.rank_in_period[j]
                gathered.append(BA.attn_gather(
                    buf, views[tname], tables[tname], page_pos[tname],
                    layer_in_type, page_seg.get(tname)))
            writes = []
            for j, kind in enumerate(self.period_kinds):
                pj = self._fsdp_gather(jax.tree.map(lambda a: a[j],
                                                    layer_params))
                tname = self.kv_type_of_kind(kind)
                layer_in_type = cycle * self.cnt[kind] + self.rank_in_period[j]
                window = cfg.sliding_window if kind == "swa" else 0
                x, k, v = BA.attn_compute(
                    pj, x, gathered[j], dist,
                    kv_local=self.ri["kv_local"], head_dim=cfg.head_dim,
                    positions=positions, seq_lens=batch.seq_lens,
                    window=window, rope_theta=cfg.rope_theta,
                    mrope_positions=mrope_pos, norm_eps=cfg.norm_eps,
                    prefill=prefill, sp_axis=sp_axis, kv_groups=kv_groups,
                    seg_ids=batch.seg_ids, chunk_start=batch.chunk_start,
                    impl=attention_impl)
                writes.append((tname, layer_in_type, k, v))
                if self.is_moe:
                    x, _ = BA.moe_block(
                        pj, x, dist, num_experts=cfg.num_experts,
                        top_k=cfg.experts_per_token,
                        capacity_factor=cfg.capacity_factor,
                        norm_eps=cfg.norm_eps)
                else:
                    x = BA.mlp_block(pj, x, dist, cfg.norm_eps)
            # phase 3: all writes at the end of the iteration
            for tname, layer_in_type, k, v in writes:
                buf = BA.attn_write(buf, views[tname], layer_in_type,
                                    write_eids[tname], positions, k, v)
            return (x, buf), None

        (x, buffer), _ = jax.lax.scan(
            cycle_body, (x, buffer), (stacked, jnp.arange(self.cycles)))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if packed:
            # one logits row per SEGMENT: its last token in the stream
            x = jnp.take(x[0], batch.seg_last_tok, axis=0)[:, None]
        elif batch.last_idx is not None:
            x = jnp.take_along_axis(
                x, batch.last_idx[:, None, None].astype(jnp.int32), axis=1)
        else:
            x = x[:, -1:]
        logits = logits_local(x, self._unembed(params))[:, 0]  # (B, V_loc)
        logits = mask_pad_vocab(logits, cfg.vocab_size, dist)
        return logits, buffer.reshape(1, 1, -1)
