"""Shared raw-JAX building blocks (no flax): init, norms, linear, sharding."""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32   # master params fp32; compute casts to bf16


# ----------------------------------------------------------------- initializers
def normal_init(key, shape, scale=0.02, dtype=PARAM_DTYPE):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(key, shape, dtype=PARAM_DTYPE):
    del key
    return jnp.zeros(shape, dtype=dtype)


def ones_init(key, shape, dtype=PARAM_DTYPE):
    del key
    return jnp.ones(shape, dtype=dtype)


# ------------------------------------------------------------------------ norms
def rms_norm(x, weight, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------- linear
def dense(x, w, b=None):
    """x: (..., in), w: (in, out) — compute in bf16, accumulate fp32."""
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, w_down)


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = dense(x, w_up, b_up)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(h, w_down, b_down)


# ------------------------------------------------------------------- GQA layout
def gqa_tp_layout(num_heads: int, num_kv_heads: int, tp: int
                  ) -> Tuple[int, int, int, int]:
    """Head layout for tensor parallelism over ``tp`` shards.

    Returns (q_pad, q_local, kv_tp, kv_local):
      kv_tp    — how many ways the KV heads are really sharded (gcd);
      kv_local — KV heads stored per device (replicated tp/kv_tp times);
      q_pad    — padded q heads = num_kv_heads * group_pad, divisible by tp
                 with GQA group alignment; q_local = q_pad // tp.
    """
    kv_tp = math.gcd(num_kv_heads, tp)
    kv_local = num_kv_heads // kv_tp
    repl = tp // kv_tp
    group = num_heads // num_kv_heads
    group_pad = -(-group // repl) * repl
    q_pad = num_kv_heads * group_pad
    q_local = q_pad // tp
    assert q_pad % tp == 0
    return q_pad, q_local, kv_tp, kv_local


def pad_heads(w, num_heads: int, q_pad: int, axis: int):
    """Zero-pad a per-head parameter from num_heads to q_pad heads, with GQA
    group-aligned placement: head h of group g goes to slot
    g*group_pad + (h - g*group)."""
    if q_pad == num_heads:
        return w
    # callers pre-arrange weights into (.., num_kv_heads, group, ..) and pad
    raise NotImplementedError  # handled at init time via padded group layout


# --------------------------------------------------------------------- sharding
def logical_sharding(mesh, *spec):
    """NamedSharding helper. ``spec`` entries are axis names or None."""
    from jax.sharding import NamedSharding
    return NamedSharding(mesh, P(*spec))


def tree_size(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def tree_bytes(params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))


def cross_entropy_loss(logits, targets, mask=None):
    """logits (..., V) fp32; targets int; mean over mask."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
