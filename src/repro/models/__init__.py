from .lm import DecodeBatch, DecoderLM
from .registry import build_model
from .tp import Dist, single_device_dist
