"""Mini param-definition framework: one template tree drives real init,
abstract ShapeDtypeStruct init (dry-run), and PartitionSpec trees."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import PARAM_DTYPE


@dataclasses.dataclass
class PD:
    """One parameter definition."""

    shape: Tuple[int, ...]
    spec: P = P()
    init: str = "normal"             # normal | zeros | ones | custom
    scale: float = 0.02
    fn: Optional[Callable[[jax.Array], jax.Array]] = None  # custom init
    dtype: Any = PARAM_DTYPE


def _init_leaf(pd: PD, key):
    if pd.init == "custom":
        out = pd.fn(key)
        assert out.shape == pd.shape, (out.shape, pd.shape)
        return out.astype(pd.dtype)
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, pd.dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, pd.dtype)
    return (pd.scale * jax.random.normal(key, pd.shape)).astype(pd.dtype)


def is_pd(x):
    return isinstance(x, PD)


def init_params(template, seed: int = 0):
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_pd)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    vals = [_init_leaf(pd, k) for pd, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_struct(template):
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype), template,
        is_leaf=is_pd)


def param_specs(template):
    return jax.tree.map(lambda pd: pd.spec, template, is_leaf=is_pd)


def sharded_init(template, mesh, seed: int = 0):
    """Init each param directly with its target sharding (avoids a host
    gather; fine on 1 device too)."""
    from jax.sharding import NamedSharding

    def one(pd: PD, key):
        shard = NamedSharding(mesh, pd.spec)
        return jax.jit(lambda k: _init_leaf(pd, k),
                       out_shardings=shard)(key)

    leaves, treedef = jax.tree.flatten(template, is_leaf=is_pd)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    vals = [one(pd, k) for pd, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)
