"""Attention math (local, inside shard_map): flash-style chunked attention
for train/prefill, paged gather attention for decode, partial-softmax
combining for sequence-parallel long-context decode.

Local GQA convention: q is (B, T, KVL, G, D) — KVL local kv heads, G padded
q-heads-per-kv-head on this device; k/v are (B, S, KVL, D).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def group_q(q, kv_local: int):
    """(B, T, q_local, D) -> (B, T, KVL, G, D)."""
    b, t, ql, d = q.shape
    assert ql % kv_local == 0
    return q.reshape(b, t, kv_local, ql // kv_local, d)


def segment_mask(q_seg, q_pos, kv_seg, kv_pos, *, window=0, chunk_start=None):
    """Attention mask for a PACKED token stream: several independent
    segments (sequences) share one batch row, identified by per-token /
    per-slot segment ids. Token i may attend slot j iff both belong to the
    same segment and j is not in i's future.

    q_seg: (B, T); kv_seg: (B, S); q_pos: (B, T); kv_pos: (B, S) — absolute
    positions within each token's own sequence. Padded q tokens carry seg id
    -1 and padded kv slots -2, so pads never match anything (including each
    other). chunk_start: (B, T) per-token start position of the token's
    current chunk — when given, slots are valid iff kv_pos < chunk_start
    (strictly before the chunk: the chunk's own slots come via the fresh-KV
    path); when None the in-chunk causal rule kv_pos <= q_pos applies.
    window > 0 adds the sliding-window bound kv_pos > q_pos - window.
    Returns (B, T, S) bool."""
    mask = q_seg[:, :, None] == kv_seg[:, None, :]
    if chunk_start is not None:
        mask &= kv_pos[:, None, :] < chunk_start[:, :, None]
    else:
        mask &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        mask &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    return mask


# --------------------------------------------------------------------- flash
def flash_attention_partials(
    q, k, v, *, causal: bool = True, window: int = 0,
    q_offset=0, kv_len: Optional[jax.Array] = None,
    block: int = 512,
):
    """Chunked online-softmax attention (pure jnp; Pallas kernel on TPU).
    Returns un-normalized partials (acc (B,KVL,G,T,D), m, l).

    q: (B, T, KVL, G, D); k, v: (B, S, KVL, D).
    q position of row i = q_offset + i; kv position of col j = j.
    window > 0 = sliding window (attend to positions > qpos - window).
    kv_len: (B,) valid kv length mask (padding beyond).
    """
    b, t, kvl, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    qf = (q * scale).astype(q.dtype)

    nblk = -(-s // block)
    pad = nblk * block - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block, kvl, d)
    vb = v.reshape(b, nblk, block, kvl, d)

    q_pos = q_offset + jnp.arange(t)                      # (T,)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, j0 = blk                              # (B, blk, KVL, D)
        kv_pos = j0 + jnp.arange(block)                   # (blk,)
        logit = jnp.einsum("btkgd,bjkd->bkgtj", qf, kblk,
                           preferred_element_type=jnp.float32)
        mask = jnp.ones((t, block), dtype=bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask = jnp.broadcast_to(mask, (b, kvl, g, t, block))
        if kv_len is not None:
            mask &= (kv_pos[None, :] < kv_len[:, None])[:, None, None, None, :]
        logit = jnp.where(mask, logit, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logit, axis=-1))
        p = jnp.exp(logit - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgtj,bjkd->bkgtd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvl, g, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvl, g, t), jnp.float32)
    a0 = jnp.zeros((b, kvl, g, t, d), jnp.float32)
    blk_starts = jnp.arange(nblk) * block
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), blk_starts),
    )
    return acc, m, l


def flash_attention(q, k, v, **kw):
    """Normalized flash attention -> (B, T, KVL, G, D) in q.dtype."""
    acc, m, l = flash_attention_partials(q, k, v, **kw)
    return finalize_softmax(acc, l).astype(q.dtype)


def merge_partials(o1, m1, l1, o2, m2, l2):
    """Merge two partial-softmax results (local, no collective)."""
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    out = o1 * c1[..., None] + o2 * c2[..., None]
    return out, m, l1 * c1 + l2 * c2


# ------------------------------------------------------------------- decode
def attend_tokens(q, k, v, mask):
    """Materialized attention for short T (decode T=1).

    q: (B, T, KVL, G, D); k/v: (B, S, KVL, D); mask: (B, T, S) bool.
    Returns (out, m, l) for partial-softmax combining."""
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    logit = jnp.einsum("btkgd,bskd->bkgts", q * scale, k,
                       preferred_element_type=jnp.float32)
    logit = jnp.where(mask[:, None, None], logit, NEG_INF)
    m = jnp.max(logit, axis=-1)
    p = jnp.exp(logit - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bkgts,bskd->bkgtd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out, m, l


def finalize_softmax(out, l):
    out = out / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1)                        # (B, T, KVL, G, D)


def combine_partials(out, m, l, axis_name: str, groups=None):
    """Flash-decoding combine across an axis (sequence-parallel decode /
    replica-group KV split). ``groups`` restricts the reduction to
    axis_index_groups (e.g. KV-replica subgroups of the tp axis).
    Returns (out, m, l) rescaled to the group max."""
    gmax = jax.lax.pmax(m, axis_name, axis_index_groups=groups)
    corr = jnp.exp(m - gmax)
    out = jax.lax.psum(out * corr[..., None], axis_name,
                       axis_index_groups=groups)
    l = jax.lax.psum(l * corr, axis_name, axis_index_groups=groups)
    return out, gmax, l


def replica_groups(kv_tp: int, repl: int):
    """tp-axis index groups [[kg*repl .. kg*repl+repl-1] ...] — the KV
    replica sets that jointly hold one kv-head group's pages."""
    return [[kg * repl + r for r in range(repl)] for kg in range(kv_tp)]


# -------------------------------------------------------------- paged cache
# Write strategy for the unified buffer:
#   "scatter"  — gather-scatter (.at[].set). In-place on TPU (donated buffer
#                scatter aliases); XLA:CPU inserts 2 pool copies.
#   "dus"      — flat dynamic_update_slice writes (loop over seqs / pages).
#                Proven 0-copy on CPU (see /tmp experiments + EXPERIMENTS.md);
#                used by the dry-run so memory_analysis reflects the TPU
#                in-place behaviour. Requires page-aligned prefill chunks.
_WRITE_MODE = ["scatter"]


def set_write_mode(mode: str):
    assert mode in ("scatter", "dus")
    _WRITE_MODE[0] = mode


def view_offset(view_shape, eid, layer, sel, slot):
    """Flat-buffer offset of (eid, layer, sel, slot, 0, 0) in an attention
    view (VP, L, 2, TPP, KVL, D). int64 math — pools exceed 2^31 units
    (requires jax_enable_x64 in the dry-run process)."""
    vp, nl, _, tpp, kvl, d = view_shape
    eid = eid.astype(jnp.int64) if hasattr(eid, "astype") else eid
    return ((((eid * nl + layer) * 2 + sel) * tpp) + slot) * kvl * d


def gather_pages(view, tables, layer):
    """view: (VP, L, 2, TPP, KVL, D); tables: (B, P) int32 (entries < 0 are
    invalid pads/frees). Returns k, v: (B, P*TPP, KVL, D).

    Layer is sliced BEFORE the page gather so the gather only moves this
    layer's bytes (the slice itself is free).

    Invalid entries are ZEROED, not merely masked downstream: the clamped
    gather would otherwise read arbitrary units of the unified buffer —
    including other types' pages, e.g. fp32 recurrent state bitcast into
    bf16 pairs, whose halves can decode as NaN. A NaN V poisons the
    partial-softmax merge even for fully-masked rows (exp(0)*NaN, and
    NaN*0 == NaN in the rescale). VALID pages are safe without an isnan
    scrub because the runner zero-initialises every freshly allocated page
    (ModelRunner.zero_pages) before its first dispatch."""
    lview = jax.lax.dynamic_index_in_dim(view, layer, axis=1, keepdims=False)
    pages = jnp.take(lview, jnp.maximum(tables, 0), axis=0)  # (B,P,2,TPP,KVL,D)
    valid = (tables >= 0)[:, :, None, None, None, None]
    pages = jnp.where(valid, pages, 0)
    k = pages[:, :, 0]
    v = pages[:, :, 1]
    b, p, tpp, kvl, d = k.shape
    return k.reshape(b, p * tpp, kvl, d), v.reshape(b, p * tpp, kvl, d)


def write_token_kv(buf, view_shape, layer, eids, slots, k_new, v_new):
    """Write T new tokens per sequence into their pages.

    buf: flat (U,) unified buffer (the scan carry); view_shape:
    (VP, L, 2, TPP, KVL, D); eids: (B, T) exec page id per new token (<0 =
    drop, e.g. non-owner shard in the replica-split); slots: (B, T) slot
    within page; k_new/v_new: (B, T, KVL, D). Returns the updated flat buf."""
    if _WRITE_MODE[0] == "scatter":
        view = buf.reshape(view_shape)
        vp, nl, _, tpp, kvl, d = view_shape
        b, t = eids.shape
        eids_f = jnp.where(eids < 0, vp, eids).reshape(-1)    # OOB -> dropped
        slot_f = slots.reshape(-1)
        kf = k_new.reshape(b * t, kvl, d).astype(view.dtype)
        vf = v_new.reshape(b * t, kvl, d).astype(view.dtype)
        layer_f = jnp.full((b * t,), layer, jnp.int32)
        view = view.at[eids_f, layer_f, 0, slot_f].set(
            kf, mode="drop", unique_indices=False)
        view = view.at[eids_f, layer_f, 1, slot_f].set(
            vf, mode="drop", unique_indices=False)
        return view.reshape(buf.shape)
    return _write_token_kv_dus(buf, view_shape, layer, eids, slots,
                               k_new, v_new)


def _write_token_kv_dus(buf, view_shape, layer, eids, slots, k_new, v_new):
    """Flat dynamic_update_slice writes (0-copy on every backend).

    Drop semantics (<0 eids) redirect the write into the SCRATCH page — the
    final small page of the buffer, reserved by the runner/dry-run sizing —
    so no read-modify-write is needed (reads before in-place writes force
    pool copies in XLA buffer assignment).

    Decode (T==1): one dus row per sequence. Prefill (T>1): fori_loop over
    (seq, page) writing whole page-layer slices — requires the chunk to start
    page-aligned (guaranteed by the runner in dus mode)."""
    vp, nl, _, tpp, kvl, d = view_shape
    b, t = eids.shape
    row = kvl * d
    total = buf.shape[0]
    kf = k_new.astype(buf.dtype)
    vf = v_new.astype(buf.dtype)
    if t == 1:
        for bi in range(b):
            eid = eids[bi, 0]
            slot = slots[bi, 0]
            for sel, data in ((0, kf), (1, vf)):
                off = view_offset(view_shape, jnp.maximum(eid, 0), layer,
                                  sel, slot)
                off = jnp.where(eid >= 0, off, total - row)   # -> scratch
                buf = jax.lax.dynamic_update_slice(
                    buf, data[bi, 0].reshape(row), (off,))
        return buf
    # prefill: page-granular writes
    npg = -(-t // tpp)
    pad = npg * tpp - t
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        eids = jnp.pad(eids, ((0, 0), (0, pad)), constant_values=-1)
    kp = kf.reshape(b, npg, tpp * row)
    vp_data = vf.reshape(b, npg, tpp * row)
    page_eids = eids[:, ::tpp]                                 # (B, npg)
    page_sz = tpp * row

    def body(j, buf):
        bi = j // npg
        pg = j % npg
        eid = page_eids[bi, pg]
        for sel, data in ((0, kp), (1, vp_data)):
            off = view_offset(view_shape, jnp.maximum(eid, 0), layer, sel, 0)
            off = jnp.where(eid >= 0, off, total - page_sz)
            buf = jax.lax.dynamic_update_slice(
                buf, jax.lax.dynamic_slice(data, (bi, pg, 0),
                                           (1, 1, page_sz)).reshape(page_sz),
                (off,))
        return buf

    return jax.lax.fori_loop(0, b * npg, body, buf)


def bf16_pair_to_f32(x):
    """(..., 2U) bf16 -> (..., U) f32 bitcast (exact fp32 state storage
    inside the bf16 unified buffer; 1 fp32 state unit = 2 buffer units)."""
    assert x.dtype == jnp.bfloat16 and x.shape[-1] % 2 == 0
    return jax.lax.bitcast_convert_type(
        x.reshape(*x.shape[:-1], x.shape[-1] // 2, 2), jnp.float32)


def f32_to_bf16_pair(x):
    assert x.dtype == jnp.float32
    y = jax.lax.bitcast_convert_type(x, jnp.bfloat16)  # (..., U, 2)
    return y.reshape(*x.shape[:-1], x.shape[-1] * 2)


def read_state(view, layer, eids):
    """State view: (VP, L, 2U) bf16. eids: (B,). Returns (B, U) f32.
    Invalid (< 0, padded-row) eids read as zero state — the clamped gather
    would otherwise hand NaN-decoding foreign bytes to the recurrent scan."""
    lview = jax.lax.dynamic_index_in_dim(view, layer, axis=1, keepdims=False)
    st = jnp.take(lview, jnp.maximum(eids, 0), axis=0)        # (B, 2U)
    st = jnp.where((eids >= 0)[:, None], st, 0)
    return bf16_pair_to_f32(st)


def write_state(buf, view_shape, layer, eids, state):
    """state: (B, U) f32, stored as bit-exact fp32 pairs.
    buf: flat unified buffer; view_shape: (VP, L, 2U)."""
    vp, nl, u2 = view_shape
    data = f32_to_bf16_pair(state.astype(jnp.float32)).astype(buf.dtype)
    if _WRITE_MODE[0] == "scatter":
        view = buf.reshape(view_shape)
        b = eids.shape[0]
        layer_f = jnp.full((b,), layer, jnp.int32)
        eids_s = jnp.where(eids < 0, vp, eids)
        view = view.at[eids_s, layer_f].set(
            data, mode="drop", unique_indices=False)
        return view.reshape(buf.shape)
    total = buf.shape[0]
    for bi in range(eids.shape[0]):
        eid = eids[bi]
        off = (jnp.maximum(eid, 0).astype(jnp.int64) * nl + layer) * u2
        off = jnp.where(eid >= 0, off, total - u2)            # -> scratch
        buf = jax.lax.dynamic_update_slice(buf, data[bi], (off,))
    return buf
