"""Pure-jnp oracle for the paged decode attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, kv_view, tables, page_pos, positions, *,
                               window: int = 0):
    """Same contract as kernel.paged_decode_attention."""
    b, kvl, g, d = q.shape
    vp, _, tpp, _, _ = kv_view.shape
    pages = jnp.take(kv_view, jnp.maximum(tables, 0), axis=0)
    # (B, P, 2, TPP, KVL, D)
    k = pages[:, :, 0].reshape(b, -1, kvl, d).astype(jnp.float32)
    v = pages[:, :, 1].reshape(b, -1, kvl, d).astype(jnp.float32)
    slot_pos = (page_pos[:, :, None]
                + jnp.arange(tpp)[None, None, :]).reshape(b, -1)
    mask = slot_pos <= positions[:, None]
    if window:
        mask &= slot_pos > (positions[:, None] - window)
    scale = 1.0 / (d ** 0.5)
    logit = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32) * scale, k)
    logit = jnp.where(mask[:, None, None, :], logit, NEG_INF)
    m = jnp.max(logit, axis=-1, keepdims=True)
    p = jnp.exp(logit - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30), v)
    return out.astype(q.dtype)
