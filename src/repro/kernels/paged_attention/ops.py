"""Jitted wrapper: Pallas kernel on TPU, interpret-mode kernel elsewhere."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import paged_decode_attention
from .ref import paged_decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("window", "use_kernel"))
def paged_decode(q, kv_view, tables, page_pos, positions, *, window=0,
                 use_kernel=True):
    if use_kernel:
        return paged_decode_attention(
            q, kv_view, tables, page_pos, positions, window=window,
            interpret=not _on_tpu())
    return paged_decode_attention_ref(
        q, kv_view, tables, page_pos, positions, window=window)
