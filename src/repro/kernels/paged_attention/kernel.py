"""Pallas TPU kernel: paged decode attention over the Jenga unified buffer.

One query token per sequence attends to its pages (exec ids from the block
table). TPU adaptation of PagedAttention's CUDA gather loops:
  * the block table rides in SMEM via PrefetchScalarGridSpec — the page
    BlockSpec's index_map reads it to stream exactly this sequence's pages
    HBM->VMEM (no materialized gather);
  * page slices are (TPP, KVL*D) tiles — lane dim 128-aligned by
    construction (head_dim 128/64, tokens_per_page >= 8);
  * online softmax state (m, l, acc) lives in VMEM scratch and persists
    across the sequential page-grid dimension.

Grid: (B, P) — P pages per sequence, iterated innermost (sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(tables_ref, page_pos_ref, positions_ref,   # scalar prefetch
            q_ref, kv_ref, o_ref,                      # VMEM refs
            m_ref, l_ref, acc_ref,                     # scratch
            *, tokens_per_page: int, window: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    n_pages = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (KVL, G, D)
    kvl, g, d = q.shape
    kv = kv_ref[0]                                     # (2, TPP, KVL, D)
    k = kv[0].astype(jnp.float32)                      # (TPP, KVL, D)
    v = kv[1].astype(jnp.float32)

    scale = 1.0 / (d ** 0.5)
    logit = jnp.einsum("kgd,tkd->kgt", q * scale, k)   # (KVL, G, TPP)

    base = page_pos_ref[b, p]
    qpos = positions_ref[b]
    slot_pos = base + jax.lax.broadcasted_iota(jnp.int32, (tokens_per_page,), 0)
    mask = slot_pos <= qpos
    if window:
        mask &= slot_pos > qpos - window
    logit = jnp.where(mask[None, None, :], logit, NEG_INF)

    m_prev = m_ref[...]                                # (KVL, G)
    m_new = jnp.maximum(m_prev, jnp.max(logit, axis=-1))
    pexp = jnp.exp(logit - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + \
        jnp.einsum("kgt,tkd->kgd", pexp, v)
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_decode_attention(q, kv_view, tables, page_pos, positions, *,
                           window: int = 0, interpret: bool = True):
    """q: (B, KVL, G, D); kv_view: (VP, 2, TPP, KVL, D) — ONE layer's view of
    the unified buffer; tables: (B, P) exec ids (<0 masked); page_pos: (B, P)
    absolute position of each page's first token (huge sentinel when
    invalid); positions: (B,) query positions. Returns (B, KVL, G, D)."""
    b, kvl, g, d = q.shape
    vp, _, tpp, kvl2, d2 = kv_view.shape
    assert (kvl, d) == (kvl2, d2)
    n_pages = tables.shape[1]
    tables_safe = jnp.maximum(tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec((1, kvl, g, d), lambda bi, p, *_: (bi, 0, 0, 0)),
            pl.BlockSpec((1, 2, tpp, kvl, d),
                         lambda bi, p, tables_ref, *_:
                         (tables_ref[bi, p], 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kvl, g, d), lambda bi, p, *_: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvl, g), jnp.float32),
            pltpu.VMEM((kvl, g), jnp.float32),
            pltpu.VMEM((kvl, g, d), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, tokens_per_page=tpp, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvl, g, d), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(tables_safe, page_pos.astype(jnp.int32), positions.astype(jnp.int32),
      q, kv_view)
