"""Pallas version compat shared by all kernels (one place to fix the next
rename): ``pltpu.TPUCompilerParams`` became ``pltpu.CompilerParams``."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
