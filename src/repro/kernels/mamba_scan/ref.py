"""Pure-jnp oracle: token-sequential Mamba2 recurrence."""
import jax
import jax.numpy as jnp


def mamba_scan_ref(x, bm, cm, dt, a_log):
    """Sequential recurrence: S_t = exp(-dt_t e^{A}) S + dt_t x_t B_t^T;
    y_t = C_t . S_t."""
    b, t, h, p = x.shape
    n = bm.shape[-1]
    decay_rate = -jnp.exp(a_log.astype(jnp.float32))        # (H,)

    def step(s, inp):
        xt, bt, ct, dtt = inp                               # (B,H,P),(B,N)...
        dec = jnp.exp(dtt * decay_rate[None])               # (B,H)
        s = s * dec[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bn,bhpn->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1)                           # (B,T,H,P)
