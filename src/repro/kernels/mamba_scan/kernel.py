"""Pallas TPU kernel: Mamba2 SSD chunk step (intra-chunk quadratic +
inter-chunk state carry), the MXU-native form of the selective scan
(DESIGN.md §3 hardware adaptation).

Grid: (B, H, n_chunks) — chunks innermost (sequential); the (P, N) state
persists in VMEM scratch across chunk steps."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams


def _kernel(x_ref, b_ref, c_ref, dt_ref, alog_ref, y_ref, s_ref,
            *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (L, P)
    bm = b_ref[0, 0].astype(jnp.float32)         # (L, N)
    cm = c_ref[0, 0].astype(jnp.float32)         # (L, N)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (L,)
    a_log = alog_ref[0]                          # scalar

    ldec = dt * (-jnp.exp(a_log))                # (L,) <= 0
    lcum = jnp.cumsum(ldec)
    cb = cm @ bm.T                               # (L, L)
    diff = lcum[:, None] - lcum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    dec = jnp.where(mask, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    score = cb * dec * dt[None, :]
    y = score @ x                                # (L, P)
    # inter-chunk read
    y += (cm * jnp.exp(lcum)[:, None]) @ s_ref[...].T
    # state update
    sfac = jnp.exp(lcum[-1] - lcum) * dt         # (L,)
    s_ref[...] = s_ref[...] * jnp.exp(lcum[-1]) + (sfac[:, None] * x).T @ bm
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def mamba_chunk_scan(x, bm, cm, dt, a_log, *, chunk=64, interpret=True):
    """x: (B, T, H, P); bm/cm: (B, T, N); dt: (B, T, H) (post-softplus);
    a_log: (H,). Returns y: (B, T, H, P) (before D-residual/gating)."""
    b, t, h, p = x.shape
    n = bm.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    xg = jnp.moveaxis(x.reshape(b, nc, chunk, h, p), 3, 1)   # (B,H,nc,L,P)
    dtg = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 3, 1)    # (B,H,nc,L)
    bg = bm.reshape(b, nc, chunk, n)
    cg = cm.reshape(b, nc, chunk, n)
    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((b, h, nc, chunk, p), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xg, bg, cg, dtg, a_log.astype(jnp.float32))
    return jnp.moveaxis(out, 1, 3).reshape(b, t, h, p)
