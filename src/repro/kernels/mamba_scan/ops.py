"""Jitted wrapper for the Mamba2 chunk-scan kernel."""
from functools import partial

import jax

from .kernel import mamba_chunk_scan
from .ref import mamba_scan_ref


@partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def mamba_scan(x, bm, cm, dt, a_log, *, chunk=64, use_kernel=True):
    if use_kernel:
        return mamba_chunk_scan(x, bm, cm, dt, a_log, chunk=chunk,
                                interpret=jax.default_backend() != "tpu")
    return mamba_scan_ref(x, bm, cm, dt, a_log)
