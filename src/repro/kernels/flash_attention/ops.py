"""Jitted wrappers for the flash attention kernels."""
from functools import partial

import jax

from .kernel import flash_attention_tpu, flash_attention_varlen_tpu
from .ref import flash_attention_ref, flash_attention_varlen_ref


@partial(jax.jit, static_argnames=("causal", "window", "use_kernel"))
def flash(q, k, v, *, causal=True, window=0, use_kernel=True):
    if use_kernel:
        return flash_attention_tpu(
            q, k, v, causal=causal, window=window,
            interpret=jax.default_backend() != "tpu")
    return flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("window", "use_kernel", "blk_q", "blk_k"))
def flash_varlen(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *, window=0,
                 use_kernel=True, blk_q=128, blk_k=128):
    """Token-packed (segment-id) flash attention — the kernel schedule the
    packed serving layout maps onto for real TPU dispatch. blk_q/blk_k set
    the block-sparse skip granularity (see _varlen_kernel)."""
    if use_kernel:
        return flash_attention_varlen_tpu(
            q, k, v, q_seg, kv_seg, q_pos, kv_pos, window=window,
            blk_q=blk_q, blk_k=blk_k,
            interpret=jax.default_backend() != "tpu")
    return flash_attention_varlen_ref(q, k, v, q_seg, kv_seg, q_pos, kv_pos,
                                      window=window)
