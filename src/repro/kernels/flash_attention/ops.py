"""Jitted wrapper for the flash attention kernel."""
from functools import partial

import jax

from .kernel import flash_attention_tpu
from .ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "use_kernel"))
def flash(q, k, v, *, causal=True, window=0, use_kernel=True):
    if use_kernel:
        return flash_attention_tpu(
            q, k, v, causal=causal, window=window,
            interpret=jax.default_backend() != "tpu")
    return flash_attention_ref(q, k, v, causal=causal, window=window)
