"""Pallas TPU kernel: flash prefill attention (causal + sliding window).

Grid: (B*H, nQ, nKV) — kv blocks innermost (sequential); online-softmax
state in VMEM scratch. Q/K/V tiles are (blk, D) with D on lanes; the MXU
sees (blk_q x D) @ (D x blk_k) matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, blk_q: int, blk_k: int, causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (blk_q, D)
    k = k_ref[0].astype(jnp.float32)                    # (blk_k, D)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    logit = (q * (1.0 / d ** 0.5)) @ k.T                # (blk_q, blk_k)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones_like(logit, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    logit = jnp.where(mask, logit, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logit, axis=-1))
    p = jnp.exp(logit - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _varlen_kernel(q_ref, k_ref, v_ref, qseg_ref, kseg_ref, qpos_ref,
                   kpos_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, blk_q: int, blk_k: int, window: int):
    """Segment-id variant: the batch is ONE packed token stream; the causal
    structure is block-diagonal over segments (q attends k iff
    kseg == qseg and kpos <= qpos). Pad q tokens carry seg -1, pad k slots
    seg -2, so pads never match anything.

    Page streams are segment-contiguous (the host packs each segment's
    pages back to back), so a KV block covers a tight interval of segment
    ids; a whole (q block, kv block) pair is skipped when the two segment
    intervals don't overlap — per-token KV work then tracks the token's
    own context length instead of the whole batch's stream."""
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_seg = qseg_ref[0][:, None]                        # (blk_q, 1)
    k_seg = kseg_ref[0][None, :]                        # (1, blk_k)
    q_pos = qpos_ref[0][:, None]
    k_pos = kpos_ref[0][None, :]

    # segment-interval overlap test (pads excluded: q pads seg -1, kv
    # pads/dead slots seg -2; an all-pad block has an empty interval)
    big = jnp.int32(1 << 30)
    qs = qseg_ref[0]
    ks = kseg_ref[0]
    q_lo = jnp.min(jnp.where(qs >= 0, qs, big))
    q_hi = jnp.max(jnp.where(qs >= 0, qs, -big))
    k_lo = jnp.min(jnp.where(ks >= 0, ks, big))
    k_hi = jnp.max(jnp.where(ks >= 0, ks, -big))
    hit = (k_lo <= q_hi) & (k_hi >= q_lo)

    @pl.when(hit)
    def _update():
        q = q_ref[0].astype(jnp.float32)                # (blk_q, D)
        k = k_ref[0].astype(jnp.float32)                # (blk_k, D)
        v = v_ref[0].astype(jnp.float32)
        d = q.shape[-1]
        logit = (q * (1.0 / d ** 0.5)) @ k.T            # (blk_q, blk_k)

        mask = (k_seg == q_seg) & (k_pos <= q_pos)
        if window:
            mask &= k_pos > q_pos - window
        logit = jnp.where(mask, logit, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logit, axis=-1))
        # fully-masked block rows contribute NOTHING (p would otherwise
        # degenerate to exp(NEG_INF - NEG_INF) = 1 per slot — a uniform
        # average leaking other segments' values into no-slot rows)
        p = jnp.where((m_new > NEG_INF / 2)[:, None],
                      jnp.exp(logit - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_varlen_tpu(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *,
                               window=0, blk_q=128, blk_k=128,
                               interpret=True):
    """Varlen (token-packed) flash attention: the T axis is one packed
    stream of concatenated segments, not one sequence.

    q: (BH, T, D); k/v: (BH, S, D); q_seg/q_pos: (T,); kv_seg/kv_pos: (S,)
    — segment ids and absolute in-sequence positions shared across the BH
    heads. Streams are padded up to block multiples internally (pad q rows
    seg -1, pad kv slots seg -2), so any ``_tok_bucket``-sized packed
    stream is accepted. Returns (BH, T, D); rows whose segment matches no
    kv slot (pad rows included) come out exactly zero."""
    bh, t, d = q.shape
    s = k.shape[1]
    t0 = t
    blk_q = min(blk_q, t)
    blk_k = min(blk_k, s)
    pad_t = -t % blk_q
    pad_s = -s % blk_k
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0)))
        q_seg = jnp.pad(q_seg, (0, pad_t), constant_values=-1)
        q_pos = jnp.pad(q_pos, (0, pad_t))
        t += pad_t
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0)))
        kv_seg = jnp.pad(kv_seg, (0, pad_s), constant_values=-2)
        kv_pos = jnp.pad(kv_pos, (0, pad_s))
        s += pad_s
    grid = (bh, t // blk_q, s // blk_k)
    kernel = functools.partial(_varlen_kernel, blk_q=blk_q, blk_k=blk_k,
                               window=window)
    # metadata rides as (1, T) 2-D arrays: TPU lowering dislikes rank-1 refs
    meta = [a.reshape(1, -1).astype(jnp.int32)
            for a in (q_seg, kv_seg, q_pos, kv_pos)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, blk_q), lambda b, qi, ki: (0, qi)),
            pl.BlockSpec((1, blk_k), lambda b, qi, ki: (0, ki)),
            pl.BlockSpec((1, blk_q), lambda b, qi, ki: (0, qi)),
            pl.BlockSpec((1, blk_k), lambda b, qi, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v, *meta)[:, :t0]


def flash_attention_tpu(q, k, v, *, causal=True, window=0,
                        blk_q=128, blk_k=128, interpret=True):
    """q: (BH, T, D); k/v: (BH, S, D). Returns (BH, T, D)."""
    bh, t, d = q.shape
    s = k.shape[1]
    blk_q = min(blk_q, t)
    blk_k = min(blk_k, s)
    assert t % blk_q == 0 and s % blk_k == 0, (t, s, blk_q, blk_k)
    grid = (bh, t // blk_q, s // blk_k)
    kernel = functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k,
                               causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
