"""Pure-jnp oracle for the flash attention kernel."""
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_varlen_ref(q, k, v, q_seg, kv_seg, q_pos, kv_pos, *,
                               window=0):
    """Masked-softmax oracle for the varlen (token-packed) kernel: the T
    axis holds concatenated segments; q attends k iff same segment and
    kv_pos <= q_pos (window-bounded when window > 0)."""
    mask = (kv_seg[None, :] == q_seg[:, None]) & \
        (kv_pos[None, :] <= q_pos[:, None])
    if window:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    d = q.shape[-1]
    logit = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                       k.astype(jnp.float32)) / (d ** 0.5)
    logit = jnp.where(mask[None], logit, NEG_INF)
    p = jnp.exp(logit - logit.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    # rows with no valid slot are exactly zero (kernel contract)
    p = p * mask.any(-1, keepdims=True)[None]
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    bh, t, d = q.shape
    s = k.shape[1]
    logit = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                       k.astype(jnp.float32)) / (d ** 0.5)
    qp = jnp.arange(t)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    logit = jnp.where(mask[None], logit, NEG_INF)
    p = jnp.exp(logit - logit.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)).astype(q.dtype)
