"""Alias module for the zamba2_1p2b assigned architecture config."""
from .archs import ZAMBA2_1P2B as CONFIG

CONFIG = CONFIG
