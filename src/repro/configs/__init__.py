"""Assigned architecture configs (exact, from the public pool) + shapes."""
from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                   SHAPES_BY_NAME, TRAIN_4K, ModelConfig, ShapeSpec, reduced,
                   shapes_for)
from .archs import ARCHS, get_config

__all__ = [
    "ALL_SHAPES", "ARCHS", "DECODE_32K", "LONG_500K", "PREFILL_32K",
    "SHAPES_BY_NAME", "TRAIN_4K", "ModelConfig", "ShapeSpec", "get_config",
    "reduced", "shapes_for",
]
