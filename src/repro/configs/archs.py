"""The 10 assigned architectures, exact configs from the assignment.

Sources per entry are noted inline ([hf:...] / [arXiv:...] as given).
Each is also importable as src/repro/configs/<id>.py (thin alias modules).
"""
from __future__ import annotations

from .base import ModelConfig

# --- MoE -------------------------------------------------------------------
# dbrx-132b [hf:databricks/dbrx-base]: 40L d6144 48H GQA(kv=8) ff/expert 10752
# vocab 100352, 16 experts top-4 fine-grained
DBRX_132B = ModelConfig(
    name="dbrx-132b", family="moe", num_layers=40, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=10752,
    vocab_size=100352, num_experts=16, experts_per_token=4, moe_d_ff=10752,
    rope_theta=5e5,
)

# qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family]: 94L d4096 64H GQA(kv=4)
# moe_d_ff 1536, vocab 151936, 128 experts top-8
QWEN3_MOE_235B = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, head_dim=128, d_ff=1536,
    vocab_size=151936, num_experts=128, experts_per_token=8, moe_d_ff=1536,
    rope_theta=1e6,
)

# --- hybrid ------------------------------------------------------------------
# zamba2-1.2b [arXiv:2411.15242]: 38 Mamba2 blocks d2048, shared attn block
# (32H, kv=32) every 6 blocks, d_ff 8192, vocab 32000, ssm_state 64
ZAMBA2_1P2B = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=32000,
    mamba_d_state=64, mamba_headdim=64, mamba_expand=2, attn_every=6,
)

# --- dense -------------------------------------------------------------------
# qwen2.5-32b [hf:Qwen/Qwen2.5 family]: 64L d5120 40H GQA(kv=8) ff27648
# vocab 152064, QKV bias
QWEN2P5_32B = ModelConfig(
    name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=27648,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
)

# h2o-danube-3-4b [arXiv:2401.16818]: 24L d3840 32H GQA(kv=8) ff10240
# vocab 32000 — llama+mistral mix: alternating full / sliding-window layers
H2O_DANUBE3_4B = ModelConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=24, d_model=3840,
    num_heads=32, num_kv_heads=8, head_dim=120, d_ff=10240, vocab_size=32000,
    attn_pattern=("full", "swa"), sliding_window=4096, rope_theta=5e5,
)

# granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: 40L d2048 32H GQA(kv=8)
# ff8192 vocab 49155
GRANITE3_2B = ModelConfig(
    name="granite-3-2b", family="dense", num_layers=40, d_model=2048,
    num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=49155,
    tie_embeddings=True, rope_theta=1e6,
)

# internlm2-1.8b [arXiv:2403.17297]: 24L d2048 16H GQA(kv=8) ff8192 vocab 92544
INTERNLM2_1P8B = ModelConfig(
    name="internlm2-1.8b", family="dense", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=92544,
    rope_theta=1e6,
)

# --- ssm ---------------------------------------------------------------------
# rwkv6-3b (Finch) [arXiv:2404.05892]: 32L d2560 attn-free, d_ff 8960,
# vocab 65536, head_size 64, data-dependent decay
RWKV6_3B = ModelConfig(
    name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=40, num_kv_heads=40, head_dim=64, d_ff=8960, vocab_size=65536,
    rwkv_head_size=64,
)

# --- vlm ---------------------------------------------------------------------
# qwen2-vl-2b [arXiv:2409.12191]: 28L d1536 12H GQA(kv=2) ff8960 vocab 151936
# M-RoPE; modality frontend stubbed (precomputed patch embeddings)
QWEN2_VL_2B = ModelConfig(
    name="qwen2-vl-2b", family="vlm", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, head_dim=128, d_ff=8960, vocab_size=151936,
    qkv_bias=True, mrope=True, mm_hidden=1536, rope_theta=1e6,
)

# --- audio -------------------------------------------------------------------
# whisper-tiny [arXiv:2212.04356]: 4L enc + 4L dec, d384 6H ff1536 vocab 51865
# conv frontend stubbed (precomputed frame embeddings)
WHISPER_TINY = ModelConfig(
    name="whisper-tiny", family="encdec", num_layers=4, d_model=384,
    num_heads=6, num_kv_heads=6, head_dim=64, d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_seq=1500, tie_embeddings=True,
)

ARCHS = {
    c.name: c for c in [
        DBRX_132B, QWEN3_MOE_235B, ZAMBA2_1P2B, QWEN2P5_32B, H2O_DANUBE3_4B,
        GRANITE3_2B, INTERNLM2_1P8B, RWKV6_3B, QWEN2_VL_2B, WHISPER_TINY,
    ]
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from None
