"""Alias module for the dbrx_132b assigned architecture config."""
from .archs import DBRX_132B as CONFIG

CONFIG = CONFIG
