"""Alias module for the whisper_tiny assigned architecture config."""
from .archs import WHISPER_TINY as CONFIG

CONFIG = CONFIG
