"""Alias module for the qwen2_vl_2b assigned architecture config."""
from .archs import QWEN2_VL_2B as CONFIG

CONFIG = CONFIG
