"""Alias module for the qwen2p5_32b assigned architecture config."""
from .archs import QWEN2P5_32B as CONFIG

CONFIG = CONFIG
