"""Alias module for the rwkv6_3b assigned architecture config."""
from .archs import RWKV6_3B as CONFIG

CONFIG = CONFIG
