"""Alias module for the qwen3_moe_235b_a22b assigned architecture config."""
from .archs import QWEN3_MOE_235B as CONFIG

CONFIG = CONFIG
