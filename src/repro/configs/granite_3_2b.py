"""Alias module for the granite_3_2b assigned architecture config."""
from .archs import GRANITE3_2B as CONFIG

CONFIG = CONFIG
