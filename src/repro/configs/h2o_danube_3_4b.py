"""Alias module for the h2o_danube_3_4b assigned architecture config."""
from .archs import H2O_DANUBE3_4B as CONFIG

CONFIG = CONFIG
