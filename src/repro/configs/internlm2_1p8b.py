"""Alias module for the internlm2_1p8b assigned architecture config."""
from .archs import INTERNLM2_1P8B as CONFIG

CONFIG = CONFIG
