"""Model / shape configuration dataclasses for all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention pattern, cycled over layers: entries "full" | "swa"
    attn_pattern: Tuple[str, ...] = ("full",)
    sliding_window: int = 4096
    qkv_bias: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert FFN dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # Mamba2 (hybrid / ssm families)
    mamba_d_state: int = 0
    mamba_headdim: int = 64
    mamba_expand: int = 2
    mamba_conv_width: int = 4
    attn_every: int = 0              # hybrid: shared attn block every k mamba blocks
    # RWKV6
    rwkv_head_size: int = 64
    # VLM
    mrope: bool = False
    mm_hidden: int = 0               # vision-embedding width (post-merger)
    # enc-dec (audio)
    encoder_layers: int = 0
    encoder_seq: int = 1500          # stub frontend frames
    # misc
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    tokens_per_page: int = 16
    # serving-scale knob: max KV pool fraction of HBM (per device)
    kv_pool_bytes: int = 4 << 30

    # ------------------------------------------------------------- helpers
    @property
    def attn_kind_per_layer(self) -> Tuple[str, ...]:
        if self.family in ("ssm",):
            return ()
        n = self.num_layers
        pat = self.attn_pattern
        return tuple(pat[i % len(pat)] for i in range(n))

    @property
    def num_swa_layers(self) -> int:
        return sum(1 for k in self.attn_kind_per_layer if k == "swa")

    @property
    def num_full_layers(self) -> int:
        return sum(1 for k in self.attn_kind_per_layer if k == "full")

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch decode with bounded per-token state at 500k context?
        True for SSM / hybrid / all-SWA mixes with at least no unbounded
        full-attention requirement... full layers make it quadratic."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True   # few attn layers; we run them sequence-parallel
        return self.num_full_layers == 0

    def validate(self) -> None:
        assert self.d_model > 0 and self.num_layers > 0
        if self.family not in ("ssm",):
            assert self.num_heads % self.num_kv_heads == 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """The assigned shape set, with the long_500k skip rule for pure
    full-attention archs (documented in DESIGN.md)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.is_sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        num_heads=4,
        num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tokens_per_page=4,
        kv_pool_bytes=64 << 20,
    )
    if cfg.num_experts:
        base.update(num_experts=4, experts_per_token=2, moe_d_ff=64)
    if cfg.mamba_d_state:
        base.update(mamba_d_state=16, mamba_headdim=16)
    if cfg.family == "hybrid":
        base.update(num_layers=5, attn_every=2)
    if cfg.family == "ssm":
        base.update(rwkv_head_size=16)
    if cfg.family == "encdec":
        base.update(encoder_layers=2, num_layers=2, encoder_seq=16)
    if cfg.family == "vlm":
        base.update(mm_hidden=64)
    if cfg.sliding_window:
        base.update(sliding_window=8)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
