"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts.

  compute    = HLO_FLOPs_per_device   / peak_FLOP/s      (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device   / HBM_bw           (819 GB/s)
  collective = collective_bytes/dev   / ICI link bw      (50 GB/s)

(The per-chip divisions cancel: cost_analysis and the HLO are per-device
SPMD programs.) MODEL_FLOPS uses 6·N·D for training and 2·N·D for inference
steps, with N_active for MoE.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir dryrun_results]
Writes a markdown table to stdout and JSON to <dir>/roofline.json.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def count_params(cfg) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    V = cfg.vocab_size
    H, KV = cfg.num_heads, cfg.num_kv_heads
    attn = d * hd * (2 * H + 2 * KV)
    out = {"embed": V * d * (1 if cfg.tie_embeddings else 2)}
    if cfg.family == "ssm":
        att_dim = d
        per_layer = 5 * d * att_dim + att_dim * d + 2 * d * cfg.d_ff \
            + d * d + 64 * (d + att_dim)
        out["layers"] = cfg.num_layers * per_layer
        out["active"] = out["layers"] + out["embed"]
        out["total"] = out["active"]
        return out
    if cfg.family == "hybrid":
        di = cfg.mamba_expand * d
        N = cfg.mamba_d_state
        mamba = 2 * d * di + 2 * d * N + d * (di // cfg.mamba_headdim) \
            + di * d
        shared = attn + 3 * d * cfg.d_ff
        out["layers"] = cfg.num_layers * mamba + shared
        out["active"] = out["layers"] + out["embed"]
        out["total"] = out["active"]
        return out
    if cfg.family == "encdec":
        per = attn + 2 * d * cfg.d_ff
        dec = 2 * attn + 2 * d * cfg.d_ff
        out["layers"] = cfg.encoder_layers * per + cfg.num_layers * dec
        out["active"] = out["layers"] + out["embed"]
        out["total"] = out["active"]
        return out
    if cfg.num_experts:
        expert = 3 * d * cfg.moe_d_ff
        per_layer_dense = attn + d * cfg.num_experts
        out["layers"] = cfg.num_layers * (
            per_layer_dense + cfg.num_experts * expert)
        active = cfg.num_layers * (
            per_layer_dense + cfg.experts_per_token * expert)
        out["active"] = active + out["embed"]
        out["total"] = out["layers"] + out["embed"]
        return out
    per_layer = attn + 3 * d * cfg.d_ff
    out["layers"] = cfg.num_layers * per_layer
    out["active"] = out["layers"] + out["embed"]
    out["total"] = out["active"]
    return out


def model_flops_per_device(cfg, shape, devices, micro=1) -> float:
    n = count_params(cfg)
    n_active = n["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / devices
    # decode: one token per sequence + attention KV reads (2*2*S*d_kv FLOPs)
    toks = shape.global_batch
    attn_read = 4.0 * shape.seq_len * cfg.num_kv_heads * cfg.head_dim \
        * max(1, cfg.num_layers) * toks
    return (2.0 * n_active * toks + attn_read) / devices


def loop_factor(cfg, shape) -> int:
    """Static trip count of the layer scan (XLA cost_analysis counts while
    bodies ONCE — see EXPERIMENTS.md 'loop-accounting' note)."""
    if cfg.family == "hybrid":
        base = cfg.num_layers // cfg.attn_every
    elif cfg.family in ("ssm", "encdec"):
        base = cfg.num_layers
    else:
        base = cfg.num_layers // max(1, len(cfg.attn_pattern))
    if shape.kind == "train":
        from .input_specs import default_micro_batches
        base *= default_micro_batches(cfg)
    return max(1, base)


def kv_bytes_per_device(cfg, shape, dist_tp=16, dp=16):
    """Bytes of KV/state one device holds for this workload (local units,
    replica-split accounted)."""
    from jax.sharding import AbstractMesh
    from ..models.registry import build_model
    from ..models.tp import Dist
    sp = shape.kind == "decode" and shape.global_batch < 32
    mesh = AbstractMesh((16, 16), ("data", "model"))
    dist = Dist(mesh=mesh, dp_axes=("data",), sp=sp)
    model = build_model(cfg, dist)
    repl = model.ri.get("repl", 1) if isinstance(model.ri, dict) else 1
    if dist.sp:
        b_loc, toks = shape.global_batch, shape.seq_len // 16
    else:
        b_loc, toks = shape.global_batch // dist.dp, shape.seq_len
    toks_attn = -(-toks // max(1, repl))
    total = 0
    for sp in model.kv_specs():
        if sp.kind in ("mamba", "rwkv"):
            total += b_loc * sp.page_units
        elif sp.kind == "cross_attn":
            total += b_loc * sp.pages_for_tokens(cfg.encoder_seq)                 * sp.page_units
        elif sp.kind == "swa":
            w = min(sp.sliding_window, toks_attn)
            total += b_loc * sp.pages_for_tokens(max(1, w)) * sp.page_units
        else:
            total += b_loc * sp.pages_for_tokens(toks_attn) * sp.page_units
    return 2 * total            # bf16


def analytic_terms(cfg, shape, devices):
    """First-principles compute/memory terms (per device, seconds)."""
    n = count_params(cfg)
    tp, dp = 16, devices // 16
    params_dev = 2 * n["total"] / tp / (1 if shape.kind != "train" else 1)
    kvb = kv_bytes_per_device(cfg, shape)
    d_attn = cfg.num_kv_heads * cfg.head_dim
    Lf = getattr(cfg, "num_layers", 0)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n["active"] * tokens / devices
        # causal attention flops (fwd+bwd ~3x fwd)
        attn = 3 * 2 * 2 * cfg.num_heads * cfg.head_dim             * shape.seq_len ** 2 / 2 * shape.global_batch * Lf / devices
        flops += attn
        act = tokens * cfg.d_model * 2 * Lf * 4 / devices
        bytes_dev = 3 * params_dev * 2 + act     # fp32 grads+params rw
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n["active"] * tokens / devices
        flops += 2 * 2 * cfg.num_heads * cfg.head_dim             * shape.seq_len ** 2 / 2 * shape.global_batch * Lf / devices
        bytes_dev = params_dev + 2 * kvb             + tokens / devices * cfg.d_model * 2 * Lf
    else:
        toks = shape.global_batch
        flops = 2.0 * n["active"] * toks / devices             + 4.0 * shape.seq_len * d_attn * Lf * toks / devices
        bytes_dev = params_dev + kvb
    return flops, bytes_dev


def load(dirname):
    from ..configs import ARCHS, SHAPES_BY_NAME
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        if f.endswith("roofline.json"):
            continue
        r = json.load(open(f))
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": f.split("__")[-1][:-5], "status": "skipped"})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh", "?"), "status": "error"})
            continue
        cfg = ARCHS[r["arch"]]
        shape = SHAPES_BY_NAME[r["shape"]]
        coll = sum(v["bytes"] for v in r["collectives"].values())
        lf = loop_factor(cfg, shape)
        a_flops, a_bytes = analytic_terms(cfg, shape, r["devices"])
        t_c = a_flops / PEAK_FLOPS
        t_m = a_bytes / HBM_BW
        t_x = coll * lf / LINK_BW
        dominant = max((("compute", t_c), ("memory", t_m),
                        ("collective", t_x)), key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(cfg, shape, r["devices"])
        # HLO-direct (uncorrected) terms for transparency
        useful = mf / max(1.0, r.get("flops_per_device", 1) * lf)
        bound = max(t_c, t_m, t_x)
        # roofline fraction: useful model FLOPs at peak vs the bound term
        frac = (mf / PEAK_FLOPS) / bound if bound else 0.0
        pool = 2 * r.get("buffer_units_per_device", 0)
        temp = r.get("temp_size_in_bytes", 0)
        copies = int(temp // pool) if pool else 0
        adj_peak = r.get("peak_bytes_per_device", 0) - copies * pool
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "devices": r["devices"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "hlo_t_compute_s": r.get("flops_per_device", 0) / PEAK_FLOPS,
            "hlo_t_memory_s": r.get("bytes_accessed_per_device", 0) / HBM_BW,
            "loop_factor": lf,
            "dominant": dominant, "model_flops_per_dev": mf,
            "hlo_flops_per_dev": r.get("flops_per_device", 0),
            "useful_ratio": useful, "roofline_frac": frac,
            "peak_gb": r.get("peak_bytes_per_device", 0) / 1e9,
            "adj_peak_gb": adj_peak / 1e9,
            "pool_copies": copies,
            "collective_bytes": coll,
            "coll_detail": {k: v for k, v in r["collectives"].items()
                            if v["count"]},
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun_results")
    args = ap.parse_args()
    rows = load(args.dir)
    with open(os.path.join(args.dir, "roofline.json"), "w") as fh:
        json.dump(rows, fh, indent=1)
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | useful | roofline | peak GB (adj) |")
    print(hdr)
    print("|" + "---|" * 10)
    for r in rows:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  + f"{r['status']} |" + " |" * 6)
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
              f"| {r['t_collective_s']:.2e} | {r['dominant']} "
              f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} "
              f"| {r['peak_gb']:.1f} ({r['adj_peak_gb']:.1f}) |")


if __name__ == "__main__":
    main()
