"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation — these are abstract shapes fed to
``jax.jit(...).lower()``. Page tables are sized to exactly the workload's KV
footprint (rounded to the LCM geometry), so ``memory_analysis`` proves the
production fit."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..core.spec import lcm as _lcm
from ..models.lm import DecodeBatch
from ..models.tp import Dist

I32 = jnp.int32


def sds(shape, dtype=I32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


@dataclasses.dataclass
class Cell:
    """One dry-run cell: abstract inputs + metadata."""

    kind: str                  # train | prefill | decode
    args: Tuple
    kwargs: Dict[str, Any]
    buffer_units: int          # per (data-shard, tp-shard) device
    notes: Dict[str, Any]


def buffer_units_for(model, cfg: ModelConfig, tokens_per_shard: int,
                     seqs_per_shard: int, enc_tokens_per_shard: int = 0,
                     margin: float = 1.05) -> int:
    """Units one device's pool needs for the workload, LCM-rounded.

    Attention-token counts are already divided by the KV replica factor
    by the caller (replica-group KV sequence split, DESIGN.md §5)."""
    units = 0
    for s in model.kv_specs():
        if s.kind in ("mamba", "rwkv"):
            units += seqs_per_shard * s.page_units
        elif s.kind == "cross_attn":
            units += s.pages_for_tokens(max(1, enc_tokens_per_shard)) \
                * s.page_units * seqs_per_shard
        elif s.kind == "swa":
            # Jenga retires out-of-window pages: pool holds window only
            w = min(s.sliding_window + s.tokens_per_page, tokens_per_shard)
            units += s.pages_for_tokens(w) * s.page_units * seqs_per_shard
        else:
            units += s.pages_for_tokens(tokens_per_shard) * s.page_units \
                * seqs_per_shard
    big = _lcm([s.page_units for s in model.kv_specs()])
    units = int(units * margin)
    # +1 large page: SCRATCH target for dropped dus writes (attention.py)
    return (-(-units // big) + 1) * big


def serve_cell(model, cfg: ModelConfig, shape: ShapeSpec, dist: Dist) -> Cell:
    tpp = cfg.tokens_per_page
    B, S = shape.global_batch, shape.seq_len
    prefill = shape.kind == "prefill"
    sp = dist.sp
    tp = dist.tp
    repl = model.ri.get("repl", 1) if isinstance(model.ri, dict) else 1
    if sp:
        s_dim = dist.mesh.shape["data"]
        b_loc = B
        seq_per_shard = -(-S // s_dim)
    else:
        s_dim = dist.dp
        assert B % s_dim == 0, (B, s_dim)
        b_loc = B // s_dim
        seq_per_shard = S
    # replica-group KV sequence split: each of the `repl` replicas of a kv
    # group holds 1/repl of the attention pages
    attn_tokens_per_shard = -(-seq_per_shard // max(1, repl))
    T = S if prefill else 1
    specs = {s.name: s for s in model.kv_specs()}
    tables, page_pos, write_eids, state_eids = {}, {}, {}, {}
    enc_seq = cfg.encoder_seq if cfg.family == "encdec" else 0
    for name, s in specs.items():
        if s.kind in ("mamba", "rwkv"):
            state_eids[name] = sds((s_dim, b_loc))
            continue
        if s.kind == "cross_attn":
            npg = s.pages_for_tokens(enc_seq)
            tables[name] = sds((s_dim, tp, b_loc, npg))
            page_pos[name] = sds((s_dim, tp, b_loc, npg))
            continue
        if s.kind == "swa":
            npg = s.pages_for_tokens(
                min(s.sliding_window + tpp, attn_tokens_per_shard)) + 1
        else:
            npg = s.pages_for_tokens(attn_tokens_per_shard)
        tables[name] = sds((s_dim, tp, b_loc, npg))
        page_pos[name] = sds((s_dim, tp, b_loc, npg))
        write_eids[name] = sds((s_dim, tp, b_loc, T))
    extra: Dict[str, Any] = {}
    if cfg.family == "encdec":
        extra["enc_lens"] = sds((B,))
        if prefill:
            extra["enc_embeds"] = sds((B, enc_seq, cfg.d_model), jnp.bfloat16)
            extra["enc_write_eids"] = sds((s_dim, tp, b_loc, enc_seq))
    if cfg.family == "vlm" and prefill:
        extra["mm_embeds"] = sds((B, T, cfg.d_model), jnp.bfloat16)
        extra["mm_mask"] = sds((B, T), jnp.bool_)
        extra["mrope_pos"] = sds((3, B, T))
    batch = DecodeBatch(
        tokens=sds((B, T)),
        positions=sds((B, T)),
        seq_lens=sds((B,)),
        tables=tables, page_pos=page_pos, write_eids=write_eids,
        state_eids=state_eids,
        last_idx=sds((B,)) if prefill else None,
        **extra)
    bunits = buffer_units_for(
        model, cfg,
        tokens_per_shard=attn_tokens_per_shard,
        seqs_per_shard=b_loc,
        enc_tokens_per_shard=enc_seq)
    return Cell(kind=shape.kind,
                args=(sds((s_dim, dist.tp, bunits), jnp.bfloat16), batch),
                kwargs={"prefill": prefill},
                buffer_units=bunits,
                notes=dict(B=B, S=S, b_loc=b_loc, s_dim=s_dim, sp=sp,
                           kv_repl_split=repl))


def train_cell(model, cfg: ModelConfig, shape: ShapeSpec, dist: Dist,
               micro_batches: int = 1) -> Cell:
    B, S = shape.global_batch, shape.seq_len
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["enc_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.family == "vlm":
        kwargs["mm_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        kwargs["mm_mask"] = sds((B, S), jnp.bool_)
        kwargs["mrope_pos"] = sds((3, B, S))
    return Cell(kind="train", args=(sds((B, S)), sds((B, S))),
                kwargs=kwargs, buffer_units=0,
                notes=dict(B=B, S=S, micro_batches=micro_batches))


def default_micro_batches(cfg: ModelConfig) -> int:
    """Microbatch count so train activations/dispatch fit a 16G chip
    (validated against the dry-run memory_analysis; see EXPERIMENTS.md)."""
    if cfg.num_experts >= 64:
        return 32
    if cfg.num_experts > 0:
        return 16
    if cfg.d_model >= 5120:
        return 16
    if cfg.d_model >= 3000:
        return 4
    if cfg.family == "ssm":
        return 8
    return 4


def wants_fsdp(cfg: ModelConfig) -> bool:
    """Enable FSDP for training when TP16-sharded weights alone would
    crowd a 16GB chip (counting fp32 grads + Adam moments)."""
    return cfg.d_model * cfg.d_ff * cfg.num_layers >= 24 * 5120 * 13824
