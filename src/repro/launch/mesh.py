"""Production mesh construction (per the assignment spec).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from ..models.tp import make_mesh_auto
    return make_mesh_auto(shape, axes)


def production_dist(*, multi_pod: bool = False, sp: bool = False):
    from ..models.tp import Dist
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    return Dist(mesh=mesh, dp_axes=dp_axes, tp_axis="model", sp=sp)
