import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
os.environ["JAX_ENABLE_X64"] = "true"  # KV pools exceed 2^31 units
"""Multi-pod dry-run driver (deliverable e).

For one (arch x shape x mesh) cell: build the production mesh, lower +
compile the step with ShapeDtypeStruct inputs (no allocation), print
``memory_analysis`` / ``cost_analysis``, and parse per-device collective
bytes from the optimized HLO. Results go to JSON for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape decode_32k [--multi-pod] [--out dryrun_results/]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_stats(hlo_text: str):
    """Per-device collective bytes by op kind, parsed from optimized HLO.

    Convention: bytes = result-shape bytes of the op on one device (the
    received volume), summed over all collective instructions."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    alts = "|".join(k + "(?:-start)?" for k in COLLECTIVES)
    pat = re.compile(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (" + alts + r")\(")
    for line in hlo_text.splitlines():
        m = pat.match(line.strip())
        if not m:
            continue
        shape_tok, op = m.groups()
        k = op[:-6] if op.endswith("-start") else op
        total = sum(shape_bytes(t)
                    for t in re.findall(r"\w+\[[\d,]*\]", shape_tok))
        out[k]["count"] += 1
        out[k]["bytes"] += total
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import ARCHS, SHAPES_BY_NAME, shapes_for
    from ..launch.input_specs import (default_micro_batches, serve_cell,
                                      train_cell, wants_fsdp)
    from ..launch.mesh import production_dist
    from ..models.registry import build_model
    from ..models.params import param_struct
    from ..training import optimizer as opt

    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in shapes_for(cfg):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention; this "
                          "arch is pure full-attention (DESIGN.md)"}
    sp = shape.kind == "decode" and shape.global_batch < 32
    dist = production_dist(multi_pod=multi_pod, sp=sp)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "devices": int(dist.dp * dist.tp) if not sp else
           int(dist.mesh.devices.size), "sp": sp}
    rec["devices"] = int(dist.mesh.devices.size)

    if shape.kind == "train":
        fsdp = wants_fsdp(cfg)
        import dataclasses as _dc
        dist = _dc.replace(dist, fsdp=fsdp)
        model = build_model(cfg, dist)
        rec["fsdp"] = fsdp
        # clamp so each microbatch still covers the DP width
        micro = min(default_micro_batches(cfg),
                    shape.global_batch // dist.dp)
        cell = train_cell(model, cfg, shape, dist, micro)
        rec["micro_batches"] = micro
        specs = model.specs()
        pstruct = model.struct()
        mesh = dist.mesh
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
        ostate = opt.OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pstruct),
            nu=jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pstruct))
        oshard = opt.OptState(
            step=NamedSharding(mesh, P()),
            mu=opt.zero1_shardings(specs, pstruct, mesh),
            nu=opt.zero1_shardings(specs, pstruct, mesh))
        acfg = opt.AdamWConfig()
        kwargs = cell.kwargs

        def train_step(params, state, tokens, targets):
            b = tokens.shape[0]
            mb = b // micro

            def split(a, name=""):
                if name == "mrope_pos":   # (3, B, T): batch is dim 1
                    r = a.reshape(a.shape[0], micro, mb, *a.shape[2:])
                    return jnp.moveaxis(r, 1, 0)
                return a.reshape(micro, mb, *a.shape[1:])

            kw_split = {k: split(v, k) for k, v in kwargs.items()}

            def mstep(carry, xs):
                gsum, lsum = carry
                tok, tgt, kws = xs
                loss, grads = jax.value_and_grad(
                    lambda p: model.train_loss(p, tok, tgt, **kws))(params)
                return (jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads),
                    lsum + loss), None

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(
                mstep, (gz, jnp.float32(0)),
                (split(tokens), split(targets), kw_split))
            grads = jax.tree.map(lambda g: g / micro, gsum)
            params2, state2, _ = opt.update(acfg, params, grads, state)
            return lsum / micro, params2, state2

        # NOTE: extras (enc/mm embeds) passed positionally (pjit forbids
        # kwargs when in_shardings is given)
        if kwargs:
            kw_names = sorted(kwargs)
            kw_structs = [kwargs[k] for k in kw_names]

            def train_step_kw(params, state, tokens, targets, *kw_vals):
                nonlocal kwargs
                kwargs = dict(zip(kw_names, kw_vals))
                return train_step(params, state, tokens, targets)

            jitted = jax.jit(
                train_step_kw,
                in_shardings=(pshard, oshard, None, None)
                + (None,) * len(kw_structs),
                donate_argnums=(0, 1))
            lowered = jitted.lower(pstruct, ostate, *cell.args, *kw_structs)
        else:
            jitted = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, None, None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(pstruct, ostate, *cell.args)
    else:
        from ..models import attention as _A
        _A.set_write_mode("dus")   # 0-copy buffer writes (see EXPERIMENTS.md)
        model = build_model(cfg, dist)
        model.param_dtype = jnp.bfloat16   # serving weights are bf16
        cell = serve_cell(cfg=cfg, model=model, shape=shape, dist=dist)
        rec["buffer_units_per_device"] = cell.buffer_units
        rec.update(cell.notes)
        pstruct = model.struct()

        def serve(params, buffer, batch):
            return model.serve_step(params, buffer, batch,
                                    prefill=cell.kwargs["prefill"])

        jitted = jax.jit(serve, donate_argnums=(1,))
        lowered = jitted.lower(pstruct, *cell.args)

    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        args_b = rec.get("argument_size_in_bytes", 0)
        alias_b = rec.get("alias_size_in_bytes", 0)
        temp_b = rec.get("temp_size_in_bytes", 0)
        out_b = rec.get("output_size_in_bytes", 0)
        rec["peak_bytes_per_device"] = args_b + temp_b + (out_b - alias_b)
    cost = compiled.cost_analysis()
    if cost:
        rec["flops_per_device"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed_per_device"] = float(
            cost.get("bytes accessed", 0.0))
    rec["collectives"] = collective_stats(compiled.as_text())
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    def one(arch, shape_name, multi_pod):
        tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag} (cached)")
            return
        print(f"[run ] {tag}", flush=True)
        try:
            rec = run_cell(arch, shape_name, multi_pod)
        except Exception as e:
            rec = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": repr(e),
                   "trace": traceback.format_exc()[-4000:]}
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
        print(f"[done] {tag}: {rec.get('status')} "
              f"(compile {rec.get('compile_s', '-')}s)", flush=True)

    if args.all:
        from ..configs import ALL_SHAPES, ARCHS
        for arch in sorted(ARCHS):
            for shape in ALL_SHAPES:
                one(arch, shape.name, args.multi_pod)
    else:
        one(args.arch, args.shape, args.multi_pod)


if __name__ == "__main__":
    main()
