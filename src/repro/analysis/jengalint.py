"""jengalint — AST lint for the serving stack's cross-cutting invariants.

The engine's correctness rests on properties no single module can see:
deterministic placement/sampling is load-bearing for exactly-once failover,
the async ring forbids host syncs anywhere in the prepare/dispatch path,
and page allocation must stay transactional (everything routes through the
manager). One stray ``np.asarray(logits)`` or ``time.time()`` in the wrong
module silently costs 500x fetch traffic or breaks bit-for-bit replay.
These rules encode where each class of call is and is not allowed.

Rules (ids are what pragmas name):

* ``host-sync`` — device-blocking calls (``block_until_ready``,
  ``jax.device_get``, ``np.asarray``/``np.array`` on device handles,
  ``.item()``, ``float()``/``bool()`` of non-trivial expressions) are
  forbidden in ``serving/runner.py`` (prepare/dispatch phases),
  ``serving/sampler.py`` and ``kernels/``. Fetch-phase code opts out per
  line with a pragma — every waiver is a reviewed sentence.
* ``nondet`` — wall-clock reads, the global ``random`` module, ``id()``
  and direct ``set`` iteration are forbidden in ``serving/scheduler.py``,
  ``serving/router.py``, ``serving/dp_engine.py`` and
  ``core/prefix_cache.py``, where iteration order decides placement and
  replay.
* ``alloc-direct`` — direct ``TypedPool`` lifecycle calls (``allocate``/
  ``free``/``acquire_cached``/``release_to_cache``) are forbidden outside
  the core allocator modules (everything routes through the manager's
  transactional API), and ``allocate_for_batch``/``allocate_for_tokens``
  results must be handled (defer/preempt), never discarded.
* ``jit-hygiene`` — inside functions handed to ``jax.jit`` /
  ``pl.pallas_call``: no ``print``, no host callbacks
  (``pure_callback``/``io_callback``/``jax.debug.callback``), and no
  Python ``if``/``while`` branching on traced parameters (branching on
  ``.shape``/``.dtype``/``.ndim``/``.size`` is static and fine; so are
  keyword-only parameters, the idiom for static flags bound via
  ``partial`` before jitting).

Waivers: ``# jengalint: allow[<rule>] <reason>`` on the offending line or
the line directly above. A waiver without a reason is itself a violation
(``waiver-reason``), and a waiver that matches nothing is reported as
``stale-waiver`` so dead pragmas cannot accumulate.

The linter is purely syntactic — it cannot prove a value is on device, so
the forbidden-call sets are tuned to this repo's idioms (``jnp.asarray``
is an upload, never flagged; ``np.asarray`` of a device handle is the
500x fetch). Precision over recall: anything it flags is worth a reviewed
sentence.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
import sys
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

# ------------------------------------------------------------------ scopes
HOT_PATH_FILES = {"serving/runner.py", "serving/sampler.py"}
HOT_PATH_PREFIXES = ("kernels/",)
NONDET_FILES = {
    "serving/scheduler.py", "serving/router.py", "serving/dp_engine.py",
    "core/prefix_cache.py",
}
# The only modules allowed to call TypedPool/LargePageAllocator lifecycle
# methods directly; everything else goes through the manager's
# transactional API (allocate_for_batch / rollback_tokens / free_request).
ALLOC_CORE_FILES = {
    "core/manager.py", "core/typed_pool.py", "core/lcm_allocator.py",
}

_NP_NAMES = {"np", "numpy"}
_TIME_FUNCS = {
    "time", "monotonic", "perf_counter", "time_ns", "monotonic_ns",
    "perf_counter_ns",
}
_POOL_LIFECYCLE = {"allocate", "free", "acquire_cached", "release_to_cache"}
_ALLOC_TXN = {"allocate_for_batch", "allocate_for_tokens"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_HOST_CALLBACKS = {"pure_callback", "io_callback", "callback"}

PRAGMA_RE = re.compile(
    r"#\s*jengalint:\s*allow\[([a-z0-9_\-, ]+)\]\s*(.*?)\s*$")


@dataclasses.dataclass(frozen=True)
class Violation:
    relpath: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.relpath}:{self.line}:{self.col}: " \
               f"[{self.rule}] {self.message}"


@dataclasses.dataclass
class Waiver:
    line: int
    rules: Tuple[str, ...]
    reason: str
    used: bool = False

    def covers(self, v: Violation) -> bool:
        return v.rule in self.rules and v.line in (self.line, self.line + 1)


def _in_hot_path(relpath: str) -> bool:
    return relpath in HOT_PATH_FILES or relpath.startswith(HOT_PATH_PREFIXES)


# ------------------------------------------------------------- rule: host-sync
def _check_host_sync(tree: ast.AST, relpath: str) -> List[Violation]:
    if not _in_hot_path(relpath):
        return []
    out: List[Violation] = []

    def flag(node: ast.AST, what: str) -> None:
        out.append(Violation(
            relpath, node.lineno, node.col_offset, "host-sync",
            f"{what} blocks the host on device results; the prepare/"
            f"dispatch path must stay sync-free (fetch-phase code waives "
            f"with a reason)"))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "block_until_ready":
                flag(node, "block_until_ready()")
            elif (f.attr == "device_get" and isinstance(f.value, ast.Name)
                    and f.value.id == "jax"):
                flag(node, "jax.device_get()")
            elif (f.attr in ("asarray", "array")
                    and isinstance(f.value, ast.Name)
                    and f.value.id in _NP_NAMES):
                flag(node, f"np.{f.attr}()")
            elif f.attr == "item" and not node.args and not node.keywords:
                flag(node, ".item()")
        elif isinstance(f, ast.Name) and f.id in ("float", "bool") \
                and node.args:
            # float(x)/bool(x) of an expression (call result, attribute
            # chain, subscript) is where device handles hide; bare names
            # and literals are overwhelmingly host scalars.
            if not isinstance(node.args[0], (ast.Constant, ast.Name)):
                flag(node, f"{f.id}() of a non-trivial expression")
    return out


# --------------------------------------------------------------- rule: nondet
def _check_nondet(tree: ast.AST, relpath: str) -> List[Violation]:
    if relpath not in NONDET_FILES:
        return []
    out: List[Violation] = []

    def flag(node: ast.AST, what: str) -> None:
        out.append(Violation(
            relpath, node.lineno, node.col_offset, "nondet",
            f"{what} breaks bit-for-bit replay; placement and scheduling "
            f"here must be deterministic (exactly-once failover recomputes "
            f"from the same decisions)"))

    def is_set_expr(e: ast.AST) -> bool:
        return isinstance(e, ast.Set) or (
            isinstance(e, ast.Call) and isinstance(e.func, ast.Name)
            and e.func.id in ("set", "frozenset"))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
                if f.value.id == "time" and f.attr in _TIME_FUNCS:
                    flag(node, f"time.{f.attr}()")
                elif f.value.id == "random" and f.attr != "Random":
                    flag(node, f"the global RNG (random.{f.attr})")
            elif isinstance(f, ast.Name):
                if f.id == "id":
                    flag(node, "id() (keys/order vary across runs)")
                elif f.id == "iter" and node.args \
                        and is_set_expr(node.args[0]):
                    flag(node, "iter() over a set")
        elif isinstance(node, ast.For) and is_set_expr(node.iter):
            flag(node, "iteration over a set")
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if is_set_expr(gen.iter):
                    flag(node, "comprehension over a set")
    return out


# --------------------------------------------------------- rule: alloc-direct
def _check_alloc(tree: ast.AST, relpath: str) -> List[Violation]:
    out: List[Violation] = []
    core = relpath in ALLOC_CORE_FILES
    for node in ast.walk(tree):
        if isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            f = node.value.func
            if isinstance(f, ast.Attribute) and f.attr in _ALLOC_TXN:
                out.append(Violation(
                    relpath, node.lineno, node.col_offset, "alloc-direct",
                    f"{f.attr}() result discarded — call sites must handle "
                    f"the defer/preempt outcome (False means the plan did "
                    f"NOT commit)"))
        elif isinstance(node, ast.Call) and not core:
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in _POOL_LIFECYCLE \
                    and not (isinstance(f.value, ast.Name)
                             and f.value.id == "self"):
                out.append(Violation(
                    relpath, node.lineno, node.col_offset, "alloc-direct",
                    f".{f.attr}() outside the core allocator modules — page "
                    f"lifecycle must route through the manager's "
                    f"transactional API"))
    return out


# --------------------------------------------------------- rule: jit-hygiene
def _jitted_names(tree: ast.AST) -> Set[str]:
    """Names of functions handed to jax.jit / pl.pallas_call in this
    module (directly, via ``partial``, or as a decorator)."""
    names: Set[str] = set()

    def harvest(call: ast.Call) -> None:
        for a in call.args:
            if isinstance(a, ast.Name):
                names.add(a.id)
            elif isinstance(a, ast.Call) and isinstance(a.func, ast.Name) \
                    and a.func.id == "partial":
                for inner in a.args:
                    if isinstance(inner, ast.Name):
                        names.add(inner.id)

    def is_jit(f: ast.AST) -> bool:
        return isinstance(f, ast.Attribute) and f.attr in (
            "jit", "pallas_call")

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and is_jit(node.func):
            harvest(node)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if is_jit(dec) or (isinstance(dec, ast.Call)
                                   and is_jit(dec.func)):
                    names.add(node.name)
    return names


def _check_jit_hygiene(tree: ast.AST, relpath: str) -> List[Violation]:
    if not _in_hot_path(relpath):
        return []
    jitted = _jitted_names(tree)
    if not jitted:
        return []
    out: List[Violation] = []

    def flag(node: ast.AST, fn: str, what: str) -> None:
        out.append(Violation(
            relpath, node.lineno, node.col_offset, "jit-hygiene",
            f"{what} inside jitted function '{fn}' — dispatch-phase "
            f"functions must be pure traced computation"))

    def check_fn(fn: ast.FunctionDef) -> None:
        # traced params: positional args minus self; keyword-only args are
        # the static-flag idiom (bound via partial before jitting).
        params = {a.arg for a in fn.args.args + fn.args.posonlyargs
                  if a.arg != "self"}
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id == "print":
                    flag(node, fn.name, "print()")
                elif isinstance(f, ast.Attribute) \
                        and f.attr in _HOST_CALLBACKS:
                    # jax.pure_callback / io_callback / jax.debug.callback
                    flag(node, fn.name, f"host callback .{f.attr}()")
            elif isinstance(node, (ast.If, ast.While)):
                static_ok = {
                    id(attr.value) for attr in ast.walk(node.test)
                    if isinstance(attr, ast.Attribute)
                    and attr.attr in _STATIC_ATTRS
                }
                for name in ast.walk(node.test):
                    if isinstance(name, ast.Name) and name.id in params \
                            and id(name) not in static_ok:
                        flag(node, fn.name,
                             f"Python branching on traced value "
                             f"'{name.id}'")
                        break

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name in jitted:
            check_fn(node)
    return out


RULES: Dict[str, Callable[[ast.AST, str], List[Violation]]] = {
    "host-sync": _check_host_sync,
    "nondet": _check_nondet,
    "alloc-direct": _check_alloc,
    "jit-hygiene": _check_jit_hygiene,
}


# ------------------------------------------------------------------- engine
def _parse_waivers(src: str, relpath: str) \
        -> Tuple[List[Waiver], List[Violation]]:
    waivers: List[Waiver] = []
    meta: List[Violation] = []
    for i, line in enumerate(src.splitlines(), start=1):
        m = PRAGMA_RE.search(line)
        if m is None:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = m.group(2).strip()
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            meta.append(Violation(
                relpath, i, 0, "waiver-reason",
                f"waiver names unknown rule(s) {unknown}; known: "
                f"{sorted(RULES)}"))
        if not reason:
            meta.append(Violation(
                relpath, i, 0, "waiver-reason",
                "waiver without a reason — every waiver is a reviewed "
                "sentence"))
        waivers.append(Waiver(i, rules, reason))
    return waivers, meta


def lint_source(src: str, relpath: str) -> List[Violation]:
    """Lint one module's source. ``relpath`` is the path relative to the
    ``repro`` package root (posix, e.g. ``serving/runner.py``) — rule
    scoping keys on it. Returns unwaived violations plus waiver-hygiene
    ones (missing reason, stale pragma)."""
    relpath = relpath.replace("\\", "/")
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Violation(relpath, e.lineno or 0, e.offset or 0,
                          "syntax", f"unparseable: {e.msg}")]
    waivers, meta = _parse_waivers(src, relpath)
    raw: List[Violation] = []
    for check in RULES.values():
        raw.extend(check(tree, relpath))
    kept: List[Violation] = []
    for v in raw:
        waived = False
        for w in waivers:
            if w.covers(v):
                w.used = True
                waived = True
        if not waived:
            kept.append(v)
    for w in waivers:
        if not w.used:
            kept.append(Violation(
                relpath, w.line, 0, "stale-waiver",
                f"waiver for {list(w.rules)} matches no violation — "
                f"remove it (dead pragmas hide future regressions)"))
    kept.extend(meta)
    return sorted(kept, key=lambda v: (v.line, v.col, v.rule))


def list_waivers(src: str, relpath: str) -> List[Waiver]:
    """All pragmas in one module (used by --list-waivers)."""
    return _parse_waivers(src, relpath)[0]


def _relpath_of(path: pathlib.Path, root: pathlib.Path) -> str:
    return path.relative_to(root).as_posix()


def lint_file(path: pathlib.Path, root: pathlib.Path) -> List[Violation]:
    return lint_source(path.read_text(), _relpath_of(path, root))


def find_package_root(start: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Locate ``src/repro`` from the repo checkout this module sits in."""
    here = start or pathlib.Path(__file__).resolve()
    for parent in here.parents:
        cand = parent / "src" / "repro"
        if cand.is_dir():
            return cand
    raise FileNotFoundError("src/repro not found above " + str(here))


def lint_tree(root: Optional[pathlib.Path] = None) -> List[Violation]:
    root = root or find_package_root()
    out: List[Violation] = []
    for path in sorted(root.rglob("*.py")):
        out.extend(lint_file(path, root))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    show_waivers = "--list-waivers" in argv
    argv = [a for a in argv if a != "--list-waivers"]
    root = pathlib.Path(argv[0]).resolve() if argv else find_package_root()
    if show_waivers:
        count = 0
        for path in sorted(root.rglob("*.py")):
            rel = _relpath_of(path, root)
            for w in list_waivers(path.read_text(), rel):
                print(f"{rel}:{w.line}: allow[{','.join(w.rules)}] "
                      f"-- {w.reason or '<NO REASON>'}")
                count += 1
        print(f"{count} waiver(s)")
        return 0
    violations = lint_tree(root)
    for v in violations:
        print(v.render())
    n_files = sum(1 for _ in root.rglob("*.py"))
    if violations:
        print(f"jengalint: {len(violations)} violation(s) in {n_files} "
              f"file(s)")
        return 1
    print(f"jengalint: {n_files} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
