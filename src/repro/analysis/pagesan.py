"""PageSan — a runtime page-lifecycle sanitizer for the Jenga allocator.

A shadow state machine over every small-page handle, recording the owner
request and the allocation site, so allocator misuse fails LOUDLY at the
faulty call instead of corrupting device KV three requests later:

    FREE --take--> ALLOCATED --release_to_cache--> CACHED --evict--> FREE
                       |   \\--free--> FREE            \\--acquire--> ALLOCATED
                       \\--(poisoning release)--> POISONED (error)

Detected bug classes:

* double-free            — ``free`` of a page already FREE
* free-while-cached      — ``free`` of a page sitting in the prefix cache
* gather-from-freed      — a dispatch reads/writes a page no request owns
  (``ModelRunner.dispatch`` calls ``check_dispatch`` on the host arrays)
* cache-poisoning        — re-caching a STATE page whose device content has
  run ahead of its boundary hash: the owner request still has dispatched
  steps in flight mutating the live page (the PR-3 uncached-preemption
  rule, extended to EOS-kill reconciliation and checkpoint copies)
* leaks at drain         — ``assert_drained`` lists every ALLOCATED page
  with its owner and allocation site
* lost in transit        — a page exported for a prefill->decode handoff
  (IN_TRANSIT) that was never released or cancelled: ``assert_drained``
  reports it separately from plain leaks, and freeing/caching/double-
  exporting an IN_TRANSIT page errors at the call site

Cost model: the pool guards every event call with ``if self.san is not
None`` — a single attribute test when disabled (``REPRO_PAGE_SANITIZER``
unset), full shadow tracking when enabled. ``verify`` cross-checks the
shadow against the pools' real ``PageState`` and is layered on the
existing ``check_invariants()`` chain.

The in-flight request set that powers the poisoning check is pushed by
the async engine (``set_inflight``) at every ring transition: rids with
dispatched-but-uncompleted segments. Releasing a state-kind page owned by
such a rid to the prefix cache is exactly the §5.3 poisoning hazard —
its boundary hash describes a shorter prefix than the device has already
written.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

# Page kinds whose content advances with EVERY computed token (recurrent
# state): caching one while its owner still has device work in flight is
# the poisoning hazard. Token-kind (KV) pages are append-only — a FULL
# page's content never changes after its hash is computed, so
# cache-while-running is safe for them.
STATE_KINDS = ("mamba", "rwkv")

FREE = "FREE"
ALLOCATED = "ALLOCATED"
CACHED = "CACHED"
POISONED = "POISONED"
# Exported for a prefill->decode handoff: the pool still counts the page
# USED (the copy stream reads it), but no further lifecycle event is legal
# until the export is released (on_export_done) or cancelled.
IN_TRANSIT = "IN_TRANSIT"


class PageSanError(RuntimeError):
    """An allocator-misuse bug caught by the sanitizer."""


def sanitizer_enabled() -> bool:
    return os.environ.get("REPRO_PAGE_SANITIZER", "") not in ("", "0")


def _call_site(skip_files: Tuple[str, ...] = ("pagesan.py", "typed_pool.py",
                                              "lcm_allocator.py")) -> str:
    """First stack frame outside the allocator/sanitizer — where the
    lifecycle call actually came from."""
    f = sys._getframe(1)
    while f is not None:
        fname = f.f_code.co_filename
        if not fname.endswith(skip_files):
            return f"{os.path.basename(fname)}:{f.f_lineno}"
        f = f.f_back
    return "<unknown>"


class _Shadow:
    __slots__ = ("state", "owner_rid", "site", "content_hash")

    def __init__(self) -> None:
        self.state = FREE
        self.owner_rid: Optional[str] = None
        self.site = "<never allocated>"
        self.content_hash: Optional[int] = None


class PageSanitizer:
    def __init__(self, specs) -> None:
        self.kinds: Dict[str, str] = {s.name: s.kind for s in specs}
        # Sliding-window specs retire out-of-window pages to the prefix
        # cache MID-REQUEST; an async dispatch prepared before that
        # retirement may still carry the eid in its table (the gather is
        # window-masked), so CACHED table entries are legal for them.
        self.windowed: Set[str] = {
            s.name for s in specs
            if getattr(s, "sliding_window", None)}
        self.shadow: Dict[str, Dict[int, _Shadow]] = {
            s.name: {} for s in specs}
        self._inflight: Set[str] = set()
        self.errors_raised = 0

    # ------------------------------------------------------------- helpers
    def _rec(self, name: str, eid: int) -> _Shadow:
        rec = self.shadow[name].get(eid)
        if rec is None:
            raise self._fail(
                name, eid, None,
                "event for a page this pool does not own (large page "
                "already released, or foreign exec id)")
        return rec

    def _fail(self, name: str, eid: int, rec: Optional[_Shadow],
              msg: str) -> PageSanError:
        self.errors_raised += 1
        ctx = ""
        if rec is not None:
            ctx = (f" [shadow={rec.state} owner={rec.owner_rid!r} "
                   f"allocated_at={rec.site} hash={rec.content_hash}]")
        return PageSanError(
            f"PageSan: {msg}: type={name} page={eid} at "
            f"{_call_site()}{ctx}")

    # -------------------------------------------------- engine-pushed state
    def set_inflight(self, rids: Iterable[str]) -> None:
        """Rids with dispatched-but-uncompleted device work; their state
        pages' device content runs ahead of the host hash chains."""
        self._inflight = set(rids)

    def clear_inflight(self, rid: str) -> None:
        self._inflight.discard(rid)

    # ------------------------------------------------------ pool-side events
    def on_adopt(self, name: str, eids: Iterable[int]) -> None:
        for eid in eids:
            self.shadow[name][eid] = _Shadow()

    def on_retire(self, name: str, eid: int) -> None:
        rec = self._rec(name, eid)
        if rec.state != FREE:
            raise self._fail(
                name, eid, rec,
                "large page released to the LCM allocator while a small "
                "page is still live")
        del self.shadow[name][eid]

    def on_take(self, name: str, eid: int, rid: str) -> None:
        rec = self._rec(name, eid)
        if rec.state != FREE:
            raise self._fail(name, eid, rec,
                             f"allocate of a page in state {rec.state}")
        rec.state = ALLOCATED
        rec.owner_rid = rid
        rec.site = _call_site()
        rec.content_hash = None

    def on_free(self, name: str, eid: int, ref_count: int) -> None:
        """``ref_count`` is the pool refcount BEFORE this free."""
        rec = self._rec(name, eid)
        if rec.state == FREE:
            raise self._fail(name, eid, rec, "double free")
        if rec.state == CACHED:
            raise self._fail(
                name, eid, rec,
                "free of a page sitting in the prefix cache (must be "
                "evicted or acquired first)")
        if rec.state == IN_TRANSIT:
            raise self._fail(
                name, eid, rec,
                "free of a page exported for handoff (the export must be "
                "released or cancelled first)")
        if ref_count <= 0:
            raise self._fail(name, eid, rec,
                             f"free with non-positive refcount {ref_count}")
        if ref_count == 1:
            rec.state = FREE
            rec.owner_rid = None
            rec.content_hash = None

    def on_cache(self, name: str, eid: int, content_hash: int,
                 owner_rid: Optional[str]) -> None:
        rec = self._rec(name, eid)
        if rec.state != ALLOCATED:
            raise self._fail(
                name, eid, rec,
                f"release_to_cache of a page in state {rec.state}")
        if self.kinds.get(name) in STATE_KINDS \
                and owner_rid in self._inflight:
            rec.state = POISONED
            raise self._fail(
                name, eid, rec,
                f"cache-poisoning: state page cached while owner "
                f"{owner_rid!r} has dispatched steps in flight — device "
                f"content runs ahead of the boundary hash "
                f"{content_hash}")
        rec.state = CACHED
        rec.content_hash = content_hash

    def on_register(self, name: str, eid: int, content_hash: int,
                    owner_rid: Optional[str]) -> None:
        """cache-while-running registration (page stays ALLOCATED)."""
        rec = self._rec(name, eid)
        if rec.state != ALLOCATED:
            raise self._fail(
                name, eid, rec,
                f"register_hash of a page in state {rec.state}")
        if self.kinds.get(name) in STATE_KINDS \
                and owner_rid in self._inflight:
            rec.state = POISONED
            raise self._fail(
                name, eid, rec,
                f"cache-poisoning: state checkpoint registered while owner "
                f"{owner_rid!r} has dispatched steps in flight — the "
                f"checkpoint copy will capture over-advanced state for "
                f"hash {content_hash}")
        rec.content_hash = content_hash

    def on_acquire(self, name: str, eid: int, rid: str,
                   was_cached: bool) -> None:
        rec = self._rec(name, eid)
        if was_cached:
            if rec.state != CACHED:
                raise self._fail(
                    name, eid, rec,
                    f"acquire_cached of a page in state {rec.state}")
            rec.state = ALLOCATED
            rec.site = _call_site()
        elif rec.state != ALLOCATED:
            raise self._fail(
                name, eid, rec,
                f"shared re-acquire of a page in state {rec.state}")
        rec.owner_rid = rid

    def on_export(self, name: str, eid: int, rid: str) -> None:
        """Page set exported for a prefill->decode handoff: the page stays
        USED in the pool (the cross-shard copy stream still reads it) but
        enters the explicit IN_TRANSIT shadow state — free/cache/re-export
        while in transit are bugs, and an export never released shows up
        at drain as lost-in-transit rather than a generic leak."""
        rec = self._rec(name, eid)
        if rec.state == IN_TRANSIT:
            raise self._fail(name, eid, rec,
                             "double export of a page already in transit")
        if rec.state != ALLOCATED:
            raise self._fail(name, eid, rec,
                             f"export of a page in state {rec.state}")
        rec.state = IN_TRANSIT
        rec.owner_rid = rid
        rec.site = _call_site()

    def on_export_done(self, name: str, eid: int) -> None:
        """Handoff finished (adopted on the destination) or cancelled: the
        source page returns to plain ALLOCATED ownership so the exporter
        can free/cache it normally. A page NOT in transit here means the
        same export was completed twice (double adopt)."""
        rec = self._rec(name, eid)
        if rec.state != IN_TRANSIT:
            raise self._fail(
                name, eid, rec,
                f"export completion of a page in state {rec.state} "
                f"(double adopt of the same export?)")
        rec.state = ALLOCATED

    def on_evict(self, name: str, eid: int) -> None:
        rec = self._rec(name, eid)
        if rec.state != CACHED:
            raise self._fail(name, eid, rec,
                             f"evict of a page in state {rec.state}")
        rec.state = FREE
        rec.owner_rid = None
        rec.content_hash = None

    # ---------------------------------------------------------- deep checks
    def check_dispatch(self, arrs: Dict[str, object]) -> None:
        """gather-from-freed: every page a dispatch reads (tables), writes
        (write_eids) or scans (state_eids) must be ALLOCATED right now.
        Killed packed segments keep their (freed) gather pages in the
        stream but are excluded via ``page_seg < 0``; padded layouts null
        dead rows to -1 outright.  Sliding-window table entries may also
        be CACHED: in-flight retirement releases slid-out pages to the
        prefix cache while an already-prepared dispatch still carries the
        eid, and the gather of those positions is window-masked."""
        page_seg = arrs.get("page_seg") or {}
        for field in ("tables", "write_eids", "state_eids"):
            coll = arrs.get(field)
            if not coll:
                continue
            for name, arr in coll.items():
                if arr is None or name not in self.shadow:
                    continue
                flat = np.asarray(arr).ravel()
                mask = flat >= 0
                if field == "tables":
                    seg = page_seg.get(name)
                    if seg is not None:
                        mask &= np.asarray(seg).ravel() >= 0
                windowed_table = (field == "tables"
                                  and name in self.windowed)
                for eid in np.unique(flat[mask]):
                    rec = self.shadow[name].get(int(eid))
                    ok = rec is not None and (
                        rec.state == ALLOCATED
                        or (windowed_table and rec.state == CACHED))
                    if not ok:
                        raise self._fail(
                            name, int(eid), rec,
                            f"gather-from-freed: dispatch {field} "
                            f"references a page no request owns")

    def live_pages(self) -> List[Tuple[str, int, _Shadow]]:
        return [(name, eid, rec)
                for name, pages in sorted(self.shadow.items())
                for eid, rec in sorted(pages.items())
                if rec.state == ALLOCATED]

    def assert_drained(self) -> None:
        """Leak check once every request finished: nothing may still be
        ALLOCATED (CACHED pages are fine — that is the prefix cache), and
        no export may still be IN_TRANSIT (a handoff that never completed
        nor cancelled lost its pages in transit)."""
        leaks = self.live_pages()
        transit = [(name, eid, rec)
                   for name, pages in sorted(self.shadow.items())
                   for eid, rec in sorted(pages.items())
                   if rec.state == IN_TRANSIT]
        if leaks or transit:
            lines = [f"  type={n} page={e} owner={r.owner_rid!r} "
                     f"allocated_at={r.site}" for n, e, r in leaks]
            lines += [f"  type={n} page={e} owner={r.owner_rid!r} "
                      f"LOST IN TRANSIT exported_at={r.site}"
                      for n, e, r in transit]
            self.errors_raised += 1
            raise PageSanError(
                "PageSan: %d leaked / %d lost-in-transit page(s) at "
                "drain:\n%s"
                % (len(leaks), len(transit), "\n".join(lines)))

    def verify(self, pools) -> None:
        """Cross-check shadow vs the pools' real PageState — called from
        ``JengaKVCacheManager.check_invariants`` when enabled."""
        from ..core.typed_pool import PageState
        expect = {PageState.EMPTY: FREE, PageState.USED: ALLOCATED,
                  PageState.EVICTABLE: CACHED}
        for name, pool in pools.items():
            shadow = self.shadow[name]
            if set(shadow) != set(pool.pages):
                extra = set(shadow) - set(pool.pages)
                missing = set(pool.pages) - set(shadow)
                raise PageSanError(
                    f"PageSan: shadow/pool page-set mismatch for {name}: "
                    f"shadow-only={sorted(extra)} pool-only="
                    f"{sorted(missing)}")
            for eid, page in pool.pages.items():
                rec = shadow[eid]
                if rec.state == POISONED:
                    continue    # already reported; state is post-mortem
                if rec.state == IN_TRANSIT:
                    # exported pages stay USED in the pool until the
                    # handoff is released or cancelled
                    if page.state == PageState.USED:
                        continue
                if rec.state != expect[page.state]:
                    raise PageSanError(
                        f"PageSan: shadow diverged for {name} page {eid}: "
                        f"shadow={rec.state} pool={page.state} "
                        f"owner={rec.owner_rid!r} site={rec.site}")
