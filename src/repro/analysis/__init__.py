"""Correctness tooling for the serving stack.

Two halves, one goal — make invariant violations fail at review/test time
instead of corrupting outputs in production:

* ``jengalint`` — AST-based static analysis with repo-specific rules
  (host syncs in the hot path, nondeterminism in replay-critical modules,
  allocator transactionality, jit-boundary hygiene). ``scripts/run_lint.py``
  runs it over the whole tree and is wired into tier-1 CI.
* ``pagesan`` — the runtime page-lifecycle sanitizer (PageSan): a shadow
  state machine over every small-page handle, enabled by
  ``REPRO_PAGE_SANITIZER=1`` and layered on the allocator's existing
  ``check_invariants()`` hooks. See ``docs/INVARIANTS.md``.
"""
from .jengalint import Violation, lint_source, lint_file, lint_tree
from .pagesan import PageSanError, PageSanitizer, sanitizer_enabled

__all__ = [
    "Violation", "lint_source", "lint_file", "lint_tree",
    "PageSanError", "PageSanitizer", "sanitizer_enabled",
]
