"""JengaKVCacheManager — the paper's full system glued together (§4 + §5).

Responsibilities:
  * builds the two-level geometry (LCM large pages, per-type small pools);
  * computes model-wide prefix-cache hits (intersection of per-type
    ``get_possible_prefix`` sets, §5.2);
  * transactional page allocation for scheduled tokens (chunked prefill /
    decode), with the §5.4 five-step algorithm inside each pool and the
    cross-type large-page LRU eviction hook (step 3);
  * page lifecycle: fill → register hash (cache-while-running) → retire
    (sliding-window early free, vision free-on-consume §6.2) → release to
    cache on request completion → evict;
  * balanced/aligned eviction via the per-type policies (§5.1);
  * memory accounting for the fragmentation/utilization benchmarks.

The manager is host-side and device-agnostic: the serving engine maps exec
page ids onto reshape views of the unified device buffer (see layout.py).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import prefix_cache as pc
from .lcm_allocator import LargePageAllocator
from .policies import LayerPolicy, make_policy
from .request import SequenceState
from .spec import KVCacheSpec, PageGeometry, make_geometry
from .typed_pool import TypedPool

STATE_KINDS = ("mamba", "rwkv")
TOKEN_KINDS = ("full_attn", "swa")
MM_KINDS = ("vision_embed", "cross_attn")


@dataclasses.dataclass
class StateCopyOp:
    """Device-side copy the engine must perform (state checkpointing §5.3)."""

    type_name: str
    src_page: int
    dst_page: int
    position: int      # prefix length the snapshot represents
    kind: str          # "checkpoint" (live->ckpt) or "restore" (ckpt->live)


@dataclasses.dataclass
class PageSetExport:
    """Snapshot of one request's typed page set for a prefill->decode
    handoff (§5.2 whole-prompt transfer unit): the per-type page tables
    with their boundary-chain hashes — the exact keys
    ``router.prefix_match_tokens`` probes — plus the live/checkpoint state
    pages and the hash-chain continuations the destination needs to keep
    extending the chains. The exported pages stay USED on the source
    (marked IN_TRANSIT in the sanitizer) until the handoff is released or
    cancelled; the destination allocates its own pages and the caller
    performs the device copies the returned (src, dst) pairs describe."""

    rid: str
    num_tokens: int                    # == num_computed == len(prompt)
    page_tables: Dict[str, List[int]]
    page_hashes: Dict[str, List[Optional[int]]]
    num_cached_pages: Dict[str, int]
    state_pages: Dict[str, int]
    ckpt_pages: Dict[str, Dict[int, int]]
    # hash-chain continuations (aux state), copied verbatim
    token_chain: Dict[str, List[int]]
    mm_chain: Dict[str, List[int]]
    state_chain: Dict[str, List[int]]
    state_boundary_hash: Dict[str, Dict[int, int]]


@dataclasses.dataclass
class TypeStats:
    page_units: int
    used: int
    evictable: int
    empty: int
    owned_large: int


@dataclasses.dataclass
class MemoryStats:
    total_units: int
    large_page_units: int
    free_large: int
    evictable_large: int
    per_type: Dict[str, TypeStats]

    @property
    def used_units(self) -> int:
        return sum(t.used * t.page_units for t in self.per_type.values())

    @property
    def evictable_units(self) -> int:
        return sum(t.evictable * t.page_units for t in self.per_type.values())

    @property
    def empty_units(self) -> int:
        """Internal fragmentation: reserved inside owned large pages, unused."""
        return sum(t.empty * t.page_units for t in self.per_type.values())

    @property
    def free_units(self) -> int:
        return self.free_large * self.large_page_units

    @property
    def utilization(self) -> float:
        return self.used_units / max(1, self.total_units)


class _ReqAux:
    """Incremental hash-chain state for one request."""

    __slots__ = (
        "keys", "mm_keys", "enc_keys", "token_chain", "mm_chain",
        "state_chain", "state_boundary_hash", "suppressed_ckpts",
    )

    def __init__(self) -> None:
        self.keys: List[int] = []
        self.mm_keys: List[int] = []
        self.enc_keys: List[int] = []
        # type -> [num_pages_hashed, chain_hash]
        self.token_chain: Dict[str, List[int]] = {}
        self.mm_chain: Dict[str, List[int]] = {}
        # type -> [position, chain_hash]
        self.state_chain: Dict[str, List[int]] = {}
        # type -> {boundary_pos: hash}
        self.state_boundary_hash: Dict[str, Dict[int, int]] = {}
        # type -> boundary positions whose checkpoint was suppressed
        # (allow_checkpoints=False) and awaits a catch-up snapshot
        self.suppressed_ckpts: Dict[str, List[int]] = {}


class JengaKVCacheManager:
    def __init__(
        self,
        specs: Sequence[KVCacheSpec],
        *,
        total_memory_bytes: int,
        mode: str = "lcm",
        enable_prefix_caching: bool = True,
        enable_inflight_retirement: bool = True,
        seed: int = 0,
        page_sanitizer: Optional[bool] = None,
    ):
        self.geometry: PageGeometry = make_geometry(
            specs, total_memory_bytes=total_memory_bytes, mode=mode
        )
        self.large_alloc = LargePageAllocator(self.geometry)
        self.pools: Dict[str, TypedPool] = {
            s.name: TypedPool(s, self.geometry, self.large_alloc) for s in specs
        }
        self.policies: Dict[str, LayerPolicy] = {
            s.name: make_policy(s) for s in specs
        }
        self.salts = {s.name: pc.salt_of(s.name) for s in specs}
        self.enable_prefix_caching = enable_prefix_caching
        self.enable_inflight_retirement = enable_inflight_retirement
        self.rng = random.Random(seed)
        self.clock = 0
        self._aux: Dict[str, _ReqAux] = {}
        # pages handed out by committed allocations since the last drain;
        # the runner zeroes them before their first dispatch (a recycled
        # large page can hold another type's stale bytes — e.g. fp32 state
        # pairs that decode as NaN when read as bf16 K/V)
        self._fresh_pages: List[Tuple[str, int]] = []
        # install the §5.4-step-3 cross-pool hook
        for pool in self.pools.values():
            pool._manager_evict_large = self._evict_large_for
        # optional PageSan shadow tracker (default: REPRO_PAGE_SANITIZER=1)
        self.sanitizer = None
        if page_sanitizer is None:
            from ..analysis.pagesan import sanitizer_enabled
            page_sanitizer = sanitizer_enabled()
        if page_sanitizer:
            from ..analysis.pagesan import PageSanitizer
            self.sanitizer = PageSanitizer(self.geometry.specs)
            for pool in self.pools.values():
                pool.san = self.sanitizer
        # running stats
        self.prefix_hit_tokens_total = 0
        self.prefix_query_tokens_total = 0
        # deferred-checkpoint + handoff accounting
        self.suppressed_checkpoints = 0
        self.catchup_checkpoints = 0
        self.handoff_exports = 0
        self.handoff_adopted = 0
        self.handoff_pages_adopted = 0

    # ------------------------------------------------------------------ util
    @property
    def specs(self) -> Tuple[KVCacheSpec, ...]:
        return self.geometry.specs

    def spec(self, name: str) -> KVCacheSpec:
        return self.geometry.spec_by_name(name)

    def tick(self) -> int:
        self.clock += 1
        return self.clock

    def _evict_large_for(self, pool: TypedPool, rid: str) -> Optional[int]:
        """§5.4 step 3: evict the LRU evictable large page (any type), then
        hand a fresh large page to the requesting pool."""
        victim = self.large_alloc.pop_evictable_lru()
        if victim is None:
            return None
        owner = self.large_alloc.owner_of(victim)
        self.pools[owner].evict_whole_large(victim)
        fresh = self.large_alloc.alloc(pool.spec.name)
        if fresh is None:  # pragma: no cover - freed page must be available
            return None
        pool._adopt_large(fresh, rid)
        return pool._take(pool.exec_id(fresh, 0), rid)

    # --------------------------------------------------------- key streams
    def _ensure_aux(self, req: SequenceState) -> _ReqAux:
        aux = self._aux.get(req.rid)
        if aux is None:
            aux = _ReqAux()
            self._aux[req.rid] = aux
            if req.encoder_items:
                aux.enc_keys = [
                    pc.combine(it.mm_hash, off)
                    for it in req.encoder_items
                    for off in range(it.length)
                ]
            if req.mm_items:
                aux.mm_keys = [
                    pc.combine(it.mm_hash, off)
                    for it in req.mm_items
                    for off in range(it.length)
                ]
        # extend main-stream keys for newly appended tokens (appends are
        # always text -> incremental extend, O(new))
        if len(aux.keys) < len(req.tokens):
            if not aux.keys:
                aux.keys = pc.key_stream(req.tokens, req.mm_items)
            else:
                aux.keys.extend(
                    int(t) for t in req.tokens[len(aux.keys):])
        return aux

    def _mm_storage_keys(self, req: SequenceState, spec: KVCacheSpec,
                         aux: _ReqAux) -> List[int]:
        if spec.kind == "cross_attn" and req.encoder_items:
            return aux.enc_keys
        return aux.mm_keys

    def _mm_storage_upto(self, req: SequenceState, spec: KVCacheSpec,
                         main_pos: int) -> int:
        """Number of storage-stream tokens needed once ``main_pos`` main
        tokens are being computed."""
        if spec.kind == "cross_attn" and req.encoder_items:
            # whole encoder stream is needed as soon as anything runs
            return sum(it.length for it in req.encoder_items) if main_pos > 0 else 0
        n = 0
        for it in req.mm_items:
            n += max(0, min(main_pos, it.start + it.length) - it.start)
        return n

    # ------------------------------------------------------------ hit logic
    def _possible_prefixes(self, req: SequenceState) -> Dict[str, Set[int]]:
        aux = self._ensure_aux(req)
        n = len(req.tokens)
        out: Dict[str, Set[int]] = {}
        for name, spec in ((s.name, s) for s in self.specs):
            pool = self.pools[name]
            policy = self.policies[name]
            salt = self.salts[name]
            if spec.kind in TOKEN_KINDS:
                hashes = pc.page_chain_hashes(aux.keys, spec.tokens_per_page, salt)
                is_hit = [False] * n
                for pi, h in enumerate(hashes):
                    if pool.lookup(h) is not None:
                        lo = pi * spec.tokens_per_page
                        hi = min(n, lo + spec.tokens_per_page)
                        for i in range(lo, hi):
                            is_hit[i] = True
                    elif spec.kind == "full_attn":
                        break  # chain broken; later pages can't hit anyway
            elif spec.kind in STATE_KINDS:
                is_hit = [False] * n
                interval = spec.state_checkpoint_interval
                h = salt
                for i, k in enumerate(aux.keys):
                    h = pc.combine(h, k)
                    p = i + 1
                    if p % interval == 0 and pool.lookup(h) is not None:
                        is_hit[i] = True
            else:  # mm kinds
                skeys = self._mm_storage_keys(req, spec, aux)
                hashes = pc.page_chain_hashes(skeys, spec.tokens_per_page, salt)
                is_hit = [False] * len(skeys)
                for pi, h in enumerate(hashes):
                    if pool.lookup(h) is not None:
                        lo = pi * spec.tokens_per_page
                        hi = min(len(skeys), lo + spec.tokens_per_page)
                        for i in range(lo, hi):
                            is_hit[i] = True
                # trailing partial storage page can never be cached
            out[name] = policy.get_possible_prefix(is_hit, req)
        return out

    def lookup_prefix(self, req: SequenceState) -> int:
        """Longest model-wide cache-hit prefix (§5.2), capped at n-1 so at
        least one token remains to compute."""
        if not self.enable_prefix_caching:
            return 0
        sets = self._possible_prefixes(req)
        common = set.intersection(*sets.values()) if sets else {0}
        n = len(req.tokens)
        valid = [p for p in common if 0 <= p <= n - 1]
        return max(valid) if valid else 0

    # ------------------------------------------------------- request begin
    def begin_request(self, req: SequenceState) -> Tuple[bool, List[StateCopyOp]]:
        """Acquire prefix-hit pages and set up hash chains. Returns
        (ok, copy_ops). On failure nothing is held."""
        aux = self._ensure_aux(req)
        now = self.tick()
        hit = self.lookup_prefix(req)
        self.prefix_query_tokens_total += len(req.tokens)
        copy_ops: List[StateCopyOp] = []
        acquired: List[Tuple[TypedPool, int]] = []
        fresh: List[Tuple[TypedPool, int]] = []

        def rollback() -> None:
            for pool, eid in acquired:
                page = pool.pages[eid]
                pool.release_to_cache(eid, page.content_hash)
            for pool, eid in fresh:
                pool.free(eid)

        try:
            for spec in self.specs:
                name, pool = spec.name, self.pools[spec.name]
                salt = self.salts[name]
                tpp = spec.tokens_per_page
                if spec.kind in TOKEN_KINDS:
                    n_hit_pages = hit // tpp
                    hashes = (pc.page_chain_hashes(aux.keys, tpp, salt)
                              if self.enable_prefix_caching else [])
                    table: List[int] = []
                    hlist: List[Optional[int]] = []
                    lo_page = 0
                    if spec.kind == "swa" and hit > 0:
                        lo_tok = max(0, hit - spec.sliding_window)
                        lo_page = lo_tok // tpp
                    for pi in range(n_hit_pages):
                        if pi < lo_page:
                            table.append(SequenceState.FREED)
                            hlist.append(hashes[pi])
                            continue
                        eid = pool.lookup(hashes[pi])
                        assert eid is not None, (name, pi, hit)
                        pool.acquire_cached(eid, req.rid)
                        pool.pages[eid].last_access = now
                        acquired.append((pool, eid))
                        table.append(eid)
                        hlist.append(hashes[pi])
                    req.page_tables[name] = table
                    req.page_hashes[name] = hlist
                    req.num_cached_pages[name] = n_hit_pages
                    aux.token_chain[name] = [
                        n_hit_pages,
                        hashes[n_hit_pages - 1] if n_hit_pages else salt,
                    ]
                elif spec.kind in STATE_KINDS and not self.enable_prefix_caching:
                    live = pool.allocate(req.rid)
                    if live is None:
                        rollback()
                        return False, []
                    fresh.append((pool, live))
                    req.state_pages[name] = live
                    req.ckpt_pages.setdefault(name, {})
                    aux.state_chain[name] = [0, salt]
                    aux.state_boundary_hash[name] = {}
                elif spec.kind in STATE_KINDS:   # caching on
                    interval = spec.state_checkpoint_interval
                    aux.state_chain[name] = [0, salt]
                    aux.state_boundary_hash[name] = {}
                    req.ckpt_pages.setdefault(name, {})
                    # live state page (one per request)
                    live = pool.allocate(req.rid)
                    if live is None:
                        rollback()
                        return False, []
                    fresh.append((pool, live))
                    req.state_pages[name] = live
                    pool.pages[live].last_access = now
                    if hit > 0:
                        assert hit % interval == 0, (hit, interval)
                        h = pc.prefix_hash(aux.keys, hit, salt)
                        ck = pool.lookup(h)
                        assert ck is not None
                        pool.acquire_cached(ck, req.rid)
                        pool.pages[ck].last_access = now
                        acquired.append((pool, ck))
                        req.ckpt_pages[name][hit] = ck
                        aux.state_chain[name] = [hit, h]
                        aux.state_boundary_hash[name][hit] = h
                        copy_ops.append(
                            StateCopyOp(name, ck, live, hit, "restore")
                        )
                else:  # mm kinds
                    skeys = self._mm_storage_keys(req, spec, aux)
                    hashes = (pc.page_chain_hashes(skeys, tpp, salt)
                              if self.enable_prefix_caching else [])
                    s_hit = self._mm_storage_upto(req, spec, hit)
                    n_hit_pages = s_hit // tpp
                    table, hlist = [], []
                    for pi in range(n_hit_pages):
                        eid = pool.lookup(hashes[pi])
                        if eid is None:
                            # storage beyond items fully inside the hit may be
                            # uncached only if the hit never required it
                            table.append(SequenceState.FREED)
                            hlist.append(hashes[pi])
                            continue
                        pool.acquire_cached(eid, req.rid)
                        pool.pages[eid].last_access = now
                        acquired.append((pool, eid))
                        table.append(eid)
                        hlist.append(hashes[pi])
                    req.page_tables[name] = table
                    req.page_hashes[name] = hlist
                    req.num_cached_pages[name] = n_hit_pages
                    aux.mm_chain[name] = [
                        n_hit_pages,
                        hashes[n_hit_pages - 1] if n_hit_pages else salt,
                    ]
        except Exception:
            rollback()
            raise
        req.num_computed = hit
        req.prefix_hit_tokens = hit
        self.prefix_hit_tokens_total += hit
        req.last_access = now
        return True, copy_ops

    # --------------------------------------------------------- allocation
    # The §5.4 transactional property is implemented with an undo journal so
    # it composes across a whole step plan: ``allocate_for_batch`` commits
    # page capacity for EVERY scheduled request of a step or rolls the whole
    # plan back as one unit; ``allocate_for_tokens`` is the one-request case.

    def _rollback_journal(self, journal: List[Tuple[str, SequenceState,
                                                    str, TypedPool, int]]):
        for kind, req, name, pool, eid in reversed(journal):
            if kind == "table":
                popped = req.page_tables[name].pop()
                assert popped == eid, (name, popped, eid)
            else:  # "state"
                del req.state_pages[name]
            pool.free(eid)

    def _allocate_into(self, req: SequenceState, target: int,
                       journal: List) -> bool:
        """Grow ``req``'s tables so tokens [num_computed, target) can be
        computed, recording every fresh page in ``journal``. Returns False
        (without rolling back — the caller owns the journal) on exhaustion.

        ``target`` may exceed ``len(req.tokens)``: the async scheduler
        commits pages for a decode token whose id is only sampled when the
        in-flight step's logits land (speculative +1 scheduling)."""
        self._ensure_aux(req)
        for spec in self.specs:
            name, pool = spec.name, self.pools[spec.name]
            tpp = spec.tokens_per_page
            if spec.kind in STATE_KINDS:
                if name not in req.state_pages:
                    eid = pool.allocate(req.rid)
                    if eid is None:
                        return False
                    req.state_pages[name] = eid
                    journal.append(("state", req, name, pool, eid))
                continue
            if spec.kind in TOKEN_KINDS:
                need_pages = -(-target // tpp)
            else:  # mm kinds
                s_need = self._mm_storage_upto(req, spec, target)
                need_pages = -(-s_need // tpp)
            table = req.page_tables.setdefault(name, [])
            for _ in range(max(0, need_pages - len(table))):
                eid = pool.allocate(req.rid)
                if eid is None:
                    return False
                table.append(eid)
                journal.append(("table", req, name, pool, eid))
        return True

    def allocate_for_batch(self, reqs: Sequence[SequenceState],
                           targets: Sequence[int]) -> bool:
        """Batch-transactional allocation for one step plan: ensure capacity
        so each ``reqs[i]`` can compute tokens [num_computed, targets[i]).
        Either every request's allocation commits or nothing changes."""
        assert len(reqs) == len(targets)
        journal: List = []
        for req, target in zip(reqs, targets):
            if not self._allocate_into(req, target, journal):
                self._rollback_journal(journal)
                return False
        self._fresh_pages.extend((name, eid)
                                 for _, _, name, _, eid in journal)
        return True

    def drain_fresh_pages(self) -> List[Tuple[str, int]]:
        """Pages allocated (committed) since the last drain, for device-side
        zero-initialisation before their first use."""
        out, self._fresh_pages = self._fresh_pages, []
        return out

    def allocate_for_tokens(self, req: SequenceState, target: int) -> bool:
        """Ensure page capacity so tokens [num_computed, target) can be
        computed. Transactional: on failure nothing changes."""
        return self.allocate_for_batch([req], [target])

    def rollback_tokens(self, req: SequenceState, target: int) -> int:
        """Undo trailing page allocations beyond what ``target`` computed
        tokens need — the async scheduler's speculative-decode rollback: a
        plan pre-commits a +1 decode page for every running request via
        ``allocate_for_batch``; when the in-flight step's logits reveal the
        request actually finished (EOS / token budget), the page committed
        for the never-computed token is popped here before the request is
        released.

        Pops trailing table entries (runner mirrors resync by table LENGTH,
        so the epoch is deliberately NOT bumped — a bump would force a full
        mirror rebuild and drop the freed-events cursor) and frees the
        non-FREED ones; popped pages are also purged from the fresh-page
        (zero-on-first-use) queue. State pages and ``num_computed`` are
        untouched. Returns the number of pages freed."""
        freed = 0
        popped: Set[Tuple[str, int]] = set()
        for spec in self.specs:
            if spec.kind in STATE_KINDS:
                continue
            name, pool = spec.name, self.pools[spec.name]
            tpp = spec.tokens_per_page
            if spec.kind in TOKEN_KINDS:
                need = -(-target // tpp)
            else:  # mm kinds
                need = -(-self._mm_storage_upto(req, spec, target) // tpp)
            table = req.page_tables.get(name)
            if not table or len(table) <= need:
                continue
            hlist = req.page_hashes.get(name, [])
            while len(table) > need:
                eid = table.pop()
                if len(hlist) > len(table):
                    hlist.pop()
                if eid == SequenceState.FREED:
                    continue
                pool.free(eid)
                popped.add((name, eid))
                freed += 1
            req.mark_trimmed(name)
        if popped:
            self._fresh_pages = [p for p in self._fresh_pages
                                 if p not in popped]
        return freed

    # --------------------------------------------------------------- advance
    def advance(self, req: SequenceState, num_new: int,
                allow_checkpoints: bool = True) -> List[StateCopyOp]:
        """Record that ``num_new`` more tokens were computed. Updates hash
        chains, registers newly full pages, retires out-of-window pages, and
        returns state-checkpoint copy ops for the engine.

        ``allow_checkpoints=False`` suppresses new state-checkpoint copies:
        required when deeper in-flight steps will keep mutating the live
        state page AFTER this copy op would execute — the snapshot would
        capture over-advanced state under a too-early boundary hash.
        Suppressed boundaries are recorded, not dropped: the next advance
        with ``allow_checkpoints=True`` (the rid has no deeper in-flight
        steps — at the latest its final ring completion) emits catch-up
        checkpoint copies for them, so depth >= 3 pipelines keep the same
        restart/prefix granularity as the sync path. At depth <= 2 every
        completion runs with ``allow_checkpoints=True``, so the deferral
        machinery is a provable no-op there."""
        aux = self._ensure_aux(req)
        old = req.num_computed
        req.num_computed = min(old + num_new, len(req.tokens))
        now = self.tick()
        req.last_access = now
        copy_ops: List[StateCopyOp] = []
        caching = self.enable_prefix_caching
        for spec in self.specs:
            name, pool = spec.name, self.pools[spec.name]
            tpp = spec.tokens_per_page
            salt = self.salts[name]
            if spec.kind in TOKEN_KINDS:
                chain = aux.token_chain.setdefault(name, [0, salt])
                table = req.page_tables.get(name, [])
                hlist = req.page_hashes.setdefault(name, [])
                while caching and (chain[0] + 1) * tpp <= req.num_computed:
                    h = chain[1]
                    for k in aux.keys[chain[0] * tpp : (chain[0] + 1) * tpp]:
                        h = pc.combine(h, k)
                    chain[0] += 1
                    chain[1] = h
                    while len(hlist) < chain[0]:
                        hlist.append(None)
                    hlist[chain[0] - 1] = h
                    if self.enable_prefix_caching and chain[0] - 1 < len(table):
                        eid = table[chain[0] - 1]
                        if eid != SequenceState.FREED:
                            pool.register_hash(eid, h)
                # sliding-window retirement (mid-request free, Fig. 16)
                if self.enable_inflight_retirement:
                    policy = self.policies[name]
                    for idx in policy.retire_pages(req):
                        eid = table[idx]
                        if eid == SequenceState.FREED:
                            continue
                        h = hlist[idx] if idx < len(hlist) else None
                        if self.enable_prefix_caching and h is not None:
                            pool.release_to_cache(eid, h)
                        else:
                            pool.free(eid)
                        req.mark_freed(name, idx)
            elif spec.kind in STATE_KINDS:
                interval = spec.state_checkpoint_interval
                chain = aux.state_chain.setdefault(name, [0, salt])
                bh = aux.state_boundary_hash.setdefault(name, {})
                pending = aux.suppressed_ckpts.setdefault(name, [])
                if (pending and allow_checkpoints and caching
                        and name in req.state_pages):
                    # catch-up: snapshot boundaries whose checkpoint was
                    # suppressed while deeper steps were in flight. The live
                    # page is now a few tokens past the boundary — the same
                    # approximation the sync path makes when one chunk
                    # crosses several boundaries before its copy ops run.
                    still: List[int] = []
                    for pos in pending:
                        if pos in req.ckpt_pages.get(name, {}):
                            continue
                        ck = pool.allocate(req.rid)
                        if ck is None:  # best-effort: retry next quiet advance
                            still.append(pos)
                            continue
                        req.ckpt_pages.setdefault(name, {})[pos] = ck
                        pool.register_hash(ck, bh[pos])
                        pool.pages[ck].last_access = now
                        copy_ops.append(StateCopyOp(
                            name, req.state_pages[name], ck,
                            pos, "checkpoint",
                        ))
                        self.catchup_checkpoints += 1
                    pending[:] = still
                while caching and chain[0] < req.num_computed:
                    chain[1] = pc.combine(chain[1], aux.keys[chain[0]])
                    chain[0] += 1
                    if chain[0] % interval == 0:
                        bh[chain[0]] = chain[1]
                        if (self.enable_prefix_caching
                                and name in req.state_pages):
                            if not allow_checkpoints:
                                pending.append(chain[0])
                                self.suppressed_checkpoints += 1
                                continue
                            ck = pool.allocate(req.rid)
                            if ck is not None:  # best-effort checkpointing
                                req.ckpt_pages.setdefault(name, {})[chain[0]] = ck
                                pool.register_hash(ck, chain[1])
                                pool.pages[ck].last_access = now
                                copy_ops.append(StateCopyOp(
                                    name, req.state_pages[name], ck,
                                    chain[0], "checkpoint",
                                ))
            else:  # mm kinds
                chain = aux.mm_chain.setdefault(name, [0, salt])
                skeys = self._mm_storage_keys(req, spec, aux)
                s_done = self._mm_storage_upto(req, spec, req.num_computed)
                table = req.page_tables.get(name, [])
                hlist = req.page_hashes.setdefault(name, [])
                while caching and (chain[0] + 1) * tpp <= s_done:
                    h = chain[1]
                    for k in skeys[chain[0] * tpp : (chain[0] + 1) * tpp]:
                        h = pc.combine(h, k)
                    chain[0] += 1
                    chain[1] = h
                    while len(hlist) < chain[0]:
                        hlist.append(None)
                    hlist[chain[0] - 1] = h
                    if self.enable_prefix_caching and chain[0] - 1 < len(table):
                        eid = table[chain[0] - 1]
                        if eid != SequenceState.FREED:
                            pool.register_hash(eid, h)
        return copy_ops

    # ------------------------------------------------- vision free-on-consume
    def consume_mm(self, req: SequenceState, upto_token: int) -> int:
        """§6.2: free vision-embedding pages whose storage tokens were all
        consumed by chunked prefill. Returns number of pages released."""
        released = 0
        for spec in self.specs:
            if spec.kind != "vision_embed":
                continue
            pool = self.pools[spec.name]
            tpp = spec.tokens_per_page
            s_done = self._mm_storage_upto(req, spec, upto_token)
            full = s_done // tpp
            table = req.page_tables.get(spec.name, [])
            hlist = req.page_hashes.get(spec.name, [])
            for idx in range(min(full, len(table))):
                eid = table[idx]
                if eid == SequenceState.FREED:
                    continue
                h = hlist[idx] if idx < len(hlist) else None
                if self.enable_prefix_caching and h is not None:
                    pool.release_to_cache(eid, h)
                else:
                    pool.free(eid)
                req.mark_freed(spec.name, idx)
                released += 1
        return released

    # ------------------------------------------------------------- touching
    def touch(self, req: SequenceState) -> None:
        """Balanced eviction: unified last-access stamping via policies (§5.1)."""
        now = self.tick()
        req.last_access = now
        for name, policy in self.policies.items():
            policy.update_last_access(self.pools[name], req, now)

    # ------------------------------------------------------------ request end
    def free_request(self, req: SequenceState, cache: bool = True,
                     cache_state: bool = True) -> None:
        """``cache_state=False`` keeps token-kind caching but plain-frees
        state pages: needed when the request finishes while deeper killed
        steps are still dispatched — the device keeps advancing the live
        state page past the boundary hash (see preempt_request)."""
        cache = cache and self.enable_prefix_caching
        cache_state = cache and cache_state
        now = self.tick()
        if cache:
            # aligned eviction: consistent fine-grained priorities (§5.1)
            for name, policy in self.policies.items():
                policy.set_prefix_length(self.pools[name], req, self.rng)
        aux = self._aux.get(req.rid)
        for spec in self.specs:
            name, pool = spec.name, self.pools[spec.name]
            table = req.page_tables.get(name, [])
            hlist = req.page_hashes.get(name, [])
            for idx, eid in enumerate(table):
                if eid == SequenceState.FREED:
                    continue
                h = hlist[idx] if idx < len(hlist) else None
                page = pool.pages[eid]
                page.last_access = max(page.last_access, req.last_access)
                if cache and h is not None:
                    pool.release_to_cache(eid, h)
                else:
                    pool.free(eid)
            req.page_tables[name] = []
            if spec.kind in STATE_KINDS:
                live = req.state_pages.pop(name, None)
                bh = (aux.state_boundary_hash.get(name, {}) if aux else {})
                if live is not None:
                    h = bh.get(req.num_computed)
                    if cache_state and h is not None:
                        pool.release_to_cache(live, h)
                    else:
                        pool.free(live)
                for pos, ck in req.ckpt_pages.get(name, {}).items():
                    h = bh.get(pos)
                    page = pool.pages[ck]
                    if cache_state and (h is not None or page.content_hash is not None):
                        pool.release_to_cache(ck, h if h is not None else page.content_hash)
                    else:
                        pool.free(ck)
                req.ckpt_pages[name] = {}
        req.bump_epoch()
        self._aux.pop(req.rid, None)

    def rollback(self, req: SequenceState, num_computed: int,
                 tokens: List[int]) -> None:
        """Speculative-decoding rollback (§6.1): rejected proposal tokens
        are discarded; their pages stay allocated and are overwritten by
        later tokens. Only valid with prefix caching disabled (hash chains
        would otherwise cover rejected content)."""
        assert not self.enable_prefix_caching
        req.tokens = list(tokens)
        req.num_computed = min(num_computed, len(req.tokens))
        aux = self._aux.get(req.rid)
        if aux is not None:
            aux.keys = aux.keys[: len(req.tokens)]

    def preempt_request(self, req: SequenceState, cache: bool = True) -> None:
        """Recompute-style preemption: release everything (cacheable pages go
        to the prefix cache), reset progress; the scheduler re-queues.

        ``cache=False`` is required when the victim has a step IN FLIGHT on
        the device (async scheduling): the dispatch is still mutating the
        victim's live state page past the position its boundary hash
        describes, so releasing it to the prefix cache would poison later
        hits with content from a longer prefix than the hash claims."""
        self.free_request(req, cache=cache)
        req.num_computed = 0
        req.prefix_hit_tokens = 0
        req.page_tables.clear()
        req.page_hashes.clear()
        req.state_pages.clear()
        req.ckpt_pages.clear()
        req.num_cached_pages.clear()

    # ------------------------------------------- prefill->decode handoff
    def _export_pages(self, export: PageSetExport):
        """Yield (type, eid) for every live page an export references, in a
        deterministic order."""
        for name in sorted(export.page_tables):
            for eid in export.page_tables[name]:
                if eid != SequenceState.FREED:
                    yield name, eid
        for name in sorted(export.state_pages):
            yield name, export.state_pages[name]
        for name in sorted(export.ckpt_pages):
            cks = export.ckpt_pages[name]
            for pos in sorted(cks):
                yield name, cks[pos]

    def export_request(self, req: SequenceState) -> PageSetExport:
        """Snapshot ``req``'s typed page set for a prefill->decode handoff.

        The request must be quiet (no in-flight steps). Pages stay USED and
        owned by ``req`` on this manager — the copy stream still reads them
        — but the sanitizer moves them to IN_TRANSIT so freeing, caching or
        re-exporting before ``release_export``/``cancel_export`` is caught,
        and an abandoned export shows up as lost-in-transit at drain."""
        aux = self._ensure_aux(req)
        export = PageSetExport(
            rid=req.rid,
            num_tokens=req.num_computed,
            page_tables={k: list(v) for k, v in req.page_tables.items()},
            page_hashes={k: list(v) for k, v in req.page_hashes.items()},
            num_cached_pages=dict(req.num_cached_pages),
            state_pages=dict(req.state_pages),
            ckpt_pages={k: dict(v) for k, v in req.ckpt_pages.items()},
            token_chain={k: list(v) for k, v in aux.token_chain.items()},
            mm_chain={k: list(v) for k, v in aux.mm_chain.items()},
            state_chain={k: list(v) for k, v in aux.state_chain.items()},
            state_boundary_hash={
                k: dict(v) for k, v in aux.state_boundary_hash.items()},
        )
        for name, eid in self._export_pages(export):
            self.pools[name].mark_exported(eid, req.rid)
        self.handoff_exports += 1
        return export

    def adopt_request(self, req: SequenceState,
                      export: PageSetExport) -> Tuple[bool, List[Tuple[str, int, int]]]:
        """Install an exported page set into THIS manager's pools so ``req``
        resumes as a whole-prompt prefix hit (§5.2): fresh pages are
        allocated mirroring the export's tables, full-page / boundary hashes
        are registered in this manager's prefix cache, and the hash-chain
        aux is rebuilt from the export so decode keeps extending the chains
        exactly where the source stopped.

        Returns ``(ok, pairs)`` where ``pairs`` lists ``(type, src_eid,
        dst_eid)`` device copies the caller must perform against the SOURCE
        engine's buffers. Transactional: on pool exhaustion every allocation
        is rolled back, ``req`` is cleared, and ``(False, [])`` returns.

        Deliberately bypasses the fresh-page zeroing queue: the handoff copy
        fills each page before its first dispatch, and a later zeroing pass
        would destroy the adopted content."""
        assert req.rid == export.rid
        now = self.tick()
        journal: List[Tuple[TypedPool, int]] = []
        pairs: List[Tuple[str, int, int]] = []

        def rollback() -> Tuple[bool, List[Tuple[str, int, int]]]:
            for pool, eid in reversed(journal):
                pool.free(eid)
            req.page_tables.clear()
            req.page_hashes.clear()
            req.state_pages.clear()
            req.ckpt_pages.clear()
            req.num_cached_pages.clear()
            self._aux.pop(req.rid, None)
            return False, []

        caching = self.enable_prefix_caching
        for spec in self.specs:
            name, pool = spec.name, self.pools[spec.name]
            if spec.kind in STATE_KINDS:
                src_live = export.state_pages.get(name)
                if src_live is None:
                    continue
                live = pool.allocate(req.rid)
                if live is None:
                    return rollback()
                journal.append((pool, live))
                req.state_pages[name] = live
                pool.pages[live].last_access = now
                pairs.append((name, src_live, live))
                bh = export.state_boundary_hash.get(name, {})
                req.ckpt_pages.setdefault(name, {})
                cks = export.ckpt_pages.get(name, {})
                for pos in sorted(cks):
                    ck = pool.allocate(req.rid)
                    if ck is None:
                        return rollback()
                    journal.append((pool, ck))
                    req.ckpt_pages[name][pos] = ck
                    pool.pages[ck].last_access = now
                    h = bh.get(pos)
                    if caching and h is not None:
                        pool.register_hash(ck, h)
                    pairs.append((name, cks[pos], ck))
            else:  # token + mm kinds
                table = export.page_tables.get(name, [])
                hlist = export.page_hashes.get(name, [])
                new_table: List[int] = []
                for i, src_eid in enumerate(table):
                    if src_eid == SequenceState.FREED:
                        new_table.append(SequenceState.FREED)
                        continue
                    eid = pool.allocate(req.rid)
                    if eid is None:
                        return rollback()
                    journal.append((pool, eid))
                    new_table.append(eid)
                    pool.pages[eid].last_access = now
                    h = hlist[i] if i < len(hlist) else None
                    if caching and h is not None:
                        pool.register_hash(eid, h)
                    pairs.append((name, src_eid, eid))
                req.page_tables[name] = new_table
                req.page_hashes[name] = list(hlist)
                req.num_cached_pages[name] = export.num_cached_pages.get(name, 0)
        # rebuild hash-chain aux so decode continues the chains verbatim
        self._aux.pop(req.rid, None)
        aux = self._ensure_aux(req)
        aux.token_chain = {k: list(v) for k, v in export.token_chain.items()}
        aux.mm_chain = {k: list(v) for k, v in export.mm_chain.items()}
        aux.state_chain = {k: list(v) for k, v in export.state_chain.items()}
        aux.state_boundary_hash = {
            k: dict(v) for k, v in export.state_boundary_hash.items()}
        req.num_computed = export.num_tokens
        req.prefix_hit_tokens = export.num_tokens
        req.last_access = now
        self.handoff_adopted += 1
        self.handoff_pages_adopted += len(pairs)
        return True, pairs

    def release_export(self, req: SequenceState, export: PageSetExport) -> None:
        """Destination adopted the page set: return the exported pages to
        plain USED ownership, then retire the source copy of the request —
        token and state pages enter THIS manager's prefix cache exactly as
        a normal completion would, so future shared-prompt arrivals still
        hit on the prefill shard."""
        for name, eid in self._export_pages(export):
            self.pools[name].mark_export_done(eid)
        self.free_request(req, cache=True, cache_state=True)

    def cancel_export(self, export: PageSetExport) -> None:
        """Adoption failed (destination pool pressure) or the destination
        died mid-handoff: lift the IN_TRANSIT marks; the source keeps owning
        and running the request as if the export never happened."""
        for name, eid in self._export_pages(export):
            self.pools[name].mark_export_done(eid)

    # --------------------------------------------------------------- queries
    def block_table(self, req: SequenceState, type_name: str) -> List[int]:
        return req.page_tables.get(type_name, [])

    def memory_stats(self) -> MemoryStats:
        per_type = {}
        for name, pool in self.pools.items():
            c = pool.counts()
            per_type[name] = TypeStats(
                page_units=pool.spec.page_units,
                used=c["used"],
                evictable=c["evictable"],
                empty=c["empty"],
                owned_large=c["owned_large"],
            )
        return MemoryStats(
            total_units=self.geometry.total_units,
            large_page_units=self.geometry.large_page_units,
            free_large=self.large_alloc.num_free,
            evictable_large=self.large_alloc.num_evictable,
            per_type=per_type,
        )

    def check_invariants(self) -> None:
        self.large_alloc.check_invariants()
        owned = set()
        for pool in self.pools.values():
            pool.check_invariants()
            assert not (owned & pool.owned_large)
            owned |= pool.owned_large
        free = self.large_alloc._free_set
        assert not (owned & free)
        assert len(owned) + len(free) == self.geometry.num_large_pages
        if self.sanitizer is not None:
            self.sanitizer.verify(self.pools)
