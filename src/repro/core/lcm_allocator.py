"""Bottom-level LCM large-page allocator (Jenga §4.1, §4.4, §5.4).

The entire KV memory is partitioned into ``num_large_pages`` pages of
``large_page_units`` (the LCM of all small-page sizes).  Large pages are
either FREE, or owned by exactly one typed small-page pool.  Eviction of
*evictable* large pages (step 3 of the §5.4 allocation algorithm) is
coordinated here via a lazy min-heap keyed by
``(max last-access over the page's small pages, insertion order)``.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Optional

from .spec import PageGeometry


@dataclasses.dataclass
class LargePage:
    page_id: int
    owner_type: Optional[str] = None     # typed pool currently owning this page
    # Timestamp used for LRU eviction of evictable large pages: the latest
    # last-access among its small pages (paper §5.4 step 3).
    evictable_ts: int = -1
    evictable_seq: int = 0               # tie-break / lazy-heap validation


class LargePageAllocator:
    """Tracks free large pages and the cross-type evictable-page LRU heap."""

    def __init__(self, geometry: PageGeometry):
        self.geometry = geometry
        self.num_pages = geometry.num_large_pages
        self.pages = [LargePage(i) for i in range(self.num_pages)]
        self._free: deque[int] = deque(range(self.num_pages))
        self._free_set: set[int] = set(range(self.num_pages))
        # Lazy heap of (ts, seq, page_id); entries validated on pop.
        self._evictable_heap: list[tuple[int, int, int]] = []
        self._evictable: set[int] = set()
        self._seq = 0

    # ---------------------------------------------------------------- alloc
    @property
    def num_free(self) -> int:
        return len(self._free_set)

    @property
    def num_evictable(self) -> int:
        return len(self._evictable)

    def alloc(self, owner_type: str) -> Optional[int]:
        """Grab a FREE large page for a typed pool; None if exhausted."""
        while self._free:
            pid = self._free.popleft()
            if pid in self._free_set:
                self._free_set.discard(pid)
                page = self.pages[pid]
                page.owner_type = owner_type
                return pid
        return None

    def free(self, page_id: int) -> None:
        """Return a large page to the free pool (all small pages empty)."""
        page = self.pages[page_id]
        if page_id in self._free_set:
            raise ValueError(f"double free of large page {page_id}")
        page.owner_type = None
        self._evictable.discard(page_id)
        self._free_set.add(page_id)
        self._free.append(page_id)

    # ------------------------------------------------------------- eviction
    def mark_evictable(self, page_id: int, ts: int) -> None:
        """All small pages of ``page_id`` are evictable; register for LRU."""
        page = self.pages[page_id]
        self._seq += 1
        page.evictable_ts = ts
        page.evictable_seq = self._seq
        self._evictable.add(page_id)
        heapq.heappush(self._evictable_heap, (ts, self._seq, page_id))

    def unmark_evictable(self, page_id: int) -> None:
        """A small page inside became used/empty; no longer whole-page evictable."""
        self._evictable.discard(page_id)

    def pop_evictable_lru(self) -> Optional[int]:
        """Pop the least-recently-used evictable large page (lazy heap)."""
        while self._evictable_heap:
            ts, seq, pid = heapq.heappop(self._evictable_heap)
            page = self.pages[pid]
            if (
                pid in self._evictable
                and page.evictable_ts == ts
                and page.evictable_seq == seq
            ):
                self._evictable.discard(pid)
                return pid
        return None

    # ------------------------------------------------------------- queries
    def owner_of(self, page_id: int) -> Optional[str]:
        return self.pages[page_id].owner_type

    def check_invariants(self) -> None:
        """Debug/property-test helper."""
        assert len(self._free_set) <= self.num_pages
        for pid in self._free_set:
            assert self.pages[pid].owner_type is None, pid
        for pid in self._evictable:
            assert self.pages[pid].owner_type is not None, pid
            assert pid not in self._free_set, pid
