"""Unified KV-buffer layout — the paper's page-layer partition (Fig. 7b/7c),
made TPU-idiomatic.

One bf16 buffer of ``total_units`` per (model-parallel) device slice holds all
layer types. A type-t small page of ``S_t`` units at unit offset
``large_id*LCM + slot*S_t`` has exec id ``large_id*spp_t + slot`` inside the
reshape view ``buffer.reshape(total_units // S_t, *type_shape)`` — reshapes
are free in XLA, so unmodified paged kernels index ``view[exec_id, layer, ...]``
exactly like PagedAttention with a per-type ``start_ptr/page_size`` (Fig. 7c).

TP note: the buffer is allocated per model-parallel shard with the KV-head
dim already divided, so the geometry below is constructed from *local* head
counts; exec page ids are identical on every shard (the allocator is
host-side and global).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp

from .spec import KVCacheSpec, PageGeometry


@dataclasses.dataclass(frozen=True)
class TypeView:
    """How to view the unified buffer for one layer type."""

    spec: KVCacheSpec
    view_shape: Tuple[int, ...]   # (virtual_pages, num_layers, *page_shape)
    page_shape: Tuple[int, ...]   # per-layer shape inside a small page

    @property
    def virtual_pages(self) -> int:
        return self.view_shape[0]


def attention_page_shape(spec: KVCacheSpec, kv_heads: int, head_dim: int
                         ) -> Tuple[int, ...]:
    """(2, tokens_per_page, kv_heads, head_dim) — K and V stacked; the token
    dim is second-minor-friendly and head_dim sits on TPU lanes."""
    assert spec.units_per_token_per_layer == 2 * kv_heads * head_dim, (
        spec, kv_heads, head_dim)
    return (2, spec.tokens_per_page, kv_heads, head_dim)


def state_page_shape(spec: KVCacheSpec) -> Tuple[int, ...]:
    """Flat per-layer state vector (conv+ssm or att+shift concatenated)."""
    return (spec.units_per_token_per_layer,)


def vision_page_shape(spec: KVCacheSpec) -> Tuple[int, ...]:
    return (spec.tokens_per_page, spec.units_per_token_per_layer)


class UnifiedLayout:
    """Derives every type's reshape view over one unified buffer."""

    def __init__(self, geometry: PageGeometry,
                 page_shapes: Dict[str, Tuple[int, ...]]):
        self.geometry = geometry
        self.views: Dict[str, TypeView] = {}
        total = geometry.total_units
        for spec in geometry.specs:
            shape = page_shapes[spec.name]
            per_layer = 1
            for d in shape:
                per_layer *= d
            assert per_layer * spec.num_layers == spec.page_units, (
                spec.name, shape, spec.page_units)
            vpages = total // spec.page_units
            self.views[spec.name] = TypeView(
                spec=spec,
                view_shape=(vpages, spec.num_layers) + shape,
                page_shape=shape,
            )

    @property
    def total_units(self) -> int:
        return self.geometry.total_units

    def alloc_buffer(self, dtype=jnp.bfloat16):
        return jnp.zeros((self.total_units,), dtype=dtype)

    def view(self, buffer, type_name: str):
        """Free reshape view of the unified buffer for one layer type."""
        tv = self.views[type_name]
        return buffer.reshape(tv.view_shape)

    def flatten(self, view, type_name: str):
        """Inverse of :meth:`view` (after functional updates)."""
        del type_name
        return view.reshape(self.total_units)

    def exec_capacity(self, type_name: str) -> int:
        """Max exec page id + 1 addressable for this type (virtual pages)."""
        return self.views[type_name].virtual_pages
