"""Per-layer-type small-page pools with request-aware allocation (Jenga §4.3, §5.4).

Each layer type owns a ``TypedPool`` that carves LCM large pages into
type-sized small pages.  Small pages live in one of three states (§5.4):

  EMPTY      — no valid KV, not referenced by any request
  USED       — referenced by >=1 running request (unevictable)
  EVICTABLE  — holds valid KV of a finished request (prefix cache), refcount 0

Exec-page-id arithmetic (paper Fig. 7c): a type-t small page in slot ``s`` of
large page ``L`` sits at unit offset ``L*LCM + s*S_t``, which is
``(L*spp_t + s) * S_t`` — i.e. exec id ``L*spp_t + s`` in a
``(total_units // S_t, ...)`` reshape view of the unified buffer.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .lcm_allocator import LargePageAllocator
from .spec import KVCacheSpec, PageGeometry


class PageState(enum.Enum):
    EMPTY = 0
    USED = 1
    EVICTABLE = 2


@dataclasses.dataclass
class SmallPage:
    exec_id: int
    large_id: int
    slot: int
    state: PageState = PageState.EMPTY
    owner_rid: Optional[str] = None       # request association (§4.3)
    ref_count: int = 0
    last_access: int = 0
    prefix_length: int = 0                # fine-grained eviction priority (§5.1)
    content_hash: Optional[int] = None    # prefix-cache key when EVICTABLE
    seq: int = 0                          # lazy-heap validation counter


class TypedPool:
    """Small-page allocator for one layer type, backed by the LCM pool."""

    def __init__(
        self,
        spec: KVCacheSpec,
        geometry: PageGeometry,
        large_alloc: LargePageAllocator,
    ):
        self.spec = spec
        self.geometry = geometry
        self.large_alloc = large_alloc
        self.spp = geometry.small_pages_per_large(spec)  # small pages / large page
        if self.spp < 1:
            raise ValueError(
                f"{spec.name}: small page ({spec.page_units}u) larger than "
                f"large page ({geometry.large_page_units}u)"
            )
        # exec id -> SmallPage, only for pages of large pages we currently own.
        self.pages: Dict[int, SmallPage] = {}
        self.owned_large: Set[int] = set()
        # Free (EMPTY) pages: per-request association lists + global set.
        self._free_by_rid: Dict[str, Set[int]] = {}
        self._free_global: Set[int] = set()
        # Evictable small pages: lazy heap by (last_access, -prefix_length).
        self._evict_heap: List[Tuple[int, int, int, int]] = []
        self._evictable: Set[int] = set()
        self._seq = 0
        # prefix-cache registry: content_hash -> exec_id
        self.cached: Dict[int, int] = {}
        # Optional PageSan shadow tracker (installed by the manager when
        # REPRO_PAGE_SANITIZER=1); every event below costs one None-check.
        self.san = None

    # ----------------------------------------------------------- id math
    def exec_id(self, large_id: int, slot: int) -> int:
        return large_id * self.spp + slot

    def large_of(self, exec_id: int) -> Tuple[int, int]:
        return divmod(exec_id, self.spp)

    # ------------------------------------------------------- bookkeeping
    def _adopt_large(self, large_id: int, rid: Optional[str]) -> None:
        """Partition a newly granted large page into EMPTY small pages
        associated with ``rid`` (§5.4 step 2)."""
        self.owned_large.add(large_id)
        if self.san is not None:
            self.san.on_adopt(
                self.spec.name,
                [self.exec_id(large_id, s) for s in range(self.spp)])
        for slot in range(self.spp):
            eid = self.exec_id(large_id, slot)
            self.pages[eid] = SmallPage(eid, large_id, slot, owner_rid=rid)
            self._free_add(eid, rid)

    def _free_add(self, eid: int, rid: Optional[str]) -> None:
        if rid is not None:
            self._free_by_rid.setdefault(rid, set()).add(eid)
        self._free_global.add(eid)

    def _free_remove(self, eid: int) -> None:
        page = self.pages[eid]
        self._free_global.discard(eid)
        if page.owner_rid is not None:
            s = self._free_by_rid.get(page.owner_rid)
            if s is not None:
                s.discard(eid)
                if not s:
                    del self._free_by_rid[page.owner_rid]

    def _large_all_state(self, large_id: int, state: PageState) -> bool:
        return all(
            self.pages[self.exec_id(large_id, s)].state == state
            for s in range(self.spp)
        )

    def _large_no_used(self, large_id: int) -> bool:
        return all(
            self.pages[self.exec_id(large_id, s)].state != PageState.USED
            for s in range(self.spp)
        )

    def _maybe_release_large(self, large_id: int) -> None:
        """If every small page in ``large_id`` is EMPTY, return it (§4.1 free)."""
        if not self._large_all_state(large_id, PageState.EMPTY):
            return
        for slot in range(self.spp):
            eid = self.exec_id(large_id, slot)
            if self.san is not None:
                self.san.on_retire(self.spec.name, eid)
            self._free_remove(eid)
            del self.pages[eid]
        self.owned_large.discard(large_id)
        self.large_alloc.unmark_evictable(large_id)
        self.large_alloc.free(large_id)

    def _maybe_mark_large_evictable(self, large_id: int) -> None:
        """If no small page is USED (and >=1 EVICTABLE), the large page joins
        the cross-type LRU (§5.4 step 3) keyed by the max small-page ts."""
        if not self._large_no_used(large_id):
            return
        sps = [self.pages[self.exec_id(large_id, s)] for s in range(self.spp)]
        if not any(p.state == PageState.EVICTABLE for p in sps):
            return
        ts = max(p.last_access for p in sps)
        self.large_alloc.mark_evictable(large_id, ts)

    # --------------------------------------------------------- allocation
    def allocate(self, rid: str) -> Optional[int]:
        """The §5.4 five-step allocation. Returns an exec page id or None."""
        # Step 1: request-associated EMPTY page.
        assoc = self._free_by_rid.get(rid)
        if assoc:
            eid = next(iter(assoc))
            return self._take(eid, rid)
        # Step 2: fresh large page from the LCM allocator.
        large_id = self.large_alloc.alloc(self.spec.name)
        if large_id is not None:
            self._adopt_large(large_id, rid)
            eid = self.exec_id(large_id, 0)
            return self._take(eid, rid)
        # Step 3: evict an evictable large page (cross-type LRU). The manager
        # resolves which pool owns the victim; see JengaKVCacheManager.
        eid = self._evict_large_via_manager(rid)
        if eid is not None:
            return eid
        # Step 4: any EMPTY page of this type (other request's association).
        if self._free_global:
            eid = next(iter(self._free_global))
            return self._take(eid, rid)
        # Step 5: evict an evictable small page of this type (LRU).
        eid = self._pop_small_evictable()
        if eid is not None:
            return self._take(eid, rid)
        return None

    # Hook installed by the manager (needs cross-pool coordination).
    _manager_evict_large = None

    def _evict_large_via_manager(self, rid: str) -> Optional[int]:
        if self._manager_evict_large is None:
            return None
        return self._manager_evict_large(self, rid)

    def _take(self, eid: int, rid: str) -> int:
        page = self.pages[eid]
        if self.san is not None:
            self.san.on_take(self.spec.name, eid, rid)
        self._free_remove(eid)
        page.state = PageState.USED
        page.ref_count = 1
        page.owner_rid = rid
        page.content_hash = None
        page.prefix_length = 0
        self.large_alloc.unmark_evictable(page.large_id)
        return eid

    # ----------------------------------------------------------- freeing
    def free(self, eid: int) -> None:
        """Drop one reference; page becomes EMPTY at refcount 0 (no caching)."""
        page = self.pages[eid]
        if self.san is not None:
            # Pre-mutation so double-free / free-while-cached are reported
            # before the refcount goes negative and corrupts state.
            self.san.on_free(self.spec.name, eid, page.ref_count)
        page.ref_count -= 1
        if page.ref_count > 0:
            return
        self._uncache(page)
        self._evictable.discard(eid)
        page.state = PageState.EMPTY
        self._free_add(eid, page.owner_rid)
        self._maybe_release_large(page.large_id)

    def release_to_cache(self, eid: int, content_hash: Optional[int]) -> None:
        """Drop one reference; at refcount 0 the page becomes EVICTABLE and is
        registered in the prefix cache under ``content_hash``."""
        page = self.pages[eid]
        page.ref_count -= 1
        if page.ref_count > 0:
            return
        if content_hash is None:
            # Nothing reusable (e.g. partially filled page): plain free.
            page.ref_count += 1
            self.free(eid)
            return
        # Dedup: if another live page already serves this hash, keep that one
        # and plain-free ours.
        old = self.cached.get(content_hash)
        if old is not None and old != eid and old in self.pages:
            old_page = self.pages[old]
            if old_page.state != PageState.EMPTY:
                page.content_hash = None
                page.ref_count += 1
                self.free(eid)
                return
        if self.san is not None:
            self.san.on_cache(self.spec.name, eid, content_hash,
                              page.owner_rid)
        page.state = PageState.EVICTABLE
        page.content_hash = content_hash
        self.cached[content_hash] = eid
        self._push_evictable(page)
        self._maybe_mark_large_evictable(page.large_id)

    def register_hash(self, eid: int, content_hash: int) -> None:
        """Register a *running* request's full page in the prefix cache so
        concurrent requests can share it (cache-while-running)."""
        page = self.pages[eid]
        if self.san is not None:
            self.san.on_register(self.spec.name, eid, content_hash,
                                 page.owner_rid)
        page.content_hash = content_hash
        self.cached.setdefault(content_hash, eid)

    def mark_exported(self, eid: int, rid: str) -> None:
        """Flag a USED page as exported for a prefill->decode handoff. The
        pool state is unchanged — the page stays USED and refcounted by its
        owner (the copy stream still reads it) — but the sanitizer's shadow
        moves to IN_TRANSIT so free/cache/re-export while the handoff is
        pending are caught, and an abandoned export is reported at drain."""
        page = self.pages[eid]
        assert page.state == PageState.USED, (eid, page.state)
        if self.san is not None:
            self.san.on_export(self.spec.name, eid, rid)

    def mark_export_done(self, eid: int) -> None:
        """Handoff adopted (or cancelled): return the exported page to
        plain USED ownership so the exporter can free/cache it normally."""
        page = self.pages[eid]
        assert page.state == PageState.USED, (eid, page.state)
        if self.san is not None:
            self.san.on_export_done(self.spec.name, eid)

    def _uncache(self, page: SmallPage) -> None:
        if page.content_hash is not None:
            if self.cached.get(page.content_hash) == page.exec_id:
                del self.cached[page.content_hash]
            page.content_hash = None

    # ----------------------------------------------------- cache lookups
    def lookup(self, content_hash: int) -> Optional[int]:
        return self.cached.get(content_hash)

    def acquire_cached(self, eid: int, rid: str) -> int:
        """Re-reference a cached EVICTABLE page for a prefix hit (→ USED)."""
        page = self.pages[eid]
        if self.san is not None:
            self.san.on_acquire(self.spec.name, eid, rid,
                                page.state == PageState.EVICTABLE)
        if page.state == PageState.EVICTABLE:
            self._evictable.discard(eid)
            page.state = PageState.USED
            page.ref_count = 1
            page.owner_rid = rid
            self.large_alloc.unmark_evictable(page.large_id)
        elif page.state == PageState.USED:
            page.ref_count += 1
        else:
            raise ValueError(f"page {eid} is EMPTY; cannot acquire")
        return eid

    # ----------------------------------------------------------- eviction
    def _push_evictable(self, page: SmallPage) -> None:
        self._seq += 1
        page.seq = self._seq
        self._evictable.add(page.exec_id)
        heapq.heappush(
            self._evict_heap,
            (page.last_access, -page.prefix_length, self._seq, page.exec_id),
        )

    def reprioritize(self, eid: int) -> None:
        """Re-key an evictable page after ts / prefix_length changed."""
        page = self.pages.get(eid)
        if page is not None and page.state == PageState.EVICTABLE:
            self._push_evictable(page)

    def _pop_small_evictable(self) -> Optional[int]:
        while self._evict_heap:
            ts, negplen, seq, eid = heapq.heappop(self._evict_heap)
            page = self.pages.get(eid)
            if (
                page is not None
                and eid in self._evictable
                and page.seq == seq
                and page.state == PageState.EVICTABLE
            ):
                if self.san is not None:
                    self.san.on_evict(self.spec.name, eid)
                self._evictable.discard(eid)
                self._uncache(page)
                page.state = PageState.EMPTY
                self._free_add(eid, page.owner_rid)
                self.large_alloc.unmark_evictable(page.large_id)
                return eid
        return None

    def _evict_small(self, eid: int) -> None:
        """Force-evict a specific EVICTABLE page to EMPTY."""
        page = self.pages[eid]
        assert page.state == PageState.EVICTABLE, page
        if self.san is not None:
            self.san.on_evict(self.spec.name, eid)
        self._evictable.discard(eid)
        self._uncache(page)
        page.state = PageState.EMPTY
        self._free_add(eid, page.owner_rid)
        self.large_alloc.unmark_evictable(page.large_id)

    def evict_whole_large(self, large_id: int) -> None:
        """Evict every EVICTABLE small page of one of our large pages, then
        release it to the LCM allocator (§5.4 step 3 completion)."""
        assert large_id in self.owned_large
        for slot in range(self.spp):
            eid = self.exec_id(large_id, slot)
            page = self.pages[eid]
            if page.state == PageState.EVICTABLE:
                self._evict_small(eid)
            elif page.state == PageState.USED:
                raise ValueError(f"large page {large_id} has USED page {eid}")
        self._maybe_release_large(large_id)

    # ------------------------------------------------------------- stats
    def counts(self) -> Dict[str, int]:
        c = {"empty": len(self._free_global), "used": 0, "evictable": 0}
        n = len(self.pages)
        # evictable set may hold stale ids only transiently; count by state
        ev = sum(1 for e in self._evictable
                 if e in self.pages
                 and self.pages[e].state == PageState.EVICTABLE)
        c["evictable"] = ev
        c["used"] = n - c["empty"] - ev
        c["owned_large"] = len(self.owned_large)
        return c

    def iter_pages(self) -> Iterable[SmallPage]:
        return self.pages.values()

    def check_invariants(self) -> None:
        for eid, p in self.pages.items():
            assert p.exec_id == eid
            if p.state == PageState.EMPTY:
                assert eid in self._free_global, eid
                assert p.ref_count == 0
            elif p.state == PageState.USED:
                assert p.ref_count >= 1, eid
                assert eid not in self._free_global
            else:
                assert p.ref_count == 0
                assert eid not in self._free_global
                assert p.content_hash is not None
        for h, eid in self.cached.items():
            assert self.pages[eid].content_hash == h
