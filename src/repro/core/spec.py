"""KV-cache specifications for heterogeneous layer types (Jenga §3-§4).

Every *layer type* in a model (full attention, sliding-window attention,
Mamba state, vision-embedding cache, cross-attention KV, ...) declares a
``KVCacheSpec``: how many storage *units* one small page occupies, how many
tokens a small page holds, and which prefix-caching policy governs it.

Units are bf16 elements (2 bytes), the native storage dtype of the unified
KV buffer.  All LCM math operates on unit counts, which is equivalent to the
paper's byte-level math up to the constant factor.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

BYTES_PER_UNIT = 2  # bf16


def lcm(values: Sequence[int]) -> int:
    out = 1
    for v in values:
        out = math.lcm(out, int(v))
    return out


def gcd(values: Sequence[int]) -> int:
    out = 0
    for v in values:
        out = math.gcd(out, int(v))
    return out


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Memory spec for one layer *type* (a group of layers sharing a page size).

    Attributes:
      name: unique layer-type name, e.g. ``"full_attn"``, ``"swa"``,
        ``"mamba"``, ``"vision_embed"``, ``"cross_attn"``.
      kind: one of {"full_attn", "swa", "mamba", "vision_embed",
        "cross_attn", "rwkv"} — selects the default prefix-cache policy.
      num_layers: how many model layers belong to this type.
      tokens_per_page: tokens stored per small page (1 for state types:
        one Mamba/RWKV state snapshot is "one token" of storage).
      units_per_token_per_layer: bf16 units one token needs in ONE layer of
        this type (e.g. 2*kv_heads*head_dim for attention K+V).
      sliding_window: window size for kind=="swa".
      state_checkpoint_interval: for state types, cache a state snapshot
        every N tokens (paper §5.3 uses 512 for Mamba).
    """

    name: str
    kind: str
    num_layers: int
    tokens_per_page: int
    units_per_token_per_layer: int
    sliding_window: Optional[int] = None
    state_checkpoint_interval: int = 512

    @property
    def units_per_token(self) -> int:
        return self.units_per_token_per_layer * self.num_layers

    @property
    def page_units(self) -> int:
        """Small-page size in units (the paper's per-type page size)."""
        return self.units_per_token * self.tokens_per_page

    @property
    def page_bytes(self) -> int:
        return self.page_units * BYTES_PER_UNIT

    def pages_for_tokens(self, num_tokens: int) -> int:
        return -(-num_tokens // self.tokens_per_page)  # ceil div


def attention_spec(
    name: str,
    *,
    num_layers: int,
    kv_heads: int,
    head_dim: int,
    tokens_per_page: int = 16,
    kind: str = "full_attn",
    sliding_window: Optional[int] = None,
) -> KVCacheSpec:
    """K + V for ``num_layers`` attention layers."""
    return KVCacheSpec(
        name=name,
        kind=kind,
        num_layers=num_layers,
        tokens_per_page=tokens_per_page,
        units_per_token_per_layer=2 * kv_heads * head_dim,
        sliding_window=sliding_window,
    )


def mamba_spec(
    name: str,
    *,
    num_layers: int,
    conv_units: int,
    ssm_units: int,
    checkpoint_interval: int = 512,
) -> KVCacheSpec:
    """One Mamba state snapshot (conv state + SSM state) per 'token' of storage."""
    return KVCacheSpec(
        name=name,
        kind="mamba",
        num_layers=num_layers,
        tokens_per_page=1,
        units_per_token_per_layer=conv_units + ssm_units,
        state_checkpoint_interval=checkpoint_interval,
    )


def rwkv_spec(
    name: str,
    *,
    num_layers: int,
    att_state_units: int,
    shift_state_units: int,
    checkpoint_interval: int = 512,
) -> KVCacheSpec:
    return KVCacheSpec(
        name=name,
        kind="rwkv",
        num_layers=num_layers,
        tokens_per_page=1,
        units_per_token_per_layer=att_state_units + shift_state_units,
        state_checkpoint_interval=checkpoint_interval,
    )


def vision_embed_spec(
    name: str, *, hidden_units: int, tokens_per_page: int = 16
) -> KVCacheSpec:
    """Vision embedding cache: one hidden vector per image token (Jenga §6.2)."""
    return KVCacheSpec(
        name=name,
        kind="vision_embed",
        num_layers=1,
        tokens_per_page=tokens_per_page,
        units_per_token_per_layer=hidden_units,
    )


def cross_attention_spec(
    name: str,
    *,
    num_layers: int,
    kv_heads: int,
    head_dim: int,
    tokens_per_page: int = 16,
) -> KVCacheSpec:
    """Encoder K/V consumed by cross-attention (image/audio tokens)."""
    return KVCacheSpec(
        name=name,
        kind="cross_attn",
        num_layers=num_layers,
        tokens_per_page=tokens_per_page,
        units_per_token_per_layer=2 * kv_heads * head_dim,
    )


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Derived two-level geometry for a set of specs (Jenga §4.1, §4.4)."""

    specs: tuple[KVCacheSpec, ...]
    large_page_units: int          # LCM of all small-page sizes
    num_large_pages: int           # pool capacity
    mode: str = "lcm"              # "lcm" | "max" | "gcd" (baselines §4.4)

    @property
    def total_units(self) -> int:
        return self.large_page_units * self.num_large_pages

    @property
    def total_bytes(self) -> int:
        return self.total_units * BYTES_PER_UNIT

    def small_pages_per_large(self, spec: KVCacheSpec) -> int:
        if self.mode == "max":
            # §4.4 MAX baseline: every small page is padded to the max
            # small-page size, i.e. one small page per large page.
            return 1
        if self.mode == "gcd":
            raise ValueError(
                "GCD pages split small pages across large pages; infeasible "
                "for real kernels (§4.4) — modeled analytically in benchmarks"
            )
        return self.large_page_units // spec.page_units

    def spec_by_name(self, name: str) -> KVCacheSpec:
        for s in self.specs:
            if s.name == name:
                return s
        raise KeyError(name)


def make_geometry(
    specs: Sequence[KVCacheSpec],
    *,
    total_memory_bytes: int,
    mode: str = "lcm",
) -> PageGeometry:
    """Compute large-page size per §4.4 and fit the pool into the budget.

    mode="lcm" is Jenga; "max" pads every small page to the max small-page
    size (internal fragmentation baseline); "gcd" is analyzed analytically in
    the benchmarks (infeasible kernels, §4.4) but supported here for the
    allocator-level comparison.
    """
    if not specs:
        raise ValueError("at least one KVCacheSpec required")
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate spec names: {names}")
    sizes = [s.page_units for s in specs]
    if mode == "lcm":
        large = lcm(sizes)
    elif mode == "max":
        large = max(sizes)
    elif mode == "gcd":
        large = gcd(sizes)
    else:
        raise ValueError(f"unknown geometry mode {mode!r}")
    total_units = total_memory_bytes // BYTES_PER_UNIT
    num_large = total_units // large
    if num_large <= 0:
        raise ValueError(
            f"memory budget {total_memory_bytes}B < one large page "
            f"({large * BYTES_PER_UNIT}B; mode={mode})"
        )
    return PageGeometry(
        specs=tuple(specs),
        large_page_units=large,
        num_large_pages=num_large,
        mode=mode,
    )
