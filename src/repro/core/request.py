"""Core sequence state shared by the allocator and the serving engine."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MMItem:
    """One multi-modal item (image / audio segment) embedded in the token
    stream: tokens [start, start+length) are its placeholder positions.
    ``mm_hash`` identifies the content (drives vision/cross-attn caching)."""

    start: int
    length: int
    mm_hash: int


@dataclasses.dataclass
class SequenceState:
    """Host-side state of one sequence for the Jenga manager.

    ``page_tables[type]`` is the ordered small-page exec-id list for
    token-storage types (full_attn / swa / vision_embed / cross_attn);
    entries may be ``FREED`` (-1) once e.g. a sliding window passed them.
    ``state_pages[type]`` is the live recurrent-state page of state types;
    ``ckpt_pages[type][pos]`` are state snapshots at token position ``pos``.

    Delta protocol for device-side mirrors (the serving ModelRunner keeps
    persistent per-request block-table arrays and updates them incrementally
    instead of rebuilding O(pages) state per step):
      * appends are discovered by comparing mirrored length to
        ``len(page_tables[type])`` (the manager only ever appends);
      * mid-table frees (sliding-window retirement, vision free-on-consume)
        are published to the append-only ``freed_events`` log;
      * trailing pops (speculative-decode rollback under async scheduling)
        are published to ``trim_events`` as (type, new_length) — a mirror
        replays them as in-order length clamps, so a table that shrinks and
        regrows to the same length still re-syncs its tail correctly;
      * ``epoch`` is bumped whenever the tables are invalidated wholesale
        (request free / preemption) — a mirror with a stale epoch rebuilds.
    """

    FREED = -1

    rid: str
    tokens: List[int]
    mm_items: Tuple[MMItem, ...] = ()
    # Encoder-decoder models (Whisper-style): encoder frames form a separate
    # storage stream for cross-attention KV; ``start`` is the offset in that
    # stream, not in ``tokens``.
    encoder_items: Tuple[MMItem, ...] = ()
    num_computed: int = 0
    page_tables: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    page_hashes: Dict[str, List[Optional[int]]] = dataclasses.field(default_factory=dict)
    state_pages: Dict[str, int] = dataclasses.field(default_factory=dict)
    ckpt_pages: Dict[str, Dict[int, int]] = dataclasses.field(default_factory=dict)
    # number of leading pages per type that came from the prefix cache
    num_cached_pages: Dict[str, int] = dataclasses.field(default_factory=dict)
    prefix_hit_tokens: int = 0
    last_access: int = 0
    # mirror-delta protocol (see class docstring)
    epoch: int = 0
    freed_events: List[Tuple[str, int]] = dataclasses.field(
        default_factory=list)
    trim_events: List[Tuple[str, int]] = dataclasses.field(
        default_factory=list)

    def mark_freed(self, type_name: str, idx: int) -> None:
        """Set a page-table entry to FREED and publish the delta."""
        self.page_tables[type_name][idx] = self.FREED
        self.freed_events.append((type_name, idx))

    def mark_trimmed(self, type_name: str) -> None:
        """Publish that trailing entries were popped from a table
        (speculative rollback): mirrors clamp their synced length to the
        table's current length before re-appending."""
        self.trim_events.append((type_name, len(self.page_tables[type_name])))

    def bump_epoch(self) -> None:
        self.epoch += 1
        self.freed_events.clear()
        self.trim_events.clear()

    def append_token(self, tok: int) -> None:
        self.tokens.append(tok)

    @property
    def num_tokens(self) -> int:
        return len(self.tokens)

    def live_pages(self, type_name: str) -> List[int]:
        return [p for p in self.page_tables.get(type_name, []) if p != self.FREED]

    def is_image_pos(self, i: int) -> bool:
        return any(it.start <= i < it.start + it.length for it in self.mm_items)
