"""Jenga core: two-level LCM memory allocation + customizable prefix caching.

Public API re-exports.
"""
from .lcm_allocator import LargePageAllocator
from .layout import (
    TypeView,
    UnifiedLayout,
    attention_page_shape,
    state_page_shape,
    vision_page_shape,
)
from .manager import (
    JengaKVCacheManager,
    MemoryStats,
    StateCopyOp,
)
from .policies import (
    CrossAttentionPolicy,
    FullAttentionPolicy,
    LayerPolicy,
    SlidingWindowPolicy,
    StateSpacePolicy,
    VisionEmbedPolicy,
    make_policy,
)
from .request import MMItem, SequenceState
from .spec import (
    BYTES_PER_UNIT,
    KVCacheSpec,
    PageGeometry,
    attention_spec,
    cross_attention_spec,
    make_geometry,
    mamba_spec,
    rwkv_spec,
    vision_embed_spec,
)
from .typed_pool import PageState, SmallPage, TypedPool

__all__ = [
    "BYTES_PER_UNIT",
    "CrossAttentionPolicy",
    "FullAttentionPolicy",
    "JengaKVCacheManager",
    "KVCacheSpec",
    "LargePageAllocator",
    "LayerPolicy",
    "MMItem",
    "MemoryStats",
    "PageGeometry",
    "PageState",
    "SequenceState",
    "SlidingWindowPolicy",
    "SmallPage",
    "StateCopyOp",
    "StateSpacePolicy",
    "TypeView",
    "TypedPool",
    "UnifiedLayout",
    "VisionEmbedPolicy",
    "attention_page_shape",
    "attention_spec",
    "cross_attention_spec",
    "make_geometry",
    "make_policy",
    "mamba_spec",
    "rwkv_spec",
    "state_page_shape",
    "vision_embed_spec",
    "vision_page_shape",
]
