"""Stable hashing for prefix-cache chains (Jenga §5).

Pages are keyed by a chain hash over the request's *key stream*: token ids for
text positions, ``mix(mm_hash, offset)`` for positions inside a multi-modal
item (image patches are not tokens — their content hash identifies them).

State types (Mamba/RWKV) key snapshots by the chain hash at the checkpoint
position. All hashes are stable 64-bit values (splitmix64 mixing), so tests
and replays are deterministic across processes.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from .request import MMItem

_MASK = (1 << 64) - 1


def mix64(x: int) -> int:
    """splitmix64 finalizer."""
    x &= _MASK
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


def combine(h: int, v: int) -> int:
    return mix64(h ^ mix64(v))


def salt_of(name: str) -> int:
    h = 0xCBF29CE484222325
    for ch in name.encode():
        h = ((h ^ ch) * 0x100000001B3) & _MASK
    return h


def key_stream(tokens: Sequence[int], mm_items: Sequence[MMItem]) -> List[int]:
    """Per-position content keys (text token id, or mm-content key)."""
    keys = [int(t) for t in tokens]
    for it in mm_items:
        for off in range(it.length):
            pos = it.start + off
            if pos < len(keys):
                keys[pos] = combine(it.mm_hash, off)
    return keys


def page_chain_hashes(
    keys: Sequence[int], tokens_per_page: int, salt: int
) -> List[int]:
    """Chain hash per FULL page: h_i = H(salt, h_{i-1}, keys of page i)."""
    out: List[int] = []
    h = salt
    n_full = len(keys) // tokens_per_page
    for i in range(n_full):
        for k in keys[i * tokens_per_page : (i + 1) * tokens_per_page]:
            h = combine(h, k)
        out.append(h)
    return out


def prefix_hash(keys: Sequence[int], upto: int, salt: int) -> int:
    """Chain hash over keys[:upto] — snapshot key for state types."""
    h = salt
    for k in keys[:upto]:
        h = combine(h, k)
    return h


def mm_stream_page_hashes(
    mm_items: Sequence[MMItem], tokens_per_page: int, salt: int,
    upto_pos: Optional[int] = None,
) -> List[int]:
    """Chain hashes over the *storage stream* of vision/cross types: the
    concatenation of mm items (text positions store nothing there).

    If ``upto_pos`` is given, only storage tokens at main-sequence position
    < upto_pos are included (used when consuming partial prompts)."""
    keys: List[int] = []
    for it in mm_items:
        for off in range(it.length):
            if upto_pos is not None and it.start + off >= upto_pos:
                break
            keys.append(combine(it.mm_hash, off))
    return page_chain_hashes(keys, tokens_per_page, salt)
