"""Layer-type prefix-caching policies — the paper's ``LayerSupportsPrefixCache``
interface (Fig. 9) with the §5.3 customizations.

Each policy expresses, for its layer type:
  * ``update_last_access``   — which pages count as "accessed" this step
                               (balanced eviction, §5.1);
  * ``set_prefix_length``    — fine-grained eviction priority among pages with
                               equal timestamps (aligned eviction, §5.1);
  * ``get_possible_prefix``  — which main-sequence prefix lengths are valid
                               cache hits given per-token availability (§5.2).

``is_hit[i]`` means: the KV/state this type needs *for token i* is present in
this type's cache. Types that store nothing for a position (e.g. text tokens
in a vision-embedding cache) report ``True`` there vacuously.
"""
from __future__ import annotations

import random
from typing import TYPE_CHECKING, List, Optional, Set

from .request import SequenceState
from .spec import KVCacheSpec

if TYPE_CHECKING:  # pragma: no cover
    from .typed_pool import TypedPool


def _aligned_prefixes(n: int, align: int) -> List[int]:
    """Candidate page-aligned prefix lengths 0, align, 2*align, ... <= n."""
    return list(range(0, n + 1, align))


class LayerPolicy:
    """Base: full-prefix dependency (standard self-attention)."""

    def __init__(self, spec: KVCacheSpec):
        self.spec = spec

    # ------------------------------------------------------------- eviction
    def update_last_access(self, pool: "TypedPool", req: SequenceState, time: int) -> None:
        """Default: every live page of the request is accessed every step."""
        for eid in req.live_pages(self.spec.name):
            pool.pages[eid].last_access = time

    def set_prefix_length(self, pool: "TypedPool", req: SequenceState,
                          rng: Optional[random.Random] = None) -> None:
        """Default: ordinal position — later tokens evicted first (§5.1)."""
        for i, eid in enumerate(req.page_tables.get(self.spec.name, [])):
            if eid != SequenceState.FREED:
                pool.pages[eid].prefix_length = i

    # ------------------------------------------------------------ cache hit
    def get_possible_prefix(self, is_hit: List[bool], req: SequenceState) -> Set[int]:
        """Full attention: prefix p valid iff tokens [0, p) all hit."""
        tpp = self.spec.tokens_per_page
        out: Set[int] = {0}
        for p in _aligned_prefixes(len(is_hit), tpp):
            if p == 0:
                continue
            if all(is_hit[:p]):
                out.add(p)
            else:
                break
        return out

    # ------------------------------------------- in-flight page retirement
    def retire_pages(self, req: SequenceState) -> List[int]:
        """Page-table indices whose pages are no longer needed by the running
        request (Jenga frees them early; vLLM keeps them — Fig. 16 waste)."""
        return []


class FullAttentionPolicy(LayerPolicy):
    pass


class SlidingWindowPolicy(LayerPolicy):
    """§5.3: only the last ``window`` tokens matter."""

    def __init__(self, spec: KVCacheSpec):
        super().__init__(spec)
        if spec.sliding_window is None:
            raise ValueError("SWA spec needs sliding_window")
        self.window = spec.sliding_window

    def update_last_access(self, pool, req, time) -> None:
        tpp = self.spec.tokens_per_page
        lo_tok = max(0, req.num_computed - self.window)
        lo_page = lo_tok // tpp
        table = req.page_tables.get(self.spec.name, [])
        for eid in table[lo_page:]:
            if eid != SequenceState.FREED:
                pool.pages[eid].last_access = time

    def get_possible_prefix(self, is_hit: List[bool], req: SequenceState) -> Set[int]:
        """p valid iff tokens [max(0, p-window), p) all hit (page aligned)."""
        tpp = self.spec.tokens_per_page
        n = len(is_hit)
        # prefix-sum of hits for O(1) range checks
        ps = [0]
        for h in is_hit:
            ps.append(ps[-1] + (1 if h else 0))
        out: Set[int] = {0}
        for p in _aligned_prefixes(n, tpp)[1:]:
            lo = max(0, p - self.window)
            # the page containing lo must be intact from its start
            lo = (lo // tpp) * tpp
            if ps[p] - ps[lo] == p - lo:
                out.add(p)
        return out

    def retire_pages(self, req: SequenceState) -> List[int]:
        """Pages entirely below the window can be dropped mid-request."""
        tpp = self.spec.tokens_per_page
        lo_tok = max(0, req.num_computed - self.window)
        lo_page = lo_tok // tpp  # pages [0, lo_page) are fully out of window
        table = req.page_tables.get(self.spec.name, [])
        return [i for i in range(min(lo_page, len(table)))
                if table[i] != SequenceState.FREED]


class StateSpacePolicy(LayerPolicy):
    """Mamba/RWKV (§5.3): fixed-size recurrent state; snapshots cached every
    ``state_checkpoint_interval`` tokens; only the snapshot at the hit
    position is needed."""

    def __init__(self, spec: KVCacheSpec):
        super().__init__(spec)
        self.interval = spec.state_checkpoint_interval

    def update_last_access(self, pool, req, time) -> None:
        # Only the live state page + the latest checkpoint are "accessed".
        name = self.spec.name
        if name in req.state_pages:
            pool.pages[req.state_pages[name]].last_access = time
        ckpts = req.ckpt_pages.get(name, {})
        if ckpts:
            pool.pages[ckpts[max(ckpts)]].last_access = time

    def set_prefix_length(self, pool, req, rng=None) -> None:
        name = self.spec.name
        for pos, eid in req.ckpt_pages.get(name, {}).items():
            pool.pages[eid].prefix_length = pos
        if name in req.state_pages:
            pool.pages[req.state_pages[name]].prefix_length = req.num_computed

    def get_possible_prefix(self, is_hit: List[bool], req: SequenceState) -> Set[int]:
        """is_hit[i] == snapshot for prefix length i+1 is cached."""
        out: Set[int] = {0}
        for p in range(self.interval, len(is_hit) + 1, self.interval):
            if is_hit[p - 1]:
                out.add(p)
        return out


class VisionEmbedPolicy(LayerPolicy):
    """§5.3: evict whole images — randomized per-image priority; an image is
    hit only if every one of its pages is cached; prefixes may not split a
    partially-cached image."""

    def update_last_access(self, pool, req, time) -> None:
        for eid in req.live_pages(self.spec.name):
            pool.pages[eid].last_access = time

    def set_prefix_length(self, pool, req, rng=None) -> None:
        rng = rng or random.Random(0)
        name = self.spec.name
        table = req.page_tables.get(name, [])
        tpp = self.spec.tokens_per_page
        # storage stream = concatenated mm items; map pages -> item index
        bounds = []  # (item_idx, first_storage_tok, last_storage_tok)
        off = 0
        items = req.encoder_items or req.mm_items
        for idx, it in enumerate(items):
            bounds.append((idx, off, off + it.length))
            off += it.length
        pri = {idx: rng.randrange(1 << 30) for idx, _, _ in bounds}
        for pi, eid in enumerate(table):
            if eid == SequenceState.FREED:
                continue
            tok = pi * tpp
            for idx, lo, hi in bounds:
                if lo <= tok < hi:
                    pool.pages[eid].prefix_length = pri[idx]
                    break

    def get_possible_prefix(self, is_hit: List[bool], req: SequenceState) -> Set[int]:
        """``is_hit`` is indexed over this type's *storage stream* (the
        concatenation of mm items)."""
        valid_upto = len(req.tokens)
        off = 0
        for it in req.mm_items:
            span_hit = all(is_hit[off : off + it.length])
            off += it.length
            if not span_hit:
                valid_upto = min(valid_upto, it.start)
        return set(range(0, valid_upto + 1))


class CrossAttentionPolicy(VisionEmbedPolicy):
    """Encoder-KV cache for cross-attention layers.

    Two flavours: (a) in-stream items (Llama-3.2-Vision pattern, §3.2) —
    identical to the vision-embedding semantics; (b) a separate encoder
    stream (Whisper-style enc-dec) — the decoder needs the *entire* encoder
    KV at every step, so hits are all-or-nothing."""

    def get_possible_prefix(self, is_hit: List[bool], req: SequenceState) -> Set[int]:
        if req.encoder_items:
            total = sum(it.length for it in req.encoder_items)
            if all(is_hit[:total]):
                return set(range(0, len(req.tokens) + 1))
            return {0}
        return super().get_possible_prefix(is_hit, req)


POLICY_BY_KIND = {
    "full_attn": FullAttentionPolicy,
    "swa": SlidingWindowPolicy,
    "mamba": StateSpacePolicy,
    "rwkv": StateSpacePolicy,
    "vision_embed": VisionEmbedPolicy,
    "cross_attn": CrossAttentionPolicy,
}


def make_policy(spec: KVCacheSpec) -> LayerPolicy:
    try:
        cls = POLICY_BY_KIND[spec.kind]
    except KeyError:
        raise ValueError(f"no policy for layer kind {spec.kind!r}") from None
    return cls(spec)
