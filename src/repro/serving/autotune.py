"""Roofline-driven token-budget autotuning (closes the ROADMAP loop:
"pick token budgets from the roofline model instead of constants").

Seeding: the serving sweet spot for ``max_num_batched_tokens`` is the
compute/memory balance point of one step. A step reads every (total)
weight byte once from HBM and spends ~2 * n_active FLOPs per token, so
the step flips from bandwidth-bound to compute-bound around

    T* = PEAK_FLOPS * (2 bytes * n_total) / (HBM_BW * 2 FLOPs * n_active)
       = (PEAK_FLOPS / HBM_BW) * n_total / n_active

tokens (~240 for a dense model on the modeled chip; higher for MoE,
whose total/active ratio > 1). Below T* extra tokens in a step are
nearly free — the budget should at least reach it. A fraction of the
budget is reserved for decodes (``max_prefill_tokens_per_step``), the
scheduler's latency knob.

Online refinement (``observe``): live ``StepMetrics`` correct the static
model. When the host build dominates device wait, the step is
host-bound: bigger steps amortize host work — grow the budget. When the
modeled attention arithmetic intensity of recent steps falls under the
machine balance, attention has gone memory-bound (long contexts): shrink
the prefill cap so decode latency is not paying for bandwidth-bound
prefill work. One adjustment per observation window avoids oscillation.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional

from ..launch.roofline import HBM_BW, PEAK_FLOPS, count_params

QUANTUM = 16          # packed-stream token bucket quantum (_tok_bucket)
MIN_BUDGET = 32
MAX_BUDGET = 4096


def _round_q(n: float) -> int:
    return QUANTUM * max(1, round(n / QUANTUM))


def shard_pool_bytes(total_bytes: int, num_shards: int) -> int:
    """Even split of a fleet-wide KV pool across data-parallel shards.
    Each shard's manager builds its own LCM geometry from its slice; the
    floor just keeps a degenerate split from rounding to zero."""
    return max(1, total_bytes // max(1, num_shards))


def roofline_token_budget(model_cfg) -> int:
    """Compute/memory balance point T* of one serving step for this model
    config, rounded to the packed-stream bucket quantum."""
    n = count_params(model_cfg)
    t_star = (PEAK_FLOPS / HBM_BW) * n["total"] / max(1, n["active"])
    return max(MIN_BUDGET, min(MAX_BUDGET, _round_q(t_star)))


@dataclasses.dataclass
class BudgetAutotuner:
    """Seeds scheduler budgets from the roofline model and refines them
    online from live StepMetrics. The engine applies ``budget`` /
    ``prefill_cap`` whenever ``observe`` returns True."""

    model_cfg: object
    decode_reserve: float = 0.25     # budget fraction kept for decodes
    window: int = 16                 # steps per observation window
    # Data-parallel shard budgets: the roofline balance point T* is PER
    # DEVICE — a shard serving 1/N of the fleet's traffic still flips from
    # bandwidth- to compute-bound at the same step size, so the seed budget
    # does NOT shrink with the fleet. What does scale is the observation
    # window: a shard sees ~1/N of the arrivals, so it needs ~N× the steps
    # for an equally confident host-vs-device / bytes-growth trend before
    # it moves its budgets.
    num_shards: int = 1
    budget: int = dataclasses.field(init=False)
    prefill_cap: int = dataclasses.field(init=False)

    def __post_init__(self):
        self.budget = roofline_token_budget(self.model_cfg)
        self.prefill_cap = max(
            QUANTUM, _round_q(self.budget * (1.0 - self.decode_reserve)))
        self.window = int(self.window * max(1, self.num_shards))
        self._hist: Deque = deque(maxlen=self.window)
        self.adjustments = 0

    def observe(self, m) -> bool:
        """Feed one StepMetrics; returns True when budgets changed."""
        self._hist.append(m)
        if len(self._hist) < self.window:
            return False
        n = len(self._hist)
        # host side includes sampling (0 under device sampling); the device
        # side prefers the pipeline timing split's compute estimate when
        # the engine runs deep enough to report it (depth > 1), falling
        # back to the blocked-fetch wait (sync loop / metrics without the
        # split). Comparing host-vs-fetch alone would under-read device
        # time exactly when pipelining hides it best.
        host = sum(x.host_build_ms + getattr(x, "host_sample_ms", 0.0)
                   for x in self._hist) / n
        disp = sum(x.dispatch_compute_ms
                   if getattr(x, "dispatch_compute_ms", 0.0) > 0
                   else x.dispatch_ms for x in self._hist) / n
        half = n // 2
        byts_early = sum(x.attn_bytes_modeled
                         for x in list(self._hist)[:half])
        byts_late = sum(x.attn_bytes_modeled
                        for x in list(self._hist)[half:])
        floor = max(QUANTUM, _round_q(self.budget / 2))
        changed = False
        if host > disp and self.budget < MAX_BUDGET:
            # host-bound: bigger steps amortize schedule + batch build
            self.budget = min(MAX_BUDGET, _round_q(self.budget * 1.5))
            self.prefill_cap = max(
                self.prefill_cap,
                _round_q(self.budget * (1.0 - self.decode_reserve)))
            changed = True
        elif byts_late > 1.5 * byts_early and self.prefill_cap > floor:
            # attention HBM traffic is growing fast (contexts outrunning
            # the block-sparse skip): reserve more of the step for decodes
            # instead of bandwidth-bound prefill work. Floor at half the
            # budget so prefill throughput never collapses.
            self.prefill_cap = max(floor, _round_q(self.prefill_cap / 2))
            changed = True
        if changed:
            self.adjustments += 1
            self._hist.clear()
        return changed
