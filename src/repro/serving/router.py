"""Cache-aware request router for data-parallel multi-engine serving.

Jenga's evaluation (and vLLM's production deployments) put the allocator
inside a FLEET of engine replicas: N independent engines, each with its
own ``JengaKVCacheManager`` / scheduler / in-flight ring, behind a
front-end router that decides which shard serves each request. The router
here implements the placement policy; the fleet orchestration (stepping,
health polling, failover) lives in ``serving.dp_engine``.

Placement (``Router.place``) is CACHE-AWARE: the request's prompt
boundary-hash chains (``Request.prompt_boundary_hashes`` /
``prompt_state_hashes`` — the exact keys each shard's pools register
pages under) are probed against every accepting shard's prefix cache, and
the shard holding the longest chain match wins: prefix-cache hits are the
single biggest per-request cost lever (hit tokens are never recomputed),
and only the shard that computed a prefix has it cached. Ties — and the
no-hit case — fall back to LEAST-LOADED by outstanding token count, then
to the lowest shard id, so placement is a deterministic function of
(config, arrival order, shard state): replaying the same workload
reproduces the same placements bit for bit.

Health feeds back as a routing COST in token units: every poll the router
reads each shard's cumulative defer/preempt counters (``ShardHealth``);
a positive delta bumps the shard's cost, quiet polls decay it. The cost
subtracts from the shard's hit score — a shard thrashing at its memory
ceiling stops attracting traffic even where its cache matches, which is
the backpressure half of the paper's fleet story: more traffic to a
defer-then-preempt-ing shard shrinks its batches further.

``policy="round-robin"`` keeps a placement-blind baseline for A/Bs
(``bench_throughput.run_router_ab`` measures the prefix-hit-rate gap).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .engine import ShardHealth
from .request import Request

ROUTE_CACHE_AWARE = "cache-aware"
ROUTE_ROUND_ROBIN = "round-robin"
ROUTE_LEAST_LOADED = "least-loaded"
POLICIES = (ROUTE_CACHE_AWARE, ROUTE_ROUND_ROBIN, ROUTE_LEAST_LOADED)


@dataclasses.dataclass
class RouterConfig:
    num_shards: int = 2
    policy: str = ROUTE_CACHE_AWARE
    # health costing, in TOKEN units so it compares against prefix-hit
    # lengths: each defer/preempt event observed in a health poll bumps the
    # shard's routing cost by ``cost_per_event``; a poll with no new events
    # decays it by ``cost_decay``. With 16-token pages, one event outweighs
    # a one-page hit — repeated thrashing outweighs any realistic hit.
    cost_per_event: float = 16.0
    cost_decay: float = 0.5
    # recorded for reproducibility bookkeeping (placement itself is a
    # deterministic function of arrival order + shard state; the seed is
    # part of the workload identity tests replay under)
    seed: int = 0


@dataclasses.dataclass
class Placement:
    """One routing decision, recorded for determinism tests and benches."""
    rid: str
    shard: int
    hit_tokens: int            # boundary-hash chain match on the winner
    load_tokens: int           # winner's outstanding tokens at placement
    cost: float                # winner's health cost at placement
    readmitted: bool = False   # re-placed after a shard drain/failover


def prefix_match_tokens(req: Request, mgr) -> int:
    """Longest prompt prefix (in tokens) whose boundary-hash chain is held
    by ``mgr``'s prefix cache, across this model's cache types.

    Token-storage types (full_attn/swa) match their per-page chain hashes
    in order and stop at the first miss (a broken chain cannot be
    extended); state types (mamba/rwkv) match checkpoint-boundary hashes
    (any boundary hit restores to that position, so the LAST hit wins).
    The joint estimate is the MIN across types — a prefix only restores if
    every type can serve it (the router-side approximation of the §5.2
    intersection the shard's ``lookup_prefix`` computes exactly at
    admission). mm/cross-attn streams are content-addressed per item and
    carry no prefix ordering, so they do not vote."""
    if not mgr.enable_prefix_caching:
        return 0
    best: Optional[int] = None
    for spec in mgr.specs:
        pool = mgr.pools[spec.name]
        salt = mgr.salts[spec.name]
        if spec.kind in ("full_attn", "swa"):
            n_pages = 0
            for h in req.prompt_boundary_hashes(spec.tokens_per_page, salt):
                if pool.lookup(h) is None:
                    break
                n_pages += 1
            tokens = n_pages * spec.tokens_per_page
        elif spec.kind in ("mamba", "rwkv"):
            tokens = 0
            for pos, h in req.prompt_state_hashes(
                    spec.state_checkpoint_interval, salt):
                if pool.lookup(h) is not None:
                    tokens = pos
        else:
            continue
        best = tokens if best is None else min(best, tokens)
    if best is None:
        return 0
    # at least one prompt token must be computed (mirrors lookup_prefix)
    return min(best, max(0, len(req.prompt) - 1))


class Router:
    """Placement policy + health costing over a fleet of engine shards.

    The router never touches the shards itself — ``place`` reads their
    caches/loads and returns a shard id; ``observe`` digests health
    snapshots the fleet driver polls. ``shards`` is any sequence of
    objects with ``.accepting`` (bool) and ``.engine`` (an ``Engine``)."""

    def __init__(self, cfg: RouterConfig):
        assert cfg.policy in POLICIES, cfg.policy
        assert cfg.num_shards >= 1, cfg.num_shards
        self.cfg = cfg
        self.costs: List[float] = [0.0] * cfg.num_shards
        self.placements: List[Placement] = []
        self._rr = 0
        self._events_seen: Dict[int, int] = {}

    # ------------------------------------------------------------- health
    def observe(self, shard_id: int, health: ShardHealth) -> None:
        """Fold one shard health snapshot into its routing cost: new
        defer/preempt events bump it, quiet polls decay it toward zero."""
        now = health.defer_count + health.preemption_count
        delta = now - self._events_seen.get(shard_id, 0)
        self._events_seen[shard_id] = now
        if delta > 0:
            self.costs[shard_id] += self.cfg.cost_per_event * delta
        else:
            self.costs[shard_id] *= self.cfg.cost_decay
            if self.costs[shard_id] < 1e-9:
                self.costs[shard_id] = 0.0

    # ---------------------------------------------------------- placement
    def place(self, req: Request, shards: Sequence, *,
              readmitted: bool = False,
              want: Optional[str] = None) -> int:
        """Pick the shard for ``req``. Deterministic: cache-aware score
        (hit tokens minus health cost) first, least-loaded second, lowest
        shard id third. Raises if no shard is accepting.

        ``want`` restricts candidates by disaggregation role:
        ``"prefill"`` (fresh arrivals — prefill-capable shards) or
        ``"decode"`` (handoff targets — decode-capable shards); colocated
        ``"both"`` shards qualify for either. If no accepting shard has a
        qualifying role the filter is DROPPED rather than failing — a
        degraded fleet (all decode shards dead) keeps serving colocated."""
        cands = [i for i, sh in enumerate(shards) if sh.accepting]
        if want is not None:
            roled = [i for i in cands
                     if getattr(shards[i].engine, "role", "both")
                     in ("both", want)]
            if roled:
                cands = roled
        if not cands:
            raise RuntimeError("router: no accepting shard")
        policy = self.cfg.policy
        if policy == ROUTE_ROUND_ROBIN:
            best = cands[self._rr % len(cands)]
            self._rr += 1
            hit = 0
        else:
            hits = {
                i: (prefix_match_tokens(req, shards[i].engine.mgr)
                    if policy == ROUTE_CACHE_AWARE else 0)
                for i in cands
            }
            loads = {i: shards[i].engine.outstanding_tokens() for i in cands}
            best = max(cands, key=lambda i: (hits[i] - self.costs[i],
                                             -loads[i], -i))
            hit = hits[best]
        req.shard_history.append(best)
        self.placements.append(Placement(
            rid=req.rid, shard=best, hit_tokens=hit,
            load_tokens=shards[best].engine.outstanding_tokens(),
            cost=self.costs[best], readmitted=readmitted))
        return best
