from .engine import (Engine, EngineConfig, ShardHealth, StepMetrics,
                     stub_modality_embed)
from ..core.request import MMItem
from .request import Request, SamplingParams, Status
from .sampler import TIE_EPS, greedy_token, host_sample, rid_hash
from .scheduler import ScheduledSeq, Scheduler, SchedulerConfig, StepPlan
from .runner import ModelRunner, StepHandle
from .router import (ROUTE_CACHE_AWARE, ROUTE_LEAST_LOADED,
                     ROUTE_ROUND_ROBIN, Placement, Router, RouterConfig,
                     prefix_match_tokens)
from .dp_engine import DPEngine, EngineShard
