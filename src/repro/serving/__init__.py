from .engine import Engine, EngineConfig, StepMetrics, stub_modality_embed
from ..core.request import MMItem
from .request import Request, SamplingParams, Status
from .scheduler import ScheduledSeq, Scheduler, SchedulerConfig, StepPlan
from .runner import ModelRunner
