from .engine import Engine, EngineConfig, StepMetrics, stub_modality_embed
from ..core.request import MMItem
from .request import Request, SamplingParams, Status
from .sampler import TIE_EPS, greedy_token, host_sample, rid_hash
from .scheduler import ScheduledSeq, Scheduler, SchedulerConfig, StepPlan
from .runner import ModelRunner, StepHandle
