"""Inference engine: scheduler + Jenga manager + model runner.

Each ``step()``: schedule -> (state restores) -> one prefill chunk ->
decode batch -> sample -> advance/checkpoint/retire -> finish.
Collects the per-step metrics the paper's figures are built from
(decode batch size Fig.15, memory breakdown Fig.16, hit rates Fig.17,
encoder runs Fig.18)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.manager import JengaKVCacheManager
from ..core.spec import KVCacheSpec
from .request import Request, SamplingParams, Status
from .runner import ModelRunner
from .scheduler import Scheduler, SchedulerConfig


def stub_modality_embed(mm_hash: int, offset: int, dim: int) -> np.ndarray:
    """Deterministic stand-in for the vision/audio frontend (assignment:
    frontends are stubs; embeddings are 'precomputed')."""
    rng = np.random.default_rng((mm_hash & 0xFFFFFFFF, offset))
    return (0.05 * rng.standard_normal(dim)).astype(np.float32)


@dataclasses.dataclass
class EngineConfig:
    kv_pool_bytes: int = 64 << 20
    max_running: int = 16
    chunk_size: int = 64
    enable_prefix_caching: bool = True
    memory_mode: str = "jenga"       # "jenga" | "paged-baseline"
    geometry_mode: str = "lcm"        # "lcm" | "max"
    seed: int = 0


@dataclasses.dataclass
class StepMetrics:
    step: int
    decode_batch: int
    prefill_tokens: int
    used_units: int
    evictable_units: int
    empty_units: int
    free_units: int
    waste_units: int = 0


class Engine:
    def __init__(self, model, cfg: EngineConfig,
                 params=None, seed: int = 0):
        self.model = model
        self.cfg = cfg
        baseline = cfg.memory_mode == "paged-baseline"
        self.mgr = JengaKVCacheManager(
            model.kv_specs(),
            total_memory_bytes=cfg.kv_pool_bytes,
            mode=cfg.geometry_mode,
            enable_prefix_caching=cfg.enable_prefix_caching,
            enable_inflight_retirement=not baseline,
            seed=cfg.seed,
        )
        if baseline:
            self._apply_baseline_semantics()
        self.scheduler = Scheduler(
            self.mgr, SchedulerConfig(max_running=cfg.max_running,
                                      chunk_size=cfg.chunk_size))
        self.runner = ModelRunner(model, self.mgr,
                                  stub_embed_fn=stub_modality_embed)
        self.params = params if params is not None else model.init(seed)
        self.step_count = 0
        self.metrics: List[StepMetrics] = []
        self.encoder_runs = 0
        self.mm_seen: set = set()
        self.finished: List[Request] = []

    # ------------------------------------------------- baseline semantics
    def _apply_baseline_semantics(self):
        """PagedAttention-style baseline (paper §3.2): all layer types are
        treated as full-prefix self-attention — mm/cross caches allocate
        pages for EVERY token, sliding windows never retire, eviction is a
        single uncustomized LRU."""
        from ..core.policies import FullAttentionPolicy
        mgr = self.mgr
        for name, spec in ((s.name, s) for s in mgr.specs):
            if spec.kind in ("swa", "vision_embed", "cross_attn"):
                pol = FullAttentionPolicy(spec)
                mgr.policies[name] = pol
        orig = mgr._mm_storage_upto

        def all_tokens(req, spec, main_pos):
            if spec.kind in ("vision_embed", "cross_attn") and not \
                    req.encoder_items:
                return main_pos            # every token, image or not
            return orig(req, spec, main_pos)

        mgr._mm_storage_upto = all_tokens

    # -------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        req.arrival = self.step_count
        self.scheduler.add(req)

    # ---------------------------------------------------------------- step
    def step(self) -> Optional[StepMetrics]:
        if not self.scheduler.has_work():
            return None
        plan = self.scheduler.schedule()
        for op in plan.copy_ops:
            self.runner.copy_page(op.type_name, op.src_page, op.dst_page)

        # ---- one prefill chunk
        if plan.prefill is not None:
            req = plan.prefill
            seq = req.seq
            if (self.model.cfg.family in ("vlm", "encdec")
                    and seq.num_computed == 0):
                items = seq.mm_items or seq.encoder_items
                for it in items:
                    if it.mm_hash not in self.mm_seen or not \
                            self.cfg.enable_prefix_caching:
                        self.encoder_runs += 1
                        self.mm_seen.add(it.mm_hash)
            logits = self.runner.run(self.params, [req], prefill=True,
                                     chunk=plan.prefill_tokens)
            n = plan.prefill_tokens
            ops = self.mgr.advance(seq, n)
            for op in ops:
                self.runner.copy_page(op.type_name, op.src_page, op.dst_page)
            self.mgr.consume_mm(seq, seq.num_computed)
            self.mgr.touch(seq)
            if not req.in_prefill:      # prompt complete -> first token
                tok = self._sample(req, logits[0])
                req.output.append(tok)
                seq.append_token(tok)
                req.first_token_step = self.step_count
                self._maybe_finish(req)

        # ---- decode batch
        if plan.decodes:
            logits = self.runner.run(self.params, plan.decodes, prefill=False)
            for i, req in enumerate(plan.decodes):
                seq = req.seq
                ops = self.mgr.advance(seq, 1)
                for op in ops:
                    self.runner.copy_page(op.type_name, op.src_page,
                                          op.dst_page)
                self.mgr.touch(seq)
                tok = self._sample(req, logits[i])
                req.output.append(tok)
                seq.append_token(tok)
                self._maybe_finish(req)

        stats = self.mgr.memory_stats()
        m = StepMetrics(
            step=self.step_count,
            decode_batch=len(plan.decodes),
            prefill_tokens=plan.prefill_tokens,
            used_units=stats.used_units,
            evictable_units=stats.evictable_units,
            empty_units=stats.empty_units,
            free_units=stats.free_units,
        )
        self.metrics.append(m)
        self.step_count += 1
        return m

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        v = self.model.cfg.vocab_size
        logits = logits[:v]
        if req.sampling.temperature <= 0:
            return int(np.argmax(logits))
        rng = np.random.default_rng(
            (req.sampling.seed, len(req.output), hash(req.rid) & 0xFFFF))
        p = logits / req.sampling.temperature
        p = np.exp(p - p.max())
        p /= p.sum()
        return int(rng.choice(v, p=p))

    def _maybe_finish(self, req: Request) -> None:
        if req.is_done():
            req.finished_step = self.step_count
            self.scheduler.finish(req, cache=True)
            self.finished.append(req)

    # ----------------------------------------------------------------- run
    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        while self.scheduler.has_work() and self.step_count < max_steps:
            self.step()
        return self.finished
