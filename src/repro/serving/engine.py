"""Inference engine: token-budget continuous batching over the Jenga
manager.

Each ``step()`` is build-batch -> ONE ``serve_step`` dispatch -> advance /
sample / retire:

  1. ``Scheduler.schedule()`` packs a per-step token budget across ALL
     running requests — any number of concurrent prefill chunks plus every
     decode — and commits the step's page allocation transactionally;
  2. the step's state-restore copies run as one batched dispatch;
  3. ``ModelRunner.prepare``/``dispatch`` executes the whole mixed plan in
     a single jitted ``serve_step`` — token-packed into one (total_tokens,)
     stream with per-token segment ids by default ("packed"), or as
     (B, T)-padded rows under the PR-1 layout ("padded");
  4. every scheduled request advances; the engine samples PER SEGMENT
     (logits come back one row per scheduled item, in plan order);
     checkpoint copies emitted by ``advance`` run as one batched dispatch
     at the end of the step.

ASYNC SCHEDULING (``EngineConfig.async_scheduling``, double-buffered):
while step N's dispatch is in flight on the device, the host plans step
N+1 and builds its packed batch — sampling and advancing step N happen one
step later, when its logits are fetched. Decode rows in plan N+1 are
scheduled SPECULATIVELY (each running decode assumed to produce +1 token,
vLLM async-scheduling style) with their pages pre-committed through the
manager's transactional ``allocate_for_batch``; when the fetched logits
reveal a request actually finished (EOS / token budget), its segment in
the already-built batch is neutralized to pad semantics and its
speculative +1 page commitment rolled back (``mgr.rollback_tokens``)
before the batch is dispatched. Greedy outputs are bit-identical to the
synchronous loop: segments are isolated by the packed segment mask, so a
dead slot changes nothing for its neighbours, and recompute preemption is
semantically transparent. ``async_scheduling`` composes with
``batching_mode`` "packed" and "padded"; "serial" (two dispatch groups per
step) falls back to the synchronous loop.

``batching_mode="serial"`` reproduces the legacy one-prefill-chunk-per-step
engine (prefill and decode as separate dispatches) for step-count A/Bs and
determinism tests.

Collects the per-step metrics the paper's figures are built from (decode
batch size Fig.15, memory breakdown Fig.16, hit rates Fig.17, encoder runs
Fig.18) plus the mixed-batch packing stats (tokens/step, prefills/step),
dispatch-waste counters (tokens vs slots paid), and the host-build /
device-wait timings the async overlap is measured by."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.manager import JengaKVCacheManager, StateCopyOp
from ..core.spec import KVCacheSpec
from .request import Request, SamplingParams, Status
from .runner import ModelRunner, PreparedStep
from .scheduler import ScheduledSeq, Scheduler, SchedulerConfig, StepPlan


# Greedy-sampling tie band: candidates within TIE_EPS of the max logit
# count as tied and the LOWEST token id wins, a deterministic rule on the
# fp32 logits (raw argmax breaks ties by array order, which bf16 noise
# reorders). What this CAN and CANNOT buy: the unembed emits fp32 logits,
# but the bf16 hidden state feeding it differs across layouts/impls
# (packed vs padded vs serial streams, ref vs kernel attention, MoE
# expert tiling, mamba2 packed vs chunked scans) by reduction order —
# per-candidate gaps to the max move by ~1e-4 on dense archetypes up to
# ~4e-3 on MoE decode chains. The band absorbs near-ties well inside it,
# but NO constant is layout-independent in general: a candidate whose gap
# lands within noise of the band edge itself still flips (measured: 1e-3
# flipped a dbrx 0.9e-3 near-tie, 3e-2 flipped on danube's #3 candidate
# at gap ~3e-2), and the flip points move with the band because earlier
# picks change the trajectory. Cross-layout greedy comparisons therefore
# use the fork-aware checker in tests/conftest.py: exact token equality
# until a divergence, which must itself be a genuinely ambiguous decision
# (both candidates within TIE_FORK_TOL of the max in BOTH modes' recorded
# fp32 rows — see EngineConfig.record_sample_logits) — a real bug (leak,
# wrong mask) diverges with a large gap and still fails loudly.
TIE_EPS = 5e-3


def greedy_token(logits: np.ndarray) -> int:
    """Tie-banded greedy argmax over one logits row (see TIE_EPS). Every
    greedy consumer (engine sampler, spec-decode draft/verify) must use
    this same rule or their outputs drift apart on near-ties."""
    return int(np.flatnonzero(logits >= logits.max() - TIE_EPS)[0])


def stub_modality_embed(mm_hash: int, offset: int, dim: int) -> np.ndarray:
    """Deterministic stand-in for the vision/audio frontend (assignment:
    frontends are stubs; embeddings are 'precomputed')."""
    rng = np.random.default_rng((mm_hash & 0xFFFFFFFF, offset))
    return (0.05 * rng.standard_normal(dim)).astype(np.float32)


@dataclasses.dataclass
class EngineConfig:
    kv_pool_bytes: int = 64 << 20
    max_running: int = 16
    chunk_size: int = 64               # per-request prefill chunk cap
    max_num_batched_tokens: int = 256  # per-step mixed-batch token budget
    max_prefill_tokens_per_step: Optional[int] = None  # long-prefill cap
    # "packed"  — one (total_tokens,) token stream with per-token segment
    #             ids (vLLM-style varlen dispatch; per-step FLOPs follow
    #             the token budget);
    # "padded"  — the PR-1 mixed layout, one (B, T)-padded row/sequence
    #             ("mixed" is accepted as a legacy alias);
    # "serial"  — legacy one-prefill-chunk-per-step, two dispatch groups.
    batching_mode: str = "packed"
    # Double-buffered step: plan + host-build step N+1 while step N's
    # dispatch is in flight; sample/advance one step delayed. Greedy
    # outputs are bit-identical to the synchronous loop. Composes with
    # "packed"/"padded"; "serial" falls back to the synchronous loop
    # (its two dispatch groups per step defeat single-slot buffering).
    async_scheduling: bool = False
    enable_prefix_caching: bool = True
    memory_mode: str = "jenga"       # "jenga" | "paged-baseline"
    geometry_mode: str = "lcm"        # "lcm" | "max"
    # "ref"    — jnp reference attention (segment-block-sparse scan);
    # "kernel" — the packed layout dispatches the Pallas varlen flash
    #            kernel (interpret mode off-TPU, so CI exercises the real
    #            kernel code path); padded/serial layouts keep ref.
    attention_impl: str = "ref"
    # Seed max_num_batched_tokens / max_prefill_tokens_per_step from the
    # roofline model and refine them online from StepMetrics (see
    # serving.autotune) instead of using the constants above.
    autotune_budgets: bool = False
    # Record each greedy sample's fp32 logits row (vocab-sliced) in
    # Engine.sample_log[rid], aligned with Request.output. Test-only
    # support for the fork-aware cross-layout greedy comparison (see the
    # TIE_EPS note); off by default — rows are vocab_size floats per token.
    record_sample_logits: bool = False
    seed: int = 0


@dataclasses.dataclass
class StepMetrics:
    step: int
    decode_batch: int          # decode sequences in this step's plan
    prefill_tokens: int        # prefill tokens across ALL chunks this step
    used_units: int
    evictable_units: int
    empty_units: int
    free_units: int
    waste_units: int = 0
    num_prefills: int = 0      # concurrent prefill chunks this step
    batched_tokens: int = 0    # total tokens in the mixed batch
    dispatched_slots: int = 0  # stream/row slots the dispatch actually paid
    pad_slots: int = 0         # slots paid beyond real tokens (waste)
    host_build_ms: float = 0.0  # host-side schedule + batch-build time
    # Device-wait time: sync = dispatch+fetch of THIS step's logits; async
    # = time blocked fetching the PREVIOUS step's logits after this step's
    # host build already ran (the overlap win is host_build_ms no longer
    # serializing with it).
    dispatch_ms: float = 0.0
    # Attention-work counters (packed layout): (q block, KV block) tiles
    # of the old-page self-attention streams this step scanned vs skipped
    # by the segment-block-sparse schedule, and the modeled FLOPs / HBM
    # bytes of the scanned tiles (host cost model — see
    # ModelRunner._attn_block_stats).
    kv_blocks_scanned: int = 0
    kv_blocks_skipped: int = 0
    attn_flops_modeled: float = 0.0
    attn_bytes_modeled: float = 0.0


@dataclasses.dataclass
class _InflightStep:
    """A dispatched-but-not-completed step (async double buffering). The
    PreparedStep itself is NOT retained — after dispatch only the plan and
    per-segment liveness matter."""
    plan: StepPlan
    handle: object             # device logits (JAX async dispatch)
    epochs: List[int]          # per-segment seq.epoch at dispatch time
    live: List[bool]           # False: segment killed at reconciliation
    step: int                  # engine step index this dispatch was logged as


class Engine:
    def __init__(self, model, cfg: EngineConfig,
                 params=None, seed: int = 0):
        self.model = model
        if cfg.batching_mode == "mixed":        # legacy alias for PR-1 mode
            cfg = dataclasses.replace(cfg, batching_mode="padded")
        self.cfg = cfg
        assert cfg.batching_mode in ("packed", "padded", "serial"), \
            cfg.batching_mode
        # serial mode issues two dispatch groups per step — double buffering
        # would interleave their completions; fall back to the sync loop
        self.async_scheduling = bool(cfg.async_scheduling) and \
            cfg.batching_mode != "serial"
        baseline = cfg.memory_mode == "paged-baseline"
        self.mgr = JengaKVCacheManager(
            model.kv_specs(),
            total_memory_bytes=cfg.kv_pool_bytes,
            mode=cfg.geometry_mode,
            enable_prefix_caching=cfg.enable_prefix_caching,
            enable_inflight_retirement=not baseline,
            seed=cfg.seed,
        )
        if baseline:
            self._apply_baseline_semantics()
        self.scheduler = Scheduler(
            self.mgr, SchedulerConfig(
                max_running=cfg.max_running,
                chunk_size=cfg.chunk_size,
                max_num_batched_tokens=cfg.max_num_batched_tokens,
                max_prefill_tokens_per_step=cfg.max_prefill_tokens_per_step,
                serial=cfg.batching_mode == "serial"))
        self.autotuner = None
        if cfg.autotune_budgets:
            from .autotune import BudgetAutotuner
            self.autotuner = BudgetAutotuner(model.cfg)
            self.scheduler.set_budgets(self.autotuner.budget,
                                       self.autotuner.prefill_cap)
        assert cfg.attention_impl in ("ref", "kernel"), cfg.attention_impl
        self.runner = ModelRunner(model, self.mgr,
                                  stub_embed_fn=stub_modality_embed,
                                  attention_impl=cfg.attention_impl)
        self.params = params if params is not None else model.init(seed)
        self.step_count = 0
        self.metrics: List[StepMetrics] = []
        self.sample_log: Dict[str, List[np.ndarray]] = {}
        self.encoder_runs = 0
        self.mm_seen: set = set()
        self.finished: List[Request] = []
        self._inflight: Optional[_InflightStep] = None
        # async-scheduling reconciliation counters: segments killed because
        # their request finished while speculatively planned, and pages
        # rolled back from those speculative +1 commitments
        self.spec_kills = 0
        self.spec_rollback_pages = 0
        # runner attention-work totals already folded into StepMetrics
        # (the runner accumulates across dispatches; steps record deltas)
        self._attn_seen = (0, 0, 0.0, 0.0)

    # ------------------------------------------------- baseline semantics
    def _apply_baseline_semantics(self):
        """PagedAttention-style baseline (paper §3.2): all layer types are
        treated as full-prefix self-attention — mm/cross caches allocate
        pages for EVERY token, sliding windows never retire, eviction is a
        single uncustomized LRU."""
        from ..core.policies import FullAttentionPolicy
        mgr = self.mgr
        for name, spec in ((s.name, s) for s in mgr.specs):
            if spec.kind in ("swa", "vision_embed", "cross_attn"):
                pol = FullAttentionPolicy(spec)
                mgr.policies[name] = pol
        orig = mgr._mm_storage_upto

        def all_tokens(req, spec, main_pos):
            if spec.kind in ("vision_embed", "cross_attn") and not \
                    req.encoder_items:
                return main_pos            # every token, image or not
            return orig(req, spec, main_pos)

        mgr._mm_storage_upto = all_tokens

    # -------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        req.arrival = self.step_count
        self.scheduler.add(req)

    # ---------------------------------------------------------------- step
    def step(self) -> Optional[StepMetrics]:
        if self.async_scheduling:
            return self._step_async()
        if not self.scheduler.has_work():
            return None
        t0 = time.perf_counter()
        plan = self.scheduler.schedule()
        # state restores of this step's admissions: one batched dispatch
        self.runner.apply_copies(plan.copy_ops)
        # scheduling counts as host build time (async hides it too)
        build_ms = (time.perf_counter() - t0) * 1e3
        disp_ms = 0.0

        slots_before = self.runner.slots_dispatched
        if plan.scheduled:
            self._count_encoder_runs(plan.scheduled)
            if self.cfg.batching_mode == "serial":
                # legacy two-dispatch step: prefill chunk, then decode batch
                groups = [g for g in (plan.prefills,
                                      [s for s in plan.scheduled
                                       if not s.is_prefill]) if g]
            else:
                groups = [plan.scheduled]
            packed = self.cfg.batching_mode == "packed"
            post_ops: List[StateCopyOp] = []
            for group in groups:
                tb = time.perf_counter()
                prep = self.runner.prepare(
                    [(s.req, s.num_tokens, s.start) for s in group],
                    packed=packed)
                td = time.perf_counter()
                build_ms += (td - tb) * 1e3
                logits = self.runner.fetch(
                    self.runner.dispatch(self.params, prep), len(group))
                disp_ms += (time.perf_counter() - td) * 1e3
                # sampling/advance below is neither build nor dispatch wait
                for i, s in enumerate(group):
                    post_ops.extend(self._advance(s, logits[i]))
            # checkpoint copies emitted while advancing: one batched dispatch
            self.runner.apply_copies(post_ops)

        return self._record_metrics(plan, slots_before, build_ms, disp_ms)

    # ---------------------------------------------------------- async step
    def _step_async(self) -> Optional[StepMetrics]:
        """One double-buffered step: plan + host-build step N+1 (the part
        the in-flight dispatch hides), THEN block on step N's logits,
        sample/advance it, reconcile plan N+1 against what actually
        happened (kill segments of requests that finished, roll back their
        speculative pages, patch the now-known decode token ids), and
        dispatch N+1 without waiting for it."""
        inf, self._inflight = self._inflight, None
        if not self.scheduler.has_work() and inf is None:
            return None

        # --- phase 1: plan step N+1 while step N executes on device
        t0 = time.perf_counter()
        inflight_toks: Dict[str, int] = {}
        if inf is not None:
            for i, s in enumerate(inf.plan.scheduled):
                if inf.live[i]:
                    inflight_toks[s.req.rid] = s.num_tokens
        plan = self.scheduler.schedule(inflight=inflight_toks)
        self.runner.apply_copies(plan.copy_ops)
        prepared = None
        if plan.scheduled:
            self._count_encoder_runs(plan.scheduled)
            prepared = self.runner.prepare(
                [(s.req, s.num_tokens, s.start) for s in plan.scheduled],
                packed=self.cfg.batching_mode == "packed")
        build_ms = (time.perf_counter() - t0) * 1e3

        # --- phase 2: complete step N (blocks on its logits)
        done, wait_ms = self._complete(inf)

        # --- phase 3: reconcile plan N+1 against step N's actual outcome
        live = [True] * len(plan.scheduled)
        seg_of = {s.req.rid: i for i, s in enumerate(plan.scheduled)}
        for req in done:
            si = seg_of.get(req.rid)
            if si is not None:
                # EOS'd while its speculative +1 decode was already planned:
                # neutralize the segment and pop the page committed for the
                # never-computed token before releasing the request.
                prepared.kill_segment(si)
                live[si] = False
                self.spec_kills += 1
                self.spec_rollback_pages += self.mgr.rollback_tokens(
                    req.seq, req.seq.num_computed)
            self._finish(req)
        if prepared is not None:
            for si in list(prepared.pending):
                s = plan.scheduled[si]
                prepared.patch_token(si, s.req.seq.tokens[s.start])

        # --- phase 4: dispatch step N+1 (async; completes next call)
        slots_before = self.runner.slots_dispatched
        tokens_before = self.runner.tokens_dispatched
        if prepared is not None and any(live):
            epochs = [s.req.seq.epoch for s in plan.scheduled]
            handle = self.runner.dispatch(self.params, prepared)
            self._inflight = _InflightStep(plan, handle, epochs, live,
                                           step=self.step_count)
        return self._record_metrics(
            plan, slots_before, build_ms, wait_ms,
            tokens=self.runner.tokens_dispatched - tokens_before)

    def _complete(self, inf: Optional[_InflightStep]):
        """Fetch an in-flight step's logits and run its delayed
        sample/advance. Segments whose request was preempted while in
        flight (stale epoch) or killed at dispatch are skipped — recompute
        preemption regenerates their tokens deterministically. Returns
        (finished requests, ms blocked on the fetch) — finish itself is
        deferred to the caller so it can reconcile the next plan first,
        and only the device wait is timed (host bookkeeping after the
        fetch is not dispatch latency)."""
        if inf is None:
            return [], 0.0
        t0 = time.perf_counter()
        logits = self.runner.fetch(inf.handle, len(inf.plan.scheduled))
        wait_ms = (time.perf_counter() - t0) * 1e3
        done: List[Request] = []
        post_ops: List[StateCopyOp] = []
        for i, s in enumerate(inf.plan.scheduled):
            req, seq = s.req, s.req.seq
            if not inf.live[i] or req.status != Status.RUNNING \
                    or seq.epoch != inf.epochs[i] \
                    or seq.num_computed != s.start:
                continue
            # stamp with the COMPLETED step's index, not the current call's
            # (sync records the sampling step; async samples one call later)
            post_ops.extend(self._advance(s, logits[i], done=done,
                                          step=inf.step))
        self.runner.apply_copies(post_ops)
        return done, wait_ms

    def _record_metrics(self, plan: StepPlan, slots_before: int,
                        build_ms: float, disp_ms: float,
                        tokens: Optional[int] = None) -> StepMetrics:
        """``batched_tokens``/``dispatched_slots``/``pad_slots`` describe
        what was actually DISPATCHED (async: killed speculative segments'
        tokens drop out and their slots count as padding waste; a fully
        killed plan dispatches nothing); ``decode_batch``/``num_prefills``/
        ``prefill_tokens`` describe the PLAN as scheduled."""
        stats = self.mgr.memory_stats()
        slots = self.runner.slots_dispatched - slots_before
        tokens = plan.total_tokens if tokens is None else tokens
        r = self.runner
        attn_now = (r.kv_blocks_scanned, r.kv_blocks_skipped,
                    r.attn_flops_modeled, r.attn_bytes_modeled)
        attn_delta = tuple(a - b for a, b in zip(attn_now, self._attn_seen))
        self._attn_seen = attn_now
        m = StepMetrics(
            step=self.step_count,
            decode_batch=len(plan.decodes),
            prefill_tokens=plan.prefill_tokens,
            used_units=stats.used_units,
            evictable_units=stats.evictable_units,
            empty_units=stats.empty_units,
            free_units=stats.free_units,
            num_prefills=len(plan.prefills),
            batched_tokens=tokens,
            dispatched_slots=slots,
            pad_slots=max(0, slots - tokens),
            host_build_ms=build_ms,
            dispatch_ms=disp_ms,
            kv_blocks_scanned=attn_delta[0],
            kv_blocks_skipped=attn_delta[1],
            attn_flops_modeled=attn_delta[2],
            attn_bytes_modeled=attn_delta[3],
        )
        self.metrics.append(m)
        self.step_count += 1
        if self.autotuner is not None and self.autotuner.observe(m):
            self.scheduler.set_budgets(self.autotuner.budget,
                                       self.autotuner.prefill_cap)
        return m

    def _count_encoder_runs(self, scheduled: Sequence[ScheduledSeq]) -> None:
        if self.model.cfg.family not in ("vlm", "encdec"):
            return
        for s in scheduled:
            seq = s.req.seq
            if not s.is_prefill or s.start != 0:
                continue
            for it in (seq.mm_items or seq.encoder_items):
                if it.mm_hash not in self.mm_seen or not \
                        self.cfg.enable_prefix_caching:
                    self.encoder_runs += 1
                    self.mm_seen.add(it.mm_hash)

    def _advance(self, s: ScheduledSeq, logits: np.ndarray,
                 done: Optional[List[Request]] = None,
                 step: Optional[int] = None) -> List[StateCopyOp]:
        """Post-dispatch bookkeeping for one scheduled sequence: record the
        computed tokens with the manager, sample once past the prompt, and
        return any state-checkpoint copy ops for batched execution. With
        ``done`` given (async), finish detection is deferred to the caller
        instead of retiring the request immediately; ``step`` overrides the
        step index stamped on first tokens/finishes (async completes step N
        during call N+1 — stamps must match the synchronous loop's)."""
        req, seq = s.req, s.req.seq
        step = self.step_count if step is None else step
        ops = self.mgr.advance(seq, s.num_tokens)
        if s.is_prefill:    # vision free-on-consume only fires during prefill
            self.mgr.consume_mm(seq, seq.num_computed)
        self.mgr.touch(seq)
        if not req.in_prefill:          # decode, or prompt just completed
            tok = self._sample(req, logits)
            req.output.append(tok)
            seq.append_token(tok)
            if req.first_token_step is None:
                req.first_token_step = step
            if req.is_done():
                if done is None:
                    self._finish(req)
                else:
                    req.finished_step = step
                    done.append(req)
        return ops

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        v = self.model.cfg.vocab_size
        logits = logits[:v]
        if self.cfg.record_sample_logits:
            self.sample_log.setdefault(req.rid, []).append(
                np.asarray(logits, np.float32).copy())
        if req.sampling.temperature <= 0:
            # greedy with a deterministic tie-break on the fp32 logits
            # (lowest token id within TIE_EPS of the max — see TIE_EPS)
            return greedy_token(logits)
        rng = np.random.default_rng(
            (req.sampling.seed, len(req.output), hash(req.rid) & 0xFFFF))
        p = logits / req.sampling.temperature
        p = np.exp(p - p.max())
        p /= p.sum()
        return int(rng.choice(v, p=p))

    def _finish(self, req: Request) -> None:
        if req.finished_step is None:   # async stamps at completion time
            req.finished_step = self.step_count
        self.scheduler.finish(req, cache=True)
        self.runner.forget(req.rid)
        self.finished.append(req)

    # ----------------------------------------------------------------- run
    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        """Drive steps until every request finished (draining the in-flight
        step on shutdown) or ``max_steps`` is hit."""
        while (self.scheduler.has_work() or self._inflight is not None) \
                and self.step_count < max_steps:
            self.step()
        return self.finished
