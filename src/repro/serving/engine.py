"""Inference engine: token-budget continuous batching over the Jenga
manager.

Each ``step()`` is build-batch -> ONE ``serve_step`` dispatch -> advance /
sample / retire:

  1. ``Scheduler.schedule()`` packs a per-step token budget across ALL
     running requests — any number of concurrent prefill chunks plus every
     decode — and commits the step's page allocation transactionally;
  2. the step's state-restore copies run as one batched dispatch;
  3. ``ModelRunner.prepare``/``dispatch`` executes the whole mixed plan in
     a single jitted ``serve_step`` — token-packed into one (total_tokens,)
     stream with per-token segment ids by default ("packed"), or as
     (B, T)-padded rows under the PR-1 layout ("padded");
  4. every scheduled request advances; the engine samples PER SEGMENT
     (logits come back one row per scheduled item, in plan order);
     checkpoint copies emitted by ``advance`` run as one batched dispatch
     at the end of the step.

ASYNC SCHEDULING (``EngineConfig.async_scheduling``, pipelined): while
step N's dispatch is in flight on the device, the host plans step N+1 and
builds its packed batch — sampling and advancing step N happen one step
later, when its results are fetched. Decode rows in plan N+1 are
scheduled SPECULATIVELY (each running decode assumed to produce +1 token,
vLLM async-scheduling style) with their pages pre-committed through the
manager's transactional ``allocate_for_batch``; when a completed step
reveals a request actually finished (EOS / token budget), its segments in
EVERY still-queued plan are neutralized to pad semantics and its
speculative page commitments rolled back in one trailing pop
(``mgr.rollback_tokens``). Greedy outputs are bit-identical to the
synchronous loop: segments are isolated by the packed segment mask, so a
dead slot changes nothing for its neighbours, and recompute preemption is
semantically transparent. ``async_scheduling`` composes with
``batching_mode`` "packed" and "padded"; "serial" (two dispatch groups per
step) falls back to the synchronous loop.

PIPELINE DEPTH (``EngineConfig.pipeline_depth``): the in-flight slot is a
ring of up to ``pipeline_depth - 1`` dispatched steps. Depth 2 (default)
is the PR-3 double buffer. Deeper rings require DEVICE SAMPLING
(``EngineConfig.device_sampling``; forced on beyond depth 2): the fused
sampling tail in ``ModelRunner.dispatch`` picks each segment's token on
device (shared ``greedy_token`` tie-band semantics, bit-identical to the
host path, plus seeded temperature/top-k — see ``serving.sampler``) and
scatters it into a device-resident token board that later dispatches read
back (``inject_tokens``), so the host plans step N+k from effective
positions without ever seeing a logit: completion blocks on a
``(segments,)`` int32 vector — 4 bytes per segment instead of
``vocab * 4`` — and logits rows are only fetched under
``record_sample_logits``.

``batching_mode="serial"`` reproduces the legacy one-prefill-chunk-per-step
engine (prefill and decode as separate dispatches) for step-count A/Bs and
determinism tests.

Collects the per-step metrics the paper's figures are built from (decode
batch size Fig.15, memory breakdown Fig.16, hit rates Fig.17, encoder runs
Fig.18) plus the mixed-batch packing stats (tokens/step, prefills/step),
dispatch-waste counters (tokens vs slots paid), and the host-build /
device-wait timings the async overlap is measured by."""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.manager import JengaKVCacheManager, StateCopyOp
from .request import Request, SamplingParams, Status
from .runner import ModelRunner
from .sampler import TIE_EPS, greedy_token, host_sample, rid_hash
from .scheduler import ScheduledSeq, Scheduler, SchedulerConfig, StepPlan


# Greedy-sampling tie band (re-exported from serving.sampler, the single
# source of truth for token selection): candidates within TIE_EPS of the
# max logit count as tied and the LOWEST token id wins, a deterministic
# rule on the fp32 logits (raw argmax breaks ties by array order, which
# bf16 noise reorders). What this CAN and CANNOT buy: the unembed emits
# fp32 logits, but the bf16 hidden state feeding it differs across
# layouts/impls (packed vs padded vs serial streams, ref vs kernel
# attention, MoE expert tiling, mamba2 packed vs chunked scans) by
# reduction order — per-candidate gaps to the max move by ~1e-4 on dense
# archetypes up to ~4e-3 on MoE decode chains. The band absorbs near-ties
# well inside it, but NO constant is layout-independent in general: a
# candidate whose gap lands within noise of the band edge itself still
# flips (measured: 1e-3 flipped a dbrx 0.9e-3 near-tie, 3e-2 flipped on
# danube's #3 candidate at gap ~3e-2), and the flip points move with the
# band because earlier picks change the trajectory. Cross-layout greedy
# comparisons therefore use the fork-aware checker in tests/conftest.py:
# exact token equality until a divergence, which must itself be a
# genuinely ambiguous decision (both candidates within TIE_FORK_TOL of
# the max in BOTH modes' recorded fp32 rows — see
# EngineConfig.record_sample_logits) — a real bug (leak, wrong mask)
# diverges with a large gap and still fails loudly. The device sampler
# implements the same rule as a boolean argmax over the band
# (see serving.sampler._band_pick) and is bit-identical to the host form.
TIE_EPS = TIE_EPS                  # re-exported; canonical home: sampler.py
greedy_token = greedy_token


def stub_modality_embed(mm_hash: int, offset: int, dim: int) -> np.ndarray:
    """Deterministic stand-in for the vision/audio frontend (assignment:
    frontends are stubs; embeddings are 'precomputed')."""
    rng = np.random.default_rng((mm_hash & 0xFFFFFFFF, offset))
    return (0.05 * rng.standard_normal(dim)).astype(np.float32)


@dataclasses.dataclass
class EngineConfig:
    kv_pool_bytes: int = 64 << 20
    max_running: int = 16
    chunk_size: int = 64               # per-request prefill chunk cap
    max_num_batched_tokens: int = 256  # per-step mixed-batch token budget
    max_prefill_tokens_per_step: Optional[int] = None  # long-prefill cap
    # "packed"  — one (total_tokens,) token stream with per-token segment
    #             ids (vLLM-style varlen dispatch; per-step FLOPs follow
    #             the token budget);
    # "padded"  — the PR-1 mixed layout, one (B, T)-padded row/sequence
    #             ("mixed" is accepted as a legacy alias);
    # "serial"  — legacy one-prefill-chunk-per-step, two dispatch groups.
    batching_mode: str = "packed"
    # Double-buffered step: plan + host-build step N+1 while step N's
    # dispatch is in flight; sample/advance one step delayed. Greedy
    # outputs are bit-identical to the synchronous loop. Composes with
    # "packed"/"padded"; "serial" falls back to the synchronous loop
    # (its two dispatch groups per step defeat single-slot buffering).
    async_scheduling: bool = False
    # In-flight pipeline depth: up to (pipeline_depth - 1) dispatched
    # steps stay queued on device. None resolves from $REPRO_PIPELINE_DEPTH
    # (default 2 — the PR-3 double buffer); 1 forces the synchronous loop.
    # Depths > 2 require device_sampling (the host never sees step N's
    # tokens before planning N+2).
    pipeline_depth: Optional[int] = None
    # Sample tokens ON DEVICE in the dispatch (fused greedy/temperature
    # tail + token board, see serving.sampler); completion then fetches 4
    # bytes per segment instead of the vocab*4 logits row. None: enabled
    # exactly when pipeline_depth > 2. Only meaningful with
    # async_scheduling; greedy results are bit-identical either way.
    device_sampling: Optional[bool] = None
    enable_prefix_caching: bool = True
    memory_mode: str = "jenga"       # "jenga" | "paged-baseline"
    geometry_mode: str = "lcm"        # "lcm" | "max"
    # "ref"    — jnp reference attention (segment-block-sparse scan);
    # "kernel" — the packed layout dispatches the Pallas varlen flash
    #            kernel (interpret mode off-TPU, so CI exercises the real
    #            kernel code path); padded/serial layouts keep ref.
    attention_impl: str = "ref"
    # Seed max_num_batched_tokens / max_prefill_tokens_per_step from the
    # roofline model and refine them online from StepMetrics (see
    # serving.autotune) instead of using the constants above.
    autotune_budgets: bool = False
    # Record each greedy sample's fp32 logits row (vocab-sliced) in
    # Engine.sample_log[rid], aligned with Request.output. Test-only
    # support for the fork-aware cross-layout greedy comparison (see the
    # TIE_EPS note); off by default — rows are vocab_size floats per token.
    record_sample_logits: bool = False
    # Disaggregation role (serving.dp_engine): "both" serves prefill and
    # decode (colocated, the default); "prefill" only runs prompt chunks —
    # a prompt-complete request goes quiet and awaits the DPEngine handoff;
    # "decode" only receives handed-off requests (the router never places
    # fresh arrivals here).
    role: str = "both"
    seed: int = 0


@dataclasses.dataclass
class StepMetrics:
    step: int
    decode_batch: int          # decode sequences in this step's plan
    prefill_tokens: int        # prefill tokens across ALL chunks this step
    used_units: int
    evictable_units: int
    empty_units: int
    free_units: int
    waste_units: int = 0
    num_prefills: int = 0      # concurrent prefill chunks this step
    batched_tokens: int = 0    # total tokens in the mixed batch
    dispatched_slots: int = 0  # stream/row slots the dispatch actually paid
    pad_slots: int = 0         # slots paid beyond real tokens (waste)
    host_build_ms: float = 0.0  # host-side schedule + batch-build time
    # Device-wait time: sync = dispatch+fetch of THIS step's logits; async
    # = time blocked fetching the PREVIOUS step's results after this step's
    # host build already ran (the overlap win is host_build_ms no longer
    # serializing with it).
    dispatch_ms: float = 0.0
    # Pipeline timing split (async; host-observed estimates). issue: time
    # spent in runner.dispatch() handing work to the device. For each step
    # COMPLETED during this call: queue = time it sat behind the previous
    # step's completion, compute = completion minus max(issue, previous
    # completion). dispatch_ms above stays the blocked-fetch wait.
    dispatch_issue_ms: float = 0.0
    dispatch_queue_ms: float = 0.0
    dispatch_compute_ms: float = 0.0
    # Host-side sampling time (greedy argmax / seeded draw in _sample);
    # 0 under device sampling — that is the point.
    host_sample_ms: float = 0.0
    # Device->host bytes fetched this step (logits rows and/or sampled
    # token vectors): vocab*4 per segment host-sampled vs 4 per segment
    # device-sampled.
    sampled_bytes_fetched: int = 0
    # Attention-work counters (packed layout): (q block, KV block) tiles
    # of the old-page self-attention streams this step scanned vs skipped
    # by the segment-block-sparse schedule, and the modeled FLOPs / HBM
    # bytes of the scanned tiles (host cost model — see
    # ModelRunner._attn_block_stats).
    kv_blocks_scanned: int = 0
    kv_blocks_skipped: int = 0
    attn_flops_modeled: float = 0.0
    attn_bytes_modeled: float = 0.0


@dataclasses.dataclass
class ShardHealth:
    """One engine's health/backpressure snapshot, read by the data-parallel
    router (serving.router) every fleet tick. ``defer_count`` and
    ``preemption_count`` are CUMULATIVE — the router costs shards on their
    deltas; ``outstanding_tokens`` is the least-loaded placement key."""
    step: int                   # engine step count (progress indicator)
    finished: int               # requests retired so far
    waiting: int                # queued, unadmitted requests
    running: int                # admitted requests
    outstanding_tokens: int     # remaining prompt + decode tokens
    inflight_steps: int         # dispatched-but-uncompleted ring depth
    defer_count: int            # scheduler defer events (cumulative)
    preemption_count: int       # recompute preemptions (cumulative)
    used_units: int             # referenced pool units
    free_units: int             # unowned pool units
    role: str = "both"          # disaggregation role (prefill/decode/both)


@dataclasses.dataclass
class _InflightStep:
    """A dispatched-but-not-completed step (one ring slot of the async
    pipeline). The PreparedStep itself is NOT retained — after dispatch
    only the plan and per-segment liveness matter."""
    plan: StepPlan
    handle: object             # runner.StepHandle (JAX async dispatch)
    epochs: List[int]          # per-segment seq.epoch at dispatch time
    live: List[bool]           # False: segment killed at reconciliation
    step: int                  # engine step index this dispatch was logged as
    dispatched_at: float = 0.0  # perf_counter at issue (timing split)


class Engine:
    def __init__(self, model, cfg: EngineConfig,
                 params=None, seed: int = 0):
        self.model = model
        if cfg.batching_mode == "mixed":        # legacy alias for PR-1 mode
            cfg = dataclasses.replace(cfg, batching_mode="padded")
        self.cfg = cfg
        assert cfg.batching_mode in ("packed", "padded", "serial"), \
            cfg.batching_mode
        # serial mode issues two dispatch groups per step — double buffering
        # would interleave their completions; fall back to the sync loop.
        # pipeline_depth 1 means "nothing in flight": also the sync loop.
        depth = cfg.pipeline_depth
        if depth is None:
            depth = int(os.environ.get("REPRO_PIPELINE_DEPTH", "2") or 2)
        depth = max(1, int(depth))
        self.async_scheduling = bool(cfg.async_scheduling) and \
            cfg.batching_mode != "serial" and depth > 1
        self.pipeline_depth = depth if self.async_scheduling else 1
        dev = cfg.device_sampling
        if dev is None:
            dev = self.pipeline_depth > 2
        self.device_sampling = bool(dev) and self.async_scheduling
        assert self.pipeline_depth <= 2 or self.device_sampling, (
            "pipeline_depth > 2 requires device_sampling: with host "
            "sampling every queued step's decode tokens would need a host "
            "patch, capping the ring at one slot")
        baseline = cfg.memory_mode == "paged-baseline"
        self.mgr = JengaKVCacheManager(
            model.kv_specs(),
            total_memory_bytes=cfg.kv_pool_bytes,
            mode=cfg.geometry_mode,
            enable_prefix_caching=cfg.enable_prefix_caching,
            enable_inflight_retirement=not baseline,
            seed=cfg.seed,
        )
        if baseline:
            self._apply_baseline_semantics()
        assert cfg.role in ("both", "prefill", "decode"), cfg.role
        self.role = cfg.role
        self.scheduler = Scheduler(
            self.mgr, SchedulerConfig(
                max_running=cfg.max_running,
                chunk_size=cfg.chunk_size,
                max_num_batched_tokens=cfg.max_num_batched_tokens,
                max_prefill_tokens_per_step=cfg.max_prefill_tokens_per_step,
                serial=cfg.batching_mode == "serial",
                prefill_only=cfg.role == "prefill"))
        self.autotuner = None
        if cfg.autotune_budgets:
            from .autotune import BudgetAutotuner
            self.autotuner = BudgetAutotuner(model.cfg)
            self.scheduler.set_budgets(self.autotuner.budget,
                                       self.autotuner.prefill_cap)
        assert cfg.attention_impl in ("ref", "kernel"), cfg.attention_impl
        self.runner = ModelRunner(model, self.mgr,
                                  stub_embed_fn=stub_modality_embed,
                                  attention_impl=cfg.attention_impl)
        self.params = params if params is not None else model.init(seed)
        self.step_count = 0
        self.metrics: List[StepMetrics] = []
        self.sample_log: Dict[str, List[np.ndarray]] = {}
        self.encoder_runs = 0
        self.mm_seen: set = set()
        self.finished: List[Request] = []
        # ring of dispatched-but-not-completed steps, oldest first. With
        # host sampling the capacity is pinned to 1 (every queued plan's
        # decode tokens need the previous step's host sample); device
        # sampling raises it to pipeline_depth - 1.
        self._inflight: Deque[_InflightStep] = deque()
        self._ring_capacity = (self.pipeline_depth - 1) \
            if self.device_sampling else 1
        # async-scheduling reconciliation counters: segments killed because
        # their request finished while speculatively planned, and pages
        # rolled back from those speculative commitments
        self.spec_kills = 0
        self.spec_rollback_pages = 0
        # runner attention-work totals already folded into StepMetrics
        # (the runner accumulates across dispatches; steps record deltas)
        self._attn_seen = (0, 0, 0.0, 0.0)
        self._bytes_seen = 0
        self._sample_ms = 0.0           # host sampling time this step
        self._last_complete_t = 0.0     # timing split (queue vs compute)

    # ------------------------------------------------- baseline semantics
    def _apply_baseline_semantics(self):
        """PagedAttention-style baseline (paper §3.2): all layer types are
        treated as full-prefix self-attention — mm/cross caches allocate
        pages for EVERY token, sliding windows never retire, eviction is a
        single uncustomized LRU."""
        from ..core.policies import FullAttentionPolicy
        mgr = self.mgr
        for name, spec in ((s.name, s) for s in mgr.specs):
            if spec.kind in ("swa", "vision_embed", "cross_attn"):
                pol = FullAttentionPolicy(spec)
                mgr.policies[name] = pol
        orig = mgr._mm_storage_upto

        def all_tokens(req, spec, main_pos):
            if spec.kind in ("vision_embed", "cross_attn") and not \
                    req.encoder_items:
                return main_pos            # every token, image or not
            return orig(req, spec, main_pos)

        mgr._mm_storage_upto = all_tokens

    # -------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        req.arrival = self.step_count
        # a failed-over request may have logged sample rows on another
        # shard's engine — or on THIS engine before a drain; recorded rows
        # must stay aligned with the output the rerun produces
        self.sample_log.pop(req.rid, None)
        self.scheduler.add(req)

    # ---------------------------------------------------------------- step
    def step(self) -> Optional[StepMetrics]:
        if self.async_scheduling:
            return self._step_async()
        if not self.scheduler.has_work():
            return None
        t0 = time.perf_counter()
        plan = self.scheduler.schedule()
        # state restores of this step's admissions: one batched dispatch
        self.runner.apply_copies(plan.copy_ops)
        # scheduling counts as host build time (async hides it too)
        build_ms = (time.perf_counter() - t0) * 1e3
        disp_ms = 0.0

        slots_before = self.runner.slots_dispatched
        if plan.scheduled:
            self._count_encoder_runs(plan.scheduled)
            if self.cfg.batching_mode == "serial":
                # legacy two-dispatch step: prefill chunk, then decode batch
                groups = [g for g in (plan.prefills,
                                      [s for s in plan.scheduled
                                       if not s.is_prefill]) if g]
            else:
                groups = [plan.scheduled]
            packed = self.cfg.batching_mode == "packed"
            post_ops: List[StateCopyOp] = []
            for group in groups:
                tb = time.perf_counter()
                prep = self.runner.prepare(
                    [(s.req, s.num_tokens, s.start) for s in group],
                    packed=packed)
                td = time.perf_counter()
                build_ms += (td - tb) * 1e3
                for s in group:     # device work now exists for these
                    s.req.started = True
                logits = self.runner.fetch(
                    self.runner.dispatch(self.params, prep), len(group))
                disp_ms += (time.perf_counter() - td) * 1e3
                # sampling/advance below is neither build nor dispatch wait
                for i, s in enumerate(group):
                    post_ops.extend(self._advance(s, logits[i]))
            # checkpoint copies emitted while advancing: one batched dispatch
            self.runner.apply_copies(post_ops)

        return self._record_metrics(plan, slots_before, build_ms, disp_ms)

    # ---------------------------------------------------------- async step
    def _step_async(self) -> Optional[StepMetrics]:
        """One pipelined step: plan + host-build the next step (the part
        the in-flight dispatches hide), THEN complete the oldest in-flight
        step(s) until a ring slot is free, reconcile the new plan AND every
        still-queued plan against what actually happened (kill segments of
        requests that finished, roll back their speculative pages, patch
        or board-feed the decode token ids), and dispatch the new step
        without waiting for it."""
        if not self.scheduler.has_work() and not self._inflight:
            return None

        # --- phase 1: plan the next step while the ring executes on device.
        # Effective positions count every VALID queued row (stale-epoch
        # rows — preempted or restarted while queued — are dead weight the
        # completion will skip, so they must not advance c_eff); samples
        # in flight are counted so will_finish fires at the same position
        # the sync loop would stop scheduling at.
        t0 = time.perf_counter()
        inflight_info: Dict[str, Tuple[int, int]] = {}
        for qinf in self._inflight:
            for i, s in enumerate(qinf.plan.scheduled):
                req, seq = s.req, s.req.seq
                if not qinf.live[i] or req.status != Status.RUNNING \
                        or seq.epoch != qinf.epochs[i]:
                    continue
                t, sm = inflight_info.get(req.rid, (0, 0))
                samples = 1 if s.start + s.num_tokens >= len(req.prompt) \
                    else 0
                inflight_info[req.rid] = (t + s.num_tokens, sm + samples)
        san = self.mgr.sanitizer
        if san is not None:
            san.set_inflight(inflight_info)
        plan = self.scheduler.schedule(inflight=inflight_info)
        self.runner.apply_copies(plan.copy_ops)
        prepared = None
        if plan.scheduled:
            self._count_encoder_runs(plan.scheduled)
            prepared = self.runner.prepare(
                [(s.req, s.num_tokens, s.start) for s in plan.scheduled],
                packed=self.cfg.batching_mode == "packed",
                sample=self.device_sampling,
                board_feed=self.device_sampling)
        build_ms = (time.perf_counter() - t0) * 1e3

        # --- phase 2: complete the oldest step(s). Completing down to
        # (capacity - 1) before a new dispatch keeps at most
        # ``pipeline_depth - 1`` steps queued; a planless call (drain, or
        # nothing schedulable under pressure) completes the WHOLE ring —
        # the host has nothing to overlap anyway, and every completed
        # result (finishes, freed pages) can only improve the next
        # schedule. This also keeps step counts depth-independent: deeper
        # rings don't pay extra one-completion-per-call shutdown steps.
        done: List[Request] = []
        wait_ms = queue_ms = compute_ms = 0.0
        target = self._ring_capacity - 1 if prepared is not None else 0
        while len(self._inflight) > target:
            inf = self._inflight.popleft()
            # rids that STILL have dispatched steps deeper in the ring:
            # their live state pages keep advancing on device after this
            # completion's copy ops would run, so checkpoint snapshots and
            # state caching must be suppressed for them (depth >= 3 only;
            # at depth 2 the ring is fully drained before a new dispatch)
            deeper = self._live_inflight_rids()
            if san is not None:
                san.set_inflight(deeper)
            d, w, q, c = self._complete(inf, deeper)
            done.extend(d)
            wait_ms += w
            queue_ms += q
            compute_ms += c

        # --- phase 3: reconcile the new plan AND every queued plan
        # against the completed steps' actual outcomes
        live = [True] * len(plan.scheduled)
        seg_of = {s.req.rid: i for i, s in enumerate(plan.scheduled)}
        for req in done:
            # finished while speculative decodes were already planned (in
            # the new plan and/or deeper ring slots): neutralize every such
            # segment, then pop ALL pages committed for never-computed
            # tokens in one trailing rollback.
            killed = False
            dispatched_kill = False
            si = seg_of.get(req.rid)
            if si is not None:
                prepared.kill_segment(si)
                live[si] = False
                self.spec_kills += 1
                killed = True
            for qinf in self._inflight:
                for i, s in enumerate(qinf.plan.scheduled):
                    if s.req.rid == req.rid and qinf.live[i]:
                        qinf.live[i] = False
                        self.spec_kills += 1
                        killed = True
                        # already ON the device: it keeps mutating the
                        # live state page after this finish
                        dispatched_kill = True
            if killed:
                self.spec_rollback_pages += self.mgr.rollback_tokens(
                    req.seq, req.seq.num_computed)
            # Killed-but-dispatched deeper steps advance the live state
            # page past the boundary hash — caching it would poison later
            # prefix hits. Token KV pages stay cacheable: killed tokens
            # only ever touched the popped/partial tail pages.
            self._finish(req, cache_state=not dispatched_kill)
        if prepared is not None:
            # host sampling: decode tokens sampled at completion above are
            # known now — patch them in. (Device sampling board-fed them
            # at prepare; pending is already empty.)
            for si in list(prepared.pending):
                s = plan.scheduled[si]
                prepared.patch_token(si, s.req.seq.tokens[s.start])

        # --- phase 4: dispatch the new step (async; completes in a later
        # call, once it reaches the head of the ring)
        slots_before = self.runner.slots_dispatched
        tokens_before = self.runner.tokens_dispatched
        issue_ms = 0.0
        if prepared is not None and any(live):
            epochs = [s.req.seq.epoch for s in plan.scheduled]
            for s in plan.scheduled:    # device work now exists for these
                s.req.started = True
            ti = time.perf_counter()
            handle = self.runner.dispatch(self.params, prepared)
            issue_ms = (time.perf_counter() - ti) * 1e3
            self._inflight.append(_InflightStep(
                plan, handle, epochs, live, step=self.step_count,
                dispatched_at=ti))
        if san is not None:
            san.set_inflight(self._live_inflight_rids())
        return self._record_metrics(
            plan, slots_before, build_ms, wait_ms,
            tokens=self.runner.tokens_dispatched - tokens_before,
            issue_ms=issue_ms, queue_ms=queue_ms, compute_ms=compute_ms)

    def _live_inflight_rids(self) -> Set[str]:
        """Rids with live, epoch-valid segments still queued in the ring —
        i.e. dispatched device work that has not completed yet."""
        rids: Set[str] = set()
        for qinf in self._inflight:
            for i, s in enumerate(qinf.plan.scheduled):
                if qinf.live[i] and s.req.status == Status.RUNNING \
                        and s.req.seq.epoch == qinf.epochs[i]:
                    rids.add(s.req.rid)
        return rids

    def _complete(self, inf: _InflightStep,
                  deeper_rids: frozenset = frozenset()):
        """Fetch an in-flight step's results and run its delayed
        sample/advance. Device sampling blocks on the (segments,) int32
        token vector (4 bytes/segment) and only fetches logits rows under
        ``record_sample_logits``; host sampling blocks on the full logits.
        Segments whose request was preempted while in flight (stale epoch)
        or killed at reconciliation are skipped — recompute preemption
        regenerates their tokens deterministically. Returns (finished
        requests, fetch-block ms, queue ms, compute ms) — finish itself is
        deferred to the caller so it can reconcile the queued plans first,
        and only the device wait is timed (host bookkeeping after the
        fetch is not dispatch latency)."""
        t0 = time.perf_counter()
        n = len(inf.plan.scheduled)
        tokens = logits = None
        if self.device_sampling:
            tokens = self.runner.fetch_tokens(inf.handle, n)
            if self.cfg.record_sample_logits:
                logits = self.runner.fetch(inf.handle, n)
        else:
            logits = self.runner.fetch(inf.handle, n)
        now = time.perf_counter()
        wait_ms = (now - t0) * 1e3
        # host-observed pipeline split: time queued behind the previous
        # completion vs time actually computing (estimates — the device
        # executes dispatches in order, so the previous completion bounds
        # this step's start from below)
        prev = self._last_complete_t or inf.dispatched_at
        queue_ms = max(0.0, (prev - inf.dispatched_at) * 1e3)
        compute_ms = max(0.0, (now - max(inf.dispatched_at, prev)) * 1e3)
        self._last_complete_t = now
        done: List[Request] = []
        post_ops: List[StateCopyOp] = []
        for i, s in enumerate(inf.plan.scheduled):
            req, seq = s.req, s.req.seq
            if not inf.live[i] or req.status != Status.RUNNING \
                    or seq.epoch != inf.epochs[i] \
                    or seq.num_computed != s.start:
                continue
            # stamp with the COMPLETED step's index, not the current call's
            # (sync records the sampling step; async samples k calls later)
            post_ops.extend(self._advance(
                s, None if logits is None else logits[i],
                done=done, step=inf.step,
                token=None if tokens is None else int(tokens[i]),
                allow_checkpoints=req.rid not in deeper_rids))
        self.runner.apply_copies(post_ops)
        return done, wait_ms, queue_ms, compute_ms

    def _record_metrics(self, plan: StepPlan, slots_before: int,
                        build_ms: float, disp_ms: float,
                        tokens: Optional[int] = None,
                        issue_ms: float = 0.0, queue_ms: float = 0.0,
                        compute_ms: float = 0.0) -> StepMetrics:
        """``batched_tokens``/``dispatched_slots``/``pad_slots`` describe
        what was actually DISPATCHED (async: killed speculative segments'
        tokens drop out and their slots count as padding waste; a fully
        killed plan dispatches nothing); ``decode_batch``/``num_prefills``/
        ``prefill_tokens`` describe the PLAN as scheduled."""
        stats = self.mgr.memory_stats()
        slots = self.runner.slots_dispatched - slots_before
        tokens = plan.total_tokens if tokens is None else tokens
        r = self.runner
        attn_now = (r.kv_blocks_scanned, r.kv_blocks_skipped,
                    r.attn_flops_modeled, r.attn_bytes_modeled)
        attn_delta = tuple(a - b for a, b in zip(attn_now, self._attn_seen))
        self._attn_seen = attn_now
        m = StepMetrics(
            step=self.step_count,
            decode_batch=len(plan.decodes),
            prefill_tokens=plan.prefill_tokens,
            used_units=stats.used_units,
            evictable_units=stats.evictable_units,
            empty_units=stats.empty_units,
            free_units=stats.free_units,
            num_prefills=len(plan.prefills),
            batched_tokens=tokens,
            dispatched_slots=slots,
            pad_slots=max(0, slots - tokens),
            host_build_ms=build_ms,
            dispatch_ms=disp_ms,
            dispatch_issue_ms=issue_ms,
            dispatch_queue_ms=queue_ms,
            dispatch_compute_ms=compute_ms,
            host_sample_ms=self._sample_ms,
            sampled_bytes_fetched=r.bytes_fetched - self._bytes_seen,
            kv_blocks_scanned=attn_delta[0],
            kv_blocks_skipped=attn_delta[1],
            attn_flops_modeled=attn_delta[2],
            attn_bytes_modeled=attn_delta[3],
        )
        self.metrics.append(m)
        self._sample_ms = 0.0
        self._bytes_seen = r.bytes_fetched
        self.step_count += 1
        if self.autotuner is not None and self.autotuner.observe(m):
            self.scheduler.set_budgets(self.autotuner.budget,
                                       self.autotuner.prefill_cap)
        return m

    def _count_encoder_runs(self, scheduled: Sequence[ScheduledSeq]) -> None:
        if self.model.cfg.family not in ("vlm", "encdec"):
            return
        for s in scheduled:
            seq = s.req.seq
            if not s.is_prefill or s.start != 0:
                continue
            for it in (seq.mm_items or seq.encoder_items):
                if it.mm_hash not in self.mm_seen or not \
                        self.cfg.enable_prefix_caching:
                    self.encoder_runs += 1
                    self.mm_seen.add(it.mm_hash)

    def _advance(self, s: ScheduledSeq, logits: Optional[np.ndarray],
                 done: Optional[List[Request]] = None,
                 step: Optional[int] = None,
                 token: Optional[int] = None,
                 allow_checkpoints: bool = True) -> List[StateCopyOp]:
        """Post-dispatch bookkeeping for one scheduled sequence: record the
        computed tokens with the manager, sample once past the prompt, and
        return any state-checkpoint copy ops for batched execution. With
        ``done`` given (async), finish detection is deferred to the caller
        instead of retiring the request immediately; ``step`` overrides the
        step index stamped on first tokens/finishes (async completes step N
        k calls later — stamps must match the synchronous loop's). With
        ``token`` given (device sampling), the pick already happened in the
        dispatch's fused tail; ``logits`` may then be None unless rows are
        being recorded."""
        req, seq = s.req, s.req.seq
        step = self.step_count if step is None else step
        ops = self.mgr.advance(seq, s.num_tokens,
                               allow_checkpoints=allow_checkpoints)
        if s.is_prefill:    # vision free-on-consume only fires during prefill
            self.mgr.consume_mm(seq, seq.num_computed)
        self.mgr.touch(seq)
        if not req.in_prefill:          # decode, or prompt just completed
            if token is not None:
                if self.cfg.record_sample_logits:
                    v = self.model.cfg.vocab_size
                    self.sample_log.setdefault(req.rid, []).append(
                        np.asarray(logits[:v], np.float32).copy())
                tok = token
            else:
                tok = self._sample(req, logits)
            req.output.append(tok)
            seq.append_token(tok)
            if req.first_token_step is None:
                req.first_token_step = step
            if req.is_done():
                if done is None:
                    self._finish(req)
                else:
                    req.finished_step = step
                    done.append(req)
        return ops

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        """Host-side token pick over one FULL-WIDTH (v_pad) logits row.
        Same semantics as the device sampler (serving.sampler is the
        single source of truth): tie-banded greedy, or the seeded
        temperature/top-k draw keyed on (seed, rid_hash, position) — the
        temperature path runs the device computation itself (host_sample)
        so host- and device-sampled outputs are identical."""
        v = self.model.cfg.vocab_size
        if self.cfg.record_sample_logits:
            self.sample_log.setdefault(req.rid, []).append(
                np.asarray(logits[:v], np.float32).copy())
        t0 = time.perf_counter()
        sp = req.sampling
        if sp.temperature <= 0:
            # greedy with a deterministic tie-break on the fp32 logits
            # (lowest token id within TIE_EPS of the max — see TIE_EPS)
            tok = greedy_token(logits[:v])
        else:
            # position of the token being sampled == len(prompt + output);
            # layout- and batch-independent, so any scheduling mode
            # reproduces the same draw. The full padded row goes in: the
            # heads emit pad columns at -1e30 and the Gumbel noise shape
            # depends on the row width.
            tok = host_sample(logits, sp.temperature, sp.top_k,
                              rid_hash(req.rid), len(req.seq.tokens),
                              sp.seed)
        self._sample_ms += (time.perf_counter() - t0) * 1e3
        return tok

    def _finish(self, req: Request, cache_state: bool = True) -> None:
        if req.finished_step is None:   # async stamps at completion time
            req.finished_step = self.step_count
        self.scheduler.finish(req, cache=True, cache_state=cache_state)
        self.runner.forget(req.rid)
        self.finished.append(req)

    # ------------------------------------------------------ shard-mode hooks
    # A data-parallel fleet (serving.dp_engine) runs N engines behind a
    # router. The router needs three things from each engine: a health /
    # load snapshot to place and cost by, and two drain paths — graceful
    # (pull never-dispatched requests off a stalled shard) and crash
    # (reset EVERYTHING for failover, pages freed uncached).

    def health_snapshot(self) -> ShardHealth:
        """Cheap point-in-time health/backpressure view for the router."""
        stats = self.mgr.memory_stats()
        return ShardHealth(
            step=self.step_count,
            finished=len(self.finished),
            waiting=self.scheduler.queue_depth(),
            running=len(self.scheduler.running),
            outstanding_tokens=self.scheduler.outstanding_tokens(),
            inflight_steps=len(self._inflight),
            defer_count=self.scheduler.defer_count,
            preemption_count=self.scheduler.preemption_count,
            used_units=stats.used_units,
            free_units=stats.free_units,
            role=self.role,
        )

    def outstanding_tokens(self) -> int:
        """Router load key: tokens of work still to compute here."""
        return self.scheduler.outstanding_tokens()

    def drain_requests(self, unstarted_only: bool = True,
                       cache: bool = True) -> List[Request]:
        """Remove requests from this engine and return them reset for
        re-admission elsewhere (``Request.reset_for_routing``).

        ``unstarted_only=True`` (graceful drain of a stalled/backpressured
        shard) takes only requests that were never part of a dispatched
        plan (``req.started`` False — note ``seq.num_computed`` alone
        cannot distinguish them: a prefix-cache hit at admission sets it
        without any device work). Such requests have no device state and
        no sampled output, so moving them cannot lose or duplicate
        anything; admitted ones release their prefix-hit pages back to the
        cache unchanged (``cache=True`` is safe — nothing was advanced, so
        every page still holds exactly the content its hash describes).

        ``unstarted_only=False`` (crash failover) drops the in-flight ring
        unfetched and resets EVERY unfinished request; pages are then
        released UNCACHED regardless of ``cache`` — dispatched work may
        have mutated state pages past their boundary hashes (the PR-3
        poisoning rule), and a dead device's pages are untrusted anyway."""
        if not unstarted_only:
            self._inflight.clear()      # crash: in-flight results are lost
            cache = False
        out: List[Request] = []
        sched = self.scheduler
        for req in list(sched.waiting):
            if unstarted_only and req.started:
                continue
            sched.waiting.remove(req)
            out.append(req)
        for req in list(sched.running):
            if unstarted_only and req.started:
                continue
            sched.running.remove(req)
            out.append(req)
        for req in out:
            if req.seq is not None:
                # waiting-but-preempted requests hold no pages; admitted
                # ones do — preempt_request handles both uniformly
                self.mgr.preempt_request(req.seq, cache=cache)
                self.runner.forget(req.rid)
            self.sample_log.pop(req.rid, None)
            req.reset_for_routing()
        return out

    # --------------------------------------------- prefill->decode handoff
    # The second shard-mode drain path: a prefill-only shard hands a
    # prompt-complete request off to a decode shard at the prompt boundary.
    # Unlike drain_requests (which resets progress for re-admission), the
    # handoff preserves ALL progress: the typed page set is exported,
    # device-copied into the destination's pools, and the request resumes
    # there as a whole-prompt prefix hit with zero recomputed tokens.

    def handoff_ready(self) -> List[Request]:
        """Requests this prefill shard is done with: prompt fully computed,
        first token sampled (the prefill chunk's own dispatch samples it),
        and QUIET — no step still in the in-flight ring, so the device has
        stopped mutating their pages and the catch-up checkpoints of any
        suppressed boundaries have already been emitted."""
        if self.role != "prefill":
            return []
        live = self._live_inflight_rids()
        return [r for r in self.scheduler.running
                if r.seq is not None and not r.in_prefill
                and r.rid not in live]

    def begin_handoff(self, req: Request):
        """Detach a handoff-ready request and export its typed page set.
        The request leaves the scheduler (nothing more is dispatched for
        it); its pages stay resident here — IN_TRANSIT — while the copy
        stream reads them. Returns the ``PageSetExport``."""
        assert req in self.scheduler.running, req.rid
        self.scheduler.running.remove(req)
        return self.mgr.export_request(req.seq)

    def complete_handoff(self, req: Request, export) -> None:
        """Destination adopted the page set: release the export — the
        source copies retire into THIS shard's prefix cache exactly like a
        normal completion (future shared-prompt arrivals still hit here) —
        and drop the runner mirrors. The request itself lives on at the
        destination; it is not counted finished here."""
        self.mgr.release_export(req.seq, export)
        self.runner.forget(req.rid)

    def cancel_handoff(self, req: Request, export) -> None:
        """Adoption failed (destination pool pressure / death): lift the
        transit marks and requeue the request here untouched — it shows up
        in ``handoff_ready`` again next tick."""
        self.mgr.cancel_export(export)
        self.scheduler.running.append(req)

    def set_role(self, role: str) -> None:
        """Reassign the disaggregation role (colocated failover: prefill
        shards flip to "both" when no decode-capable shard is alive).
        Takes effect at the next ``schedule()`` call."""
        assert role in ("both", "prefill", "decode"), role
        self.role = role
        self.scheduler.cfg.prefill_only = role == "prefill"

    # ----------------------------------------------------------------- run
    @property
    def has_inflight(self) -> bool:
        """Whether any dispatched step is still awaiting completion."""
        return bool(self._inflight)

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        """Drive steps until every request finished (draining the in-flight
        ring on shutdown) or ``max_steps`` is hit."""
        while (self.scheduler.has_work() or self.has_inflight) \
                and self.step_count < max_steps:
            self.step()
        return self.finished
