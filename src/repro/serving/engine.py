"""Inference engine: token-budget continuous batching over the Jenga
manager.

Each ``step()`` is build-batch -> ONE ``serve_step`` dispatch -> advance /
sample / retire:

  1. ``Scheduler.schedule()`` packs a per-step token budget across ALL
     running requests — any number of concurrent prefill chunks plus every
     decode — and commits the step's page allocation transactionally;
  2. the step's state-restore copies run as one batched dispatch;
  3. ``ModelRunner.run_plan`` executes the whole mixed plan in a single
     jitted ``serve_step`` — token-packed into one (total_tokens,) stream
     with per-token segment ids by default ("packed"), or as (B, T)-padded
     rows under the PR-1 layout ("padded");
  4. every scheduled request advances; the engine samples PER SEGMENT
     (logits come back one row per scheduled item, in plan order);
     checkpoint copies emitted by ``advance`` run as one batched dispatch
     at the end of the step.

``batching_mode="serial"`` reproduces the legacy one-prefill-chunk-per-step
engine (prefill and decode as separate dispatches) for step-count A/Bs and
determinism tests.

Collects the per-step metrics the paper's figures are built from (decode
batch size Fig.15, memory breakdown Fig.16, hit rates Fig.17, encoder runs
Fig.18) plus the mixed-batch packing stats (tokens/step, prefills/step)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.manager import JengaKVCacheManager, StateCopyOp
from ..core.spec import KVCacheSpec
from .request import Request, SamplingParams, Status
from .runner import ModelRunner
from .scheduler import ScheduledSeq, Scheduler, SchedulerConfig, StepPlan


def stub_modality_embed(mm_hash: int, offset: int, dim: int) -> np.ndarray:
    """Deterministic stand-in for the vision/audio frontend (assignment:
    frontends are stubs; embeddings are 'precomputed')."""
    rng = np.random.default_rng((mm_hash & 0xFFFFFFFF, offset))
    return (0.05 * rng.standard_normal(dim)).astype(np.float32)


@dataclasses.dataclass
class EngineConfig:
    kv_pool_bytes: int = 64 << 20
    max_running: int = 16
    chunk_size: int = 64               # per-request prefill chunk cap
    max_num_batched_tokens: int = 256  # per-step mixed-batch token budget
    max_prefill_tokens_per_step: Optional[int] = None  # long-prefill cap
    # "packed"  — one (total_tokens,) token stream with per-token segment
    #             ids (vLLM-style varlen dispatch; per-step FLOPs follow
    #             the token budget);
    # "padded"  — the PR-1 mixed layout, one (B, T)-padded row/sequence
    #             ("mixed" is accepted as a legacy alias);
    # "serial"  — legacy one-prefill-chunk-per-step, two dispatch groups.
    batching_mode: str = "packed"
    enable_prefix_caching: bool = True
    memory_mode: str = "jenga"       # "jenga" | "paged-baseline"
    geometry_mode: str = "lcm"        # "lcm" | "max"
    seed: int = 0


@dataclasses.dataclass
class StepMetrics:
    step: int
    decode_batch: int          # decode sequences in this step's plan
    prefill_tokens: int        # prefill tokens across ALL chunks this step
    used_units: int
    evictable_units: int
    empty_units: int
    free_units: int
    waste_units: int = 0
    num_prefills: int = 0      # concurrent prefill chunks this step
    batched_tokens: int = 0    # total tokens in the mixed batch
    dispatched_slots: int = 0  # stream/row slots the dispatch actually paid


class Engine:
    def __init__(self, model, cfg: EngineConfig,
                 params=None, seed: int = 0):
        self.model = model
        if cfg.batching_mode == "mixed":        # legacy alias for PR-1 mode
            cfg = dataclasses.replace(cfg, batching_mode="padded")
        self.cfg = cfg
        assert cfg.batching_mode in ("packed", "padded", "serial"), \
            cfg.batching_mode
        baseline = cfg.memory_mode == "paged-baseline"
        self.mgr = JengaKVCacheManager(
            model.kv_specs(),
            total_memory_bytes=cfg.kv_pool_bytes,
            mode=cfg.geometry_mode,
            enable_prefix_caching=cfg.enable_prefix_caching,
            enable_inflight_retirement=not baseline,
            seed=cfg.seed,
        )
        if baseline:
            self._apply_baseline_semantics()
        self.scheduler = Scheduler(
            self.mgr, SchedulerConfig(
                max_running=cfg.max_running,
                chunk_size=cfg.chunk_size,
                max_num_batched_tokens=cfg.max_num_batched_tokens,
                max_prefill_tokens_per_step=cfg.max_prefill_tokens_per_step,
                serial=cfg.batching_mode == "serial"))
        self.runner = ModelRunner(model, self.mgr,
                                  stub_embed_fn=stub_modality_embed)
        self.params = params if params is not None else model.init(seed)
        self.step_count = 0
        self.metrics: List[StepMetrics] = []
        self.encoder_runs = 0
        self.mm_seen: set = set()
        self.finished: List[Request] = []

    # ------------------------------------------------- baseline semantics
    def _apply_baseline_semantics(self):
        """PagedAttention-style baseline (paper §3.2): all layer types are
        treated as full-prefix self-attention — mm/cross caches allocate
        pages for EVERY token, sliding windows never retire, eviction is a
        single uncustomized LRU."""
        from ..core.policies import FullAttentionPolicy
        mgr = self.mgr
        for name, spec in ((s.name, s) for s in mgr.specs):
            if spec.kind in ("swa", "vision_embed", "cross_attn"):
                pol = FullAttentionPolicy(spec)
                mgr.policies[name] = pol
        orig = mgr._mm_storage_upto

        def all_tokens(req, spec, main_pos):
            if spec.kind in ("vision_embed", "cross_attn") and not \
                    req.encoder_items:
                return main_pos            # every token, image or not
            return orig(req, spec, main_pos)

        mgr._mm_storage_upto = all_tokens

    # -------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        req.arrival = self.step_count
        self.scheduler.add(req)

    # ---------------------------------------------------------------- step
    def step(self) -> Optional[StepMetrics]:
        if not self.scheduler.has_work():
            return None
        plan = self.scheduler.schedule()
        # state restores of this step's admissions: one batched dispatch
        self.runner.apply_copies(plan.copy_ops)

        n_decodes = len(plan.decodes)
        n_prefills = len(plan.prefills)
        prefill_tokens = plan.prefill_tokens
        batched_tokens = plan.total_tokens
        slots_before = self.runner.slots_dispatched
        if plan.scheduled:
            self._count_encoder_runs(plan.scheduled)
            if self.cfg.batching_mode == "serial":
                # legacy two-dispatch step: prefill chunk, then decode batch
                groups = [g for g in (plan.prefills,
                                      [s for s in plan.scheduled
                                       if not s.is_prefill]) if g]
            else:
                groups = [plan.scheduled]
            packed = self.cfg.batching_mode == "packed"
            post_ops: List[StateCopyOp] = []
            for group in groups:
                logits = self.runner.run_plan(
                    self.params, [(s.req, s.num_tokens) for s in group],
                    packed=packed)
                for i, s in enumerate(group):
                    post_ops.extend(self._advance(s, logits[i]))
            # checkpoint copies emitted while advancing: one batched dispatch
            self.runner.apply_copies(post_ops)

        stats = self.mgr.memory_stats()
        m = StepMetrics(
            step=self.step_count,
            decode_batch=n_decodes,
            prefill_tokens=prefill_tokens,
            used_units=stats.used_units,
            evictable_units=stats.evictable_units,
            empty_units=stats.empty_units,
            free_units=stats.free_units,
            num_prefills=n_prefills,
            batched_tokens=batched_tokens,
            dispatched_slots=self.runner.slots_dispatched - slots_before,
        )
        self.metrics.append(m)
        self.step_count += 1
        return m

    def _count_encoder_runs(self, scheduled: Sequence[ScheduledSeq]) -> None:
        if self.model.cfg.family not in ("vlm", "encdec"):
            return
        for s in scheduled:
            seq = s.req.seq
            if not s.is_prefill or seq.num_computed != 0:
                continue
            for it in (seq.mm_items or seq.encoder_items):
                if it.mm_hash not in self.mm_seen or not \
                        self.cfg.enable_prefix_caching:
                    self.encoder_runs += 1
                    self.mm_seen.add(it.mm_hash)

    def _advance(self, s: ScheduledSeq, logits: np.ndarray
                 ) -> List[StateCopyOp]:
        """Post-dispatch bookkeeping for one scheduled sequence: record the
        computed tokens with the manager, sample once past the prompt, and
        return any state-checkpoint copy ops for batched execution."""
        req, seq = s.req, s.req.seq
        ops = self.mgr.advance(seq, s.num_tokens)
        if s.is_prefill:    # vision free-on-consume only fires during prefill
            self.mgr.consume_mm(seq, seq.num_computed)
        self.mgr.touch(seq)
        if not req.in_prefill:          # decode, or prompt just completed
            tok = self._sample(req, logits)
            req.output.append(tok)
            seq.append_token(tok)
            if req.first_token_step is None:
                req.first_token_step = self.step_count
            self._maybe_finish(req)
        return ops

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        v = self.model.cfg.vocab_size
        logits = logits[:v]
        if req.sampling.temperature <= 0:
            return int(np.argmax(logits))
        rng = np.random.default_rng(
            (req.sampling.seed, len(req.output), hash(req.rid) & 0xFFFF))
        p = logits / req.sampling.temperature
        p = np.exp(p - p.max())
        p /= p.sum()
        return int(rng.choice(v, p=p))

    def _maybe_finish(self, req: Request) -> None:
        if req.is_done():
            req.finished_step = self.step_count
            self.scheduler.finish(req, cache=True)
            self.runner.forget(req.rid)
            self.finished.append(req)

    # ----------------------------------------------------------------- run
    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        while self.scheduler.has_work() and self.step_count < max_steps:
            self.step()
        return self.finished
