"""Token-budget continuous-batching scheduler (vLLM-style, Kwon et al.
2023) on top of the Jenga manager.

``schedule()`` packs ONE mixed plan per engine step: every decode-phase
request contributes one token and as many concurrent prefill chunks as fit
the remaining per-step token budget (``max_num_batched_tokens``) ride along
in the same plan. The engine executes the whole plan as a single device
dispatch, which is how the batch capacity the Jenga allocator frees is
converted into tokens/step (paper §7, Fig. 13-15).

Allocation for the plan is batch-transactional: the manager's
``allocate_for_batch`` commits page capacity for every scheduled request or
rolls the step back as one unit (the §5.4 property lifted to the plan
level). On failure the scheduler preempts the latest-arrival running
request (vLLM recompute preemption) — preferring victims outside the plan,
then shrinking the plan itself — and retries.

ASYNC SCHEDULING (``Engine`` pipelining): ``schedule(inflight=...)``
plans the NEXT step while up to ``pipeline_depth - 1`` earlier steps are
still executing on the device. ``inflight`` maps request id ->
``(tokens, samples)`` the in-flight ring is computing (a bare int is
accepted as ``(tokens, tokens-will-sample)`` for direct callers); packing
uses the EFFECTIVE position ``num_computed + inflight_tokens``
(vLLM async-scheduling style):

  * an in-flight prefill chunk continues from its effective end;
  * a request whose prompt completes in flight is speculatively scheduled
    as a decode of the token the in-flight step is about to sample — its
    token id is patched into the prepared batch when the logits land, and
    its +1 page commitment is rolled back (``mgr.rollback_tokens``) if the
    sample turns out to be EOS;
  * a request whose in-flight SAMPLES deterministically exhaust
    ``max_new_tokens`` is not schedulable — it WILL finish (with several
    steps queued, each in-flight decode row past the prompt counts as one
    sample).

Preempting a request with tokens in flight releases its pages WITHOUT
caching (``preempt_request(cache=False)``): the device is still mutating
its live recurrent state past the position the boundary hash describes,
so caching would poison later prefix hits.

``serial=True`` reproduces the legacy one-prefill-chunk-per-step schedule
(no token budget, decodes unbudgeted); the engine then issues prefill and
decode as separate dispatches. It exists for A/B step-count comparisons and
for the mixed-vs-serial determinism tests. Serial mode is never driven
with ``inflight`` (the engine falls back to the synchronous loop).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from ..core.manager import JengaKVCacheManager, StateCopyOp
from .request import Request, Status


@dataclasses.dataclass
class SchedulerConfig:
    """Packing knobs for one engine step.

    Interactions with ``EngineConfig``: ``serial`` mirrors
    ``batching_mode="serial"`` (legacy one-prefill-per-step schedule) and
    is incompatible with async double-buffering — the engine silently runs
    the synchronous loop for it; ``"packed"``/``"padded"`` layouts both
    support ``async_scheduling`` (the layout only changes how the runner
    flattens the plan, not how it is scheduled)."""
    max_running: int = 16
    chunk_size: int = 64            # serial-mode prefill chunk size
    max_num_batched_tokens: int = 256   # per-step mixed-batch token budget
    # Latency-aware packing: cap on PREFILL tokens per step (None = the
    # whole budget). Depth-first packing optimizes throughput, but a huge
    # prompt would otherwise monopolize the step budget for many steps in a
    # row and starve decode latency; the cap reserves the remainder of the
    # budget for decodes every step.
    max_prefill_tokens_per_step: Optional[int] = None
    max_preemptions: int = 100
    serial: bool = False            # legacy one-prefill-per-step schedule
    # Disaggregated serving: a prefill-only shard never schedules decode
    # rows. A request whose prompt completes (its first token sampled by
    # the prefill chunk's own dispatch) simply goes quiet and waits for the
    # DPEngine handoff to move it to a decode shard.
    prefill_only: bool = False


@dataclasses.dataclass
class ScheduledSeq:
    """One request's share of a step: compute ``num_tokens`` tokens starting
    at position ``start`` (1 for decodes, a chunk for prefills).
    ``is_prefill`` is snapshotted at schedule time (advancing the sequence
    flips ``req.in_prefill`` before step metrics are read). ``start``
    equals ``seq.num_computed`` for synchronous plans and runs ahead of it
    by the in-flight token count under async scheduling."""
    req: Request
    num_tokens: int
    is_prefill: bool = False
    start: int = -1


@dataclasses.dataclass
class StepPlan:
    """Flattened mixed batch for one engine step: decodes first, then
    prefill chunks, all dispatched together (or in two groups under the
    serial compat schedule).

    ``total_tokens`` / ``prefill_tokens`` are computed ONCE at construction
    (the plan is immutable after ``schedule()`` returns) — consumers in the
    engine/runner read the cached fields instead of re-walking the
    scheduled list on every access."""
    scheduled: List[ScheduledSeq]
    copy_ops: List[StepCopy] = dataclasses.field(default_factory=list)
    total_tokens: int = dataclasses.field(init=False, default=0)
    prefill_tokens: int = dataclasses.field(init=False, default=0)

    def __post_init__(self):
        self.total_tokens = sum(s.num_tokens for s in self.scheduled)
        self.prefill_tokens = sum(s.num_tokens for s in self.scheduled
                                  if s.is_prefill)

    @property
    def decodes(self) -> List[Request]:
        return [s.req for s in self.scheduled if not s.is_prefill]

    @property
    def prefills(self) -> List[ScheduledSeq]:
        return [s for s in self.scheduled if s.is_prefill]


StepCopy = StateCopyOp


class Scheduler:
    def __init__(self, manager: JengaKVCacheManager, cfg: SchedulerConfig):
        self.mgr = manager
        self.cfg = cfg
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.preemption_count = 0
        # backpressure signal: prefill chunks dropped from a plan because
        # the batch allocation would not commit (defer-then-preempt's first,
        # cheaper resort). Together with ``preemption_count`` this is what a
        # data-parallel router reads to cost a thrashing shard (a shard
        # repeatedly deferring/preempting is out of memory headroom — more
        # traffic makes it worse, not faster).
        self.defer_count = 0
        self._inflight_rids: frozenset = frozenset()

    def add(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # --------------------------------------------------------- load signals
    def outstanding_tokens(self) -> int:
        """Tokens of admitted-or-queued work still to compute: remaining
        prompt plus remaining decode budget over every waiting and running
        request. This is the router's least-loaded placement key — unlike
        queue DEPTH it weighs a queue of huge prompts correctly against a
        queue of one-token decodes."""
        total = 0
        for req in list(self.waiting) + self.running:
            done = req.seq.num_computed if req.seq is not None else 0
            total += max(0, len(req.prompt) - done)
            total += max(0, req.sampling.max_new_tokens - req.num_generated)
        return total

    def queue_depth(self) -> int:
        """Requests admitted to nothing yet (waiting only)."""
        return len(self.waiting)

    def set_budgets(self, max_num_batched_tokens: int,
                    max_prefill_tokens_per_step: Optional[int]) -> None:
        """Retarget the step packing budgets between steps (autotuning —
        see serving.autotune). ``schedule()`` reads the config fresh each
        call, so the next plan picks the new budgets up immediately."""
        self.cfg.max_num_batched_tokens = max_num_batched_tokens
        self.cfg.max_prefill_tokens_per_step = max_prefill_tokens_per_step

    # ------------------------------------------------------------ schedule
    def schedule(self, inflight: Optional[Dict[str, object]] = None
                 ) -> StepPlan:
        # normalize values to (tokens_in_flight, samples_in_flight)
        inflight = {rid: v if isinstance(v, tuple) else (v, 1)
                    for rid, v in (inflight or {}).items()}
        self._inflight_rids = frozenset(inflight)

        # 1) admit new requests while capacity allows; begin_request acquires
        #    prefix-cache hits and may emit state-restore copy ops.
        admit_ops: List[Tuple[Request, StateCopyOp]] = []
        while self.waiting and len(self.running) < self.cfg.max_running:
            req = self.waiting[0]
            if req.seq is None or req.seq.num_computed == 0:
                seq = req.make_seq() if req.seq is None else req.seq
                ok, ops = self.mgr.begin_request(seq)
                if not ok:
                    break
                admit_ops.extend((req, op) for op in ops)
            self.waiting.popleft()
            req.status = Status.RUNNING
            self.running.append(req)

        def c_eff(req: Request) -> int:
            """Effective computed position: what the request will have once
            the in-flight step lands."""
            return req.seq.num_computed + inflight.get(req.rid, (0, 0))[0]

        def will_finish(req: Request) -> bool:
            """The in-flight ring deterministically samples this request's
            last allowed token (max_new_tokens) — it cannot take more work.
            EOS finishes are NOT predictable; those are speculatively
            scheduled and reconciled by the engine (segment kill + page
            rollback)."""
            samples = inflight.get(req.rid, (0, 0))[1]
            return (req.rid in inflight and c_eff(req) >= len(req.prompt)
                    and req.num_generated + samples
                    >= req.sampling.max_new_tokens)

        schedulable = [r for r in self.running if not will_finish(r)]
        if self.cfg.prefill_only:
            # prefill shard: requests past their prompt await handoff
            schedulable = [r for r in schedulable
                           if c_eff(r) < len(r.prompt)]

        # 2) pack candidates under the token budget: decodes first (they are
        #    latency-critical and cheap), then prefill chunks FIFO.
        budget = self.cfg.max_num_batched_tokens
        cands: List[ScheduledSeq] = []
        for req in schedulable:
            if c_eff(req) < len(req.prompt):
                continue                # still prefilling (effectively)
            if not self.cfg.serial and budget <= 0:
                break               # budget exhausted; rest run next step
            cands.append(ScheduledSeq(req, 1, is_prefill=False,
                                      start=c_eff(req)))
            budget -= 1
        # Prefill packing is DEPTH-first: the oldest prefill takes as much
        # of the remaining budget as its prompt needs, then the next, ...
        # (one request reaches decode quickly and frees its slack instead
        # of every request holding a memory-hungry partial prefill). The
        # per-request ``chunk_size`` cap only applies to the serial compat
        # schedule; in mixed mode the budget IS the chunking control —
        # bounded by ``max_prefill_tokens_per_step`` so a huge prompt
        # cannot monopolize every step's budget and starve decode latency.
        n_prefills = 0
        p_budget = budget
        if self.cfg.max_prefill_tokens_per_step is not None:
            p_budget = min(p_budget, self.cfg.max_prefill_tokens_per_step)
        for req in schedulable:
            ce = c_eff(req)
            if ce >= len(req.prompt):
                continue
            if self.cfg.serial and n_prefills >= 1:
                break
            cap = self.cfg.chunk_size if self.cfg.serial else p_budget
            chunk = min(cap, len(req.prompt) - ce)
            if chunk <= 0:
                break               # out of budget; later prefills wait
            cands.append(ScheduledSeq(req, chunk, is_prefill=True, start=ce))
            budget -= chunk
            p_budget -= chunk
            n_prefills += 1

        # 3) batch-transactional allocation: retry until the WHOLE plan
        #    commits as one unit. On failure, first DEFER prefill chunks
        #    (drop from this step's plan, keep their pages — no progress is
        #    lost), then fall back to recompute preemption of the
        #    latest-arrival running request so the oldest request always
        #    makes progress (no livelock under memory pressure).
        while cands:
            seqs = [c.req.seq for c in cands]
            targets = [c.start + c.num_tokens for c in cands]
            if self.mgr.allocate_for_batch(seqs, targets):
                break
            prefills = [c for c in cands if c.is_prefill]
            if prefills:
                cands.remove(self._latest(prefills, key=lambda c: c.req))
                self.defer_count += 1
                continue
            keep = min(cands, key=lambda c: c.req.arrival).req
            victims = [r for r in self.running if r is not keep]
            if not victims:
                self._preempt(keep)     # a single request cannot fit at all
                cands = []
                break
            self._preempt(self._latest(victims))
            cands = [c for c in cands if c.req.status == Status.RUNNING]

        # 4) progress guarantee: if every candidate was deferred (all
        #    running requests hold pages but none can grow), the oldest
        #    SCHEDULABLE request gets its tokens by recompute-preempting
        #    latest-arrival victims — otherwise mid-prefill requests
        #    deadlock the pool. (Requests that merely await their in-flight
        #    completion are not starved — they need no allocation.)
        schedulable = [r for r in schedulable if r.status == Status.RUNNING]
        if not cands and schedulable:
            head = min(schedulable, key=lambda r: r.arrival)
            ce = c_eff(head)
            cap = (self.cfg.chunk_size if self.cfg.serial
                   else self.cfg.max_num_batched_tokens)
            if not self.cfg.serial and \
                    self.cfg.max_prefill_tokens_per_step is not None:
                cap = min(cap, self.cfg.max_prefill_tokens_per_step)
            nt = (min(cap, len(head.prompt) - ce)
                  if ce < len(head.prompt) else 1)
            while not self.mgr.allocate_for_tokens(head.seq, ce + nt):
                victims = [r for r in self.running if r is not head]
                if not victims:
                    self._preempt(head)   # a lone request that cannot fit
                    break
                self._preempt(self._latest(victims))
            else:
                cands = [ScheduledSeq(head, nt,
                                      is_prefill=ce < len(head.prompt),
                                      start=ce)]

        # restore ops of admissions that got preempted again in step 3 must
        # not run (their destination pages are already freed)
        copy_ops = [op for req, op in admit_ops
                    if req.status == Status.RUNNING]
        return StepPlan(scheduled=cands, copy_ops=copy_ops)

    # ------------------------------------------------------------ preempt
    def _latest(self, items, key=lambda x: x):
        """Latest-ARRIVAL element; ties break toward the latest-ADMITTED
        (highest index in ``running``). Bare ``max`` would return the first
        maximal element — the oldest, most-progressed request — inverting
        the recompute-preemption policy whenever arrivals tie (every batch
        submitted before stepping shares one arrival stamp)."""
        # keyed by rid (unique per request), not id(): object identity is
        # allocation-order dependent and would break bit-for-bit replay
        order = {r.rid: i for i, r in enumerate(self.running)}
        return max(items, key=lambda it: (key(it).arrival,
                                          order.get(key(it).rid, -1)))

    def _preempt(self, req: Request) -> None:
        # an in-flight victim's device state runs ahead of its hash chains —
        # releasing its pages to the prefix cache would poison later hits
        self.mgr.preempt_request(req.seq,
                                 cache=req.rid not in self._inflight_rids)
        req.preemptions += 1
        self.preemption_count += 1
        req.status = Status.WAITING
        self.running.remove(req)
        self.waiting.appendleft(req)

    # ------------------------------------------------------------- finish
    def finish(self, req: Request, cache: bool = True,
               cache_state: bool = True) -> None:
        self.mgr.free_request(req.seq, cache=cache, cache_state=cache_state)
        req.status = Status.FINISHED
        if req in self.running:
            self.running.remove(req)
