"""Continuous-batching scheduler with chunked prefill and recompute
preemption, integrated with the Jenga manager (begin/allocate/preempt)."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from ..core.manager import JengaKVCacheManager, StateCopyOp
from .request import Request, Status


@dataclasses.dataclass
class SchedulerConfig:
    max_running: int = 16
    chunk_size: int = 64            # chunked-prefill token budget per step
    max_preemptions: int = 100


@dataclasses.dataclass
class StepPlan:
    prefill: Optional[Request]          # one prefill chunk this step
    prefill_tokens: int
    decodes: List[Request]              # requests decoding one token each
    copy_ops: List[StepCopy] = dataclasses.field(default_factory=list)


StepCopy = StateCopyOp


class Scheduler:
    def __init__(self, manager: JengaKVCacheManager, cfg: SchedulerConfig):
        self.mgr = manager
        self.cfg = cfg
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.preemption_count = 0

    def add(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ schedule
    def schedule(self) -> StepPlan:
        copy_ops: List[StateCopyOp] = []
        # 1) admit new requests while capacity allows
        while (self.waiting and len(self.running) < self.cfg.max_running):
            req = self.waiting[0]
            if req.seq is None or req.seq.num_computed == 0:
                seq = req.make_seq() if req.seq is None else req.seq
                ok, ops = self.mgr.begin_request(seq)
                if not ok:
                    break
                copy_ops.extend(ops)
            self.waiting.popleft()
            req.status = Status.RUNNING
            self.running.append(req)

        # 2) pick one prefill chunk (FIFO among running prefills)
        plan_prefill = None
        prefill_tokens = 0
        for req in self.running:
            if req.in_prefill:
                seq = req.seq
                target = min(len(req.prompt),
                             seq.num_computed + self.cfg.chunk_size)
                while not self.mgr.allocate_for_tokens(seq, target):
                    victim = self._pick_victim(exclude=req)
                    if victim is None:
                        target = 0
                        break
                    self._preempt(victim)
                if target > seq.num_computed:
                    plan_prefill = req
                    prefill_tokens = target - seq.num_computed
                break

        # 3) all decode-phase requests step one token
        decodes = []
        for req in list(self.running):
            if req.in_prefill or req is plan_prefill:
                continue
            seq = req.seq
            while not self.mgr.allocate_for_tokens(seq, seq.num_tokens):
                victim = self._pick_victim(exclude=req)
                if victim is None or victim is req:
                    victim = req          # self-preempt as last resort
                self._preempt(victim)
                if victim is req:
                    seq = None
                    break
            if seq is not None:
                decodes.append(req)
        return StepPlan(prefill=plan_prefill, prefill_tokens=prefill_tokens,
                        decodes=decodes, copy_ops=copy_ops)

    # ------------------------------------------------------------ preempt
    def _pick_victim(self, exclude: Request) -> Optional[Request]:
        """Latest-arrival running request (vLLM recompute preemption)."""
        cands = [r for r in self.running if r is not exclude]
        if not cands:
            return None
        return max(cands, key=lambda r: r.arrival)

    def _preempt(self, req: Request) -> None:
        self.mgr.preempt_request(req.seq)
        req.preemptions += 1
        self.preemption_count += 1
        req.status = Status.WAITING
        self.running.remove(req)
        self.waiting.appendleft(req)

    # ------------------------------------------------------------- finish
    def finish(self, req: Request, cache: bool = True) -> None:
        self.mgr.free_request(req.seq, cache=cache)
        req.status = Status.FINISHED
        if req in self.running:
            self.running.remove(req)
