"""Serving-level request objects."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple

from ..core.request import MMItem, SequenceState


class Status(enum.Enum):
    WAITING = 0
    RUNNING = 1
    FINISHED = 2


@dataclasses.dataclass
class SamplingParams:
    max_new_tokens: int = 16
    temperature: float = 0.0        # 0 = greedy
    top_k: int = 0                  # 0 = no truncation (temperature > 0 only)
    eos_token: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: str
    prompt: List[int]
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    mm_items: Tuple[MMItem, ...] = ()
    encoder_items: Tuple[MMItem, ...] = ()
    status: Status = Status.WAITING
    arrival: float = 0.0
    seq: Optional[SequenceState] = None
    output: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    first_token_step: Optional[int] = None
    finished_step: Optional[int] = None

    def make_seq(self) -> SequenceState:
        self.seq = SequenceState(
            rid=self.rid, tokens=list(self.prompt),
            mm_items=self.mm_items, encoder_items=self.encoder_items)
        return self.seq

    @property
    def in_prefill(self) -> bool:
        return (self.seq is not None
                and self.seq.num_computed < len(self.prompt))

    @property
    def num_generated(self) -> int:
        return len(self.output)

    def is_done(self) -> bool:
        if self.num_generated >= self.sampling.max_new_tokens:
            return True
        eos = self.sampling.eos_token
        return eos is not None and self.output and self.output[-1] == eos
