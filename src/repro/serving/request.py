"""Serving-level request objects."""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

from ..core import prefix_cache as pc
from ..core.request import MMItem, SequenceState


class Status(enum.Enum):
    WAITING = 0
    RUNNING = 1
    FINISHED = 2


@dataclasses.dataclass
class SamplingParams:
    max_new_tokens: int = 16
    temperature: float = 0.0        # 0 = greedy
    top_k: int = 0                  # 0 = no truncation (temperature > 0 only)
    eos_token: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: str
    prompt: List[int]
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    mm_items: Tuple[MMItem, ...] = ()
    encoder_items: Tuple[MMItem, ...] = ()
    status: Status = Status.WAITING
    arrival: float = 0.0
    seq: Optional[SequenceState] = None
    output: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    first_token_step: Optional[int] = None
    finished_step: Optional[int] = None
    # ---- routing metadata (multi-engine data-parallel serving) ----
    # True once the request has been part of a DISPATCHED plan on some
    # engine (device work exists / existed for it). A never-dispatched
    # request is trivially safe to pull off a shard and re-admit elsewhere:
    # there is no device state to lose and no output to deduplicate.
    started: bool = False
    # shard ids this request was placed on, in order (last = current);
    # >1 entry means the request survived a shard drain / failover.
    shard_history: List[int] = dataclasses.field(default_factory=list)
    # memoized prompt boundary-hash chains, keyed on (tokens_per_page,
    # salt) — the router probes every shard's prefix cache with the same
    # chains, so they are computed once per request, not once per probe.
    _route_hashes: Dict[tuple, list] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def make_seq(self) -> SequenceState:
        self.seq = SequenceState(
            rid=self.rid, tokens=list(self.prompt),
            mm_items=self.mm_items, encoder_items=self.encoder_items)
        return self.seq

    # ------------------------------------------------- routing hash chains
    def routing_keys(self) -> List[int]:
        """Per-position content keys of the PROMPT (text token ids, mm
        content keys) — the stream every shard's prefix-cache chains hash
        over. Memoized; prompts are immutable."""
        keys = self._route_hashes.get(("keys",))
        if keys is None:
            keys = pc.key_stream(self.prompt, self.mm_items)
            self._route_hashes[("keys",)] = keys
        return keys

    def prompt_boundary_hashes(self, tokens_per_page: int,
                               salt: int) -> List[int]:
        """Chain hash per FULL prompt page for a token-storage type with
        this page geometry — exactly the keys a shard's pool registers its
        pages under, so ``pool.lookup`` on these answers "does this shard
        hold my prefix"."""
        k = ("page", tokens_per_page, salt)
        h = self._route_hashes.get(k)
        if h is None:
            h = pc.page_chain_hashes(self.routing_keys(), tokens_per_page,
                                     salt)
            self._route_hashes[k] = h
        return h

    def prompt_state_hashes(self, interval: int,
                            salt: int) -> List[Tuple[int, int]]:
        """(position, chain-hash) at every state-checkpoint boundary inside
        the prompt — the keys state-type (mamba/rwkv) snapshot pages are
        registered under."""
        k = ("state", interval, salt)
        out = self._route_hashes.get(k)
        if out is None:
            out = []
            h = salt
            for i, key in enumerate(self.routing_keys()):
                h = pc.combine(h, key)
                if (i + 1) % interval == 0:
                    out.append((i + 1, h))
            self._route_hashes[k] = out
        return out

    # ------------------------------------------------------- re-admission
    def reset_for_routing(self) -> None:
        """Return to a fresh, unplaced state so another shard can admit the
        request from scratch. Any partial progress (sampled tokens, shard-
        local sequence state) is DISCARDED — greedy and the seeded
        temperature draws are deterministic in (rid, position), so a full
        recompute elsewhere reproduces the same output, which is what makes
        cross-shard failover exactly-once. The old shard must already have
        released the request's pages (``Engine.drain_requests``)."""
        self.status = Status.WAITING
        self.seq = None
        self.output = []
        self.started = False
        self.first_token_step = None
        self.finished_step = None

    @property
    def in_prefill(self) -> bool:
        return (self.seq is not None
                and self.seq.num_computed < len(self.prompt))

    @property
    def num_generated(self) -> int:
        return len(self.output)

    def is_done(self) -> bool:
        if self.num_generated >= self.sampling.max_new_tokens:
            return True
        eos = self.sampling.eos_token
        return eos is not None and self.output and self.output[-1] == eos
