"""ModelRunner: builds device batches from Jenga manager state and runs
bucketed jitted serve steps (no retrace across allocator changes — exec
page ids are plain i32 data, the paper's §4.2 property).

One ``run_plan`` call executes a whole scheduler step — any number of
concurrent prefill chunks plus all decodes — as a single dispatch, in one
of two layouts:

* PACKED (default, vLLM-style): the step is flattened into ONE
  ``(total_tokens_bucket,)`` token stream with per-token ``segment_ids``,
  absolute ``positions``, per-token KV write targets, and per-segment
  ``(start, last_tok)`` metadata; per-type page tables are likewise
  flattened into one page stream with per-page owning segments. Per-step
  FLOPs in the dense layers are proportional to the scheduler's token
  budget — a decode row co-scheduled with a 512-token prefill chunk no
  longer pays 512 tokens of padding. Token buckets are pow2 up to 16 then
  multiples of 16 (see ``_tok_bucket``), so jit retraces stay bounded while
  stream padding waste stays under ~10% on decode-heavy mixed steps.

* PADDED (the PR-1 layout, kept for A/Bs): one row per sequence, padded to
  the ``(B=_pow2(n), T=_pow2(max_chunk))`` bucket with SENTINEL positions
  at pads — per-step FLOPs scale with B*T, not with the token budget.

``run_plan`` is three phases the async engine drives separately:

  * ``prepare`` builds the whole batch as HOST numpy (``PreparedStep``) —
    this is the part double-buffering hides behind the previous step's
    in-flight dispatch. Decode items whose token id is not sampled yet
    (async speculative scheduling: the in-flight step produces it) are
    recorded in ``PreparedStep.pending`` and patched in later.
  * ``dispatch`` uploads, zeroes fresh pages, and issues the jitted
    ``serve_step`` without blocking (JAX async dispatch); it returns the
    device logits handle.
  * ``fetch`` blocks on the handle and returns per-segment logits rows.

``PreparedStep.kill_segment`` neutralizes one segment to pad semantics
(used when a speculatively scheduled request turns out to have finished at
the in-flight step): its tokens become pads (segment id -1, SENTINEL
positions), its KV/state writes drop (-1 exec ids), its logits row is
garbage the caller discards. The packed scan/attention math is keyed
entirely on segment-id equality, so an interior pad run is as inert as the
tail pads every dispatch already carries.

Host-side cost model: per-request block tables are kept as persistent
numpy mirrors updated incrementally from the manager's append/free/trim
deltas (``SequenceState.freed_events`` / ``trim_events`` + table length),
instead of re-walking O(pages) python lists per request per step. All
``StateCopyOp``s of a step phase execute as one batched gather/scatter
dispatch per KV type instead of one jit call per op.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.manager import JengaKVCacheManager, StateCopyOp
from ..core.request import SequenceState
from ..core.spec import lcm as _lcm
from ..models.lm import DecodeBatch
from .request import Request
from .sampler import get_sample_fn, inject_tokens, rid_hash

SENTINEL_POS = np.int32(1 << 29)


def _pow2(n: int, lo: int = 1) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


def _tok_bucket(n: int) -> int:
    """Packed-stream token bucket: pow2 below 16 (decode-only steps hit
    exact small buckets), then multiples of 16 — bounded retraces with
    <= 15 pad slots per dispatch instead of pow2's up-to-50% waste."""
    if n <= 16:
        return _pow2(n)
    return 16 * (-(-n // 16))


def _norm_items(items) -> List[Tuple[Request, int, int]]:
    """Normalize plan items to (request, num_tokens, start): 2-tuples keep
    the synchronous default ``start = seq.num_computed``; the async engine
    passes explicit starts that run ahead of ``num_computed`` while the
    previous step is still in flight."""
    out = []
    for it in items:
        r, nt = it[0], it[1]
        start = it[2] if len(it) > 2 and it[2] >= 0 else r.seq.num_computed
        out.append((r, nt, start))
    return out


@dataclasses.dataclass
class StepHandle:
    """Device handles of one dispatched step: the per-segment logits (and,
    when the dispatch carried a fused sampling tail, the sampled token
    vector). ``fetch_tokens`` blocks on 4 bytes per segment; ``fetch``
    on the full ``(segments, v_pad)`` fp32 matrix."""

    logits: object
    tokens: object = None
    n: int = 0


@dataclasses.dataclass
class PreparedStep:
    """One plan's device batch, still host-side numpy (phase 1 of 3).

    ``pending`` lists segment indices whose (single) decode token id was
    not known at build time — the in-flight step samples it; the engine
    calls ``patch_token`` once the sample lands, or ``kill_segment`` if
    the request turned out to have finished instead. With device
    sampling, pending decode rows are instead moved to ``board_fed``:
    their token id is read ON DEVICE from the sampled-token board
    (``tok_src`` holds the board slot per token position, -1 elsewhere),
    so no host patch is needed and >1 step can stay in flight."""

    arrs: Dict[str, object]           # DecodeBatch field -> numpy / dict
    info: dict
    items: List[Tuple[Request, int, int]]
    packed: bool
    pending: List[int]
    dead: set = dataclasses.field(default_factory=set)
    samp: Optional[dict] = None       # fused sampling tail metadata
    tok_src: Optional[np.ndarray] = None
    board_fed: List[int] = dataclasses.field(default_factory=list)

    @property
    def n(self) -> int:
        return self.info["n"]

    def patch_token(self, si: int, tok: int) -> None:
        """Fill segment ``si``'s (single) decode token id."""
        if self.packed:
            off, nt = self.info["seg_off"][si]
            assert nt == 1, (si, nt)
            self.arrs["tokens"][0, off] = tok
        else:
            self.arrs["tokens"][si, 0] = tok
        if si in self.pending:
            self.pending.remove(si)

    def kill_segment(self, si: int) -> None:
        """Neutralize segment ``si`` to pad semantics: the request finished
        at the in-flight step, so its speculative slot must compute nothing
        and write nowhere. Its logits row becomes garbage (the engine skips
        it); no live token can see a pad, so the other segments' outputs
        are bit-identical with or without the dead slot."""
        self.dead.add(si)
        if si in self.pending:
            self.pending.remove(si)
        if si in self.board_fed:
            self.board_fed.remove(si)
        if self.samp is not None:
            # dead segment: no board write, no random draw needed
            self.samp["dst"][si] = -1
            self.samp["temps"][si] = 0.0
        a = self.arrs
        if self.packed:
            off, nt = self.info["seg_off"][si]
            sl = slice(off, off + nt)
            a["tokens"][0, sl] = 0
            a["positions"][0, sl] = SENTINEL_POS
            a["seg_ids"][0, sl] = -1
            a["chunk_start"][0, sl] = SENTINEL_POS
            if a["mm_mask"] is not None:
                a["mm_mask"][0, sl] = False
            for v in a["write_eids"].values():
                v[0, 0, 0, sl] = -1
            for v in a["page_seg"].values():
                np.place(v, v == si, -2)
        else:
            a["tokens"][si, :] = 0
            a["positions"][si, :] = SENTINEL_POS
            a["seq_lens"][si] = 1
            a["last_idx"][si] = 0
            if a["mm_mask"] is not None:
                a["mm_mask"][si, :] = False
            for v in a["write_eids"].values():
                v[0, 0, si, :] = -1
            for v in a["tables"].values():
                v[0, 0, si, :] = -1
            for v in a["page_pos"].values():
                v[0, 0, si, :] = SENTINEL_POS
        for v in a["state_eids"].values():
            v[0, si] = -1
        if self.tok_src is not None:
            if self.packed:
                off, nt = self.info["seg_off"][si]
                self.tok_src[0, off:off + nt] = -1
            else:
                self.tok_src[si, :] = -1


class _SeqMirror:
    """Persistent per-request device-batch state: block-table + slot-position
    arrays per KV type, grown geometrically and patched from manager deltas."""

    __slots__ = ("epoch", "evt_cursor", "trim_cursor", "table", "pos", "n")

    def __init__(self, epoch: int):
        self.epoch = epoch
        self.evt_cursor = 0
        self.trim_cursor = 0
        self.table: Dict[str, np.ndarray] = {}
        self.pos: Dict[str, np.ndarray] = {}
        self.n: Dict[str, int] = {}

    def _ensure(self, name: str, cap: int) -> None:
        cur = self.table.get(name)
        if cur is not None and cur.shape[0] >= cap:
            return
        new_cap = _pow2(cap, 8)
        table = np.full((new_cap,), -1, np.int32)
        pos = np.full((new_cap,), SENTINEL_POS, np.int32)
        if cur is not None:
            table[: cur.shape[0]] = cur
            pos[: cur.shape[0]] = self.pos[name]
        self.table[name] = table
        self.pos[name] = pos


def _seg_intervals(vals: np.ndarray, block: int):
    """Per-block (lo, hi) segment-id intervals of a flat id stream, pads
    (negative ids) excluded; an all-pad block gets an empty interval that
    overlaps nothing."""
    n = -(-vals.shape[0] // block)
    pad = n * block - vals.shape[0]
    v = np.pad(vals, (0, pad), constant_values=-2).reshape(n, block)
    valid = v >= 0
    big = 1 << 30
    lo = np.where(valid, v, big).min(axis=1)
    hi = np.where(valid, v, -big).max(axis=1)
    return lo, hi


class ModelRunner:
    def __init__(self, model, manager: JengaKVCacheManager,
                 stub_embed_fn=None, attention_impl: str = "ref"):
        assert attention_impl in ("ref", "kernel"), attention_impl
        self.model = model
        self.mgr = manager
        self.attention_impl = attention_impl
        self.specs = {s.name: s for s in model.kv_specs()}
        self.stub_embed_fn = stub_embed_fn
        big = _lcm([s.page_units for s in self.specs.values()])
        units = manager.geometry.total_units + big   # + scratch page
        self.buffer = jnp.zeros((1, 1, units), jnp.bfloat16)
        # serve-step jit cache, shared across ALL runners of one model:
        # the cache pins the static ``prefill`` flag per bucket key, and
        # jax.jit itself retraces per input shape, so runners over pools of
        # different sizes coexist safely. Engines are recreated freely in
        # tests/benchmarks (A/B over batching modes, async vs sync) —
        # without sharing, every engine would recompile every bucket.
        if not hasattr(model, "_serve_jit_cache"):
            model._serve_jit_cache = {}
        self._steps: Dict = model._serve_jit_cache
        self._copy_fn = None
        self._zero_fn = None
        self._batch_copy_fns: Dict = {}
        self._batch_zero_fns: Dict = {}
        self._xfer_fns: Dict = {}       # cross-runner handoff copies
        self._xfer1_fn = None
        self._mirrors: Dict[str, _SeqMirror] = {}
        self._table_specs = {n: s for n, s in self.specs.items()
                             if s.kind not in ("mamba", "rwkv")}
        self._state_specs = {n: s for n, s in self.specs.items()
                             if s.kind in ("mamba", "rwkv")}
        # dispatch-efficiency counters (padding-waste A/B in benchmarks):
        # real tokens vs. stream/row slots actually dispatched
        self.tokens_dispatched = 0
        self.slots_dispatched = 0
        self.dispatch_count = 0
        # attention-work counters (block-sparse observability): cumulative
        # totals across dispatches; the engine records per-step deltas into
        # StepMetrics. Host-modeled from the packed layout metadata — see
        # _attn_block_stats.
        self.kv_blocks_scanned = 0
        self.kv_blocks_skipped = 0
        self.attn_flops_modeled = 0.0
        self.attn_bytes_modeled = 0.0
        # device->host traffic (fetch/fetch_tokens), for the pipeline A/B
        self.bytes_fetched = 0
        # sampled-token board: persistent device int32 vector the fused
        # sampling tail scatters into and later dispatches read from
        # (see serving.sampler). Slots are per-request (rid-keyed, with a
        # free list) unless the caller passes explicit board_dst/board_src
        # (spec decode chains).
        self._board = jnp.zeros((64,), jnp.int32)
        self._board_slots: Dict[str, int] = {}
        self._board_free: List[int] = []
        self._board_top = 0

    # -------------------------------------------------------------- mirrors
    def _mirror(self, seq: SequenceState) -> _SeqMirror:
        """Sync this sequence's mirror from the manager's deltas: new table
        entries are appended, freed entries patched from ``freed_events``,
        trailing pops clamped from ``trim_events`` (speculative rollback —
        no epoch bump, so the cursors survive), and a stale ``epoch``
        (free/preemption) forces a rebuild."""
        m = self._mirrors.get(seq.rid)
        if m is None or m.epoch != seq.epoch:
            m = _SeqMirror(seq.epoch)
            self._mirrors[seq.rid] = m
        for name, idx in seq.freed_events[m.evt_cursor:]:
            if idx < m.n.get(name, 0):
                m.table[name][idx] = -1
                m.pos[name][idx] = SENTINEL_POS
        m.evt_cursor = len(seq.freed_events)
        for name, new_len in seq.trim_events[m.trim_cursor:]:
            if new_len < m.n.get(name, 0):
                m.n[name] = new_len
        m.trim_cursor = len(seq.trim_events)
        for name, spec in self._table_specs.items():
            entries = seq.page_tables.get(name)
            if not entries:
                continue
            n0 = m.n.get(name, 0)
            if len(entries) <= n0:
                continue
            m._ensure(name, len(entries))
            new = np.fromiter(entries[n0:], np.int32, len(entries) - n0)
            m.table[name][n0:len(entries)] = new
            tpp = spec.tokens_per_page
            m.pos[name][n0:len(entries)] = np.where(
                new == SequenceState.FREED, SENTINEL_POS,
                np.arange(n0, len(entries), dtype=np.int32) * tpp)
            m.n[name] = len(entries)
        return m

    def forget(self, rid: str) -> None:
        """Drop the mirror (and board slot) of a finished request. The
        freed board slot may be handed to a new request immediately:
        device dispatch order guarantees any still-queued write of the
        old owner lands before the new owner's first write."""
        self._mirrors.pop(rid, None)
        slot = self._board_slots.pop(rid, None)
        if slot is not None:
            self._board_free.append(slot)

    # ----------------------------------------------------------- token board
    def board_slot(self, rid: str) -> int:
        """Stable board slot of a request (allocated on first use)."""
        s = self._board_slots.get(rid)
        if s is None:
            if self._board_free:
                s = self._board_free.pop()
            else:
                s = self._board_top
                self._board_top += 1
            self._board_slots[rid] = s
        return s

    def _ensure_board(self, cap: int) -> None:
        cur = int(self._board.shape[0])
        if cap <= cur:
            return
        new_cap = _pow2(cap, 64)
        self._board = jnp.concatenate(
            [self._board, jnp.zeros((new_cap - cur,), jnp.int32)])

    # ------------------------------------------- shared per-item builders
    def _mm_enc_flags(self, items) -> Tuple[bool, bool]:
        """Whether this batch carries mm-embed / encoder fields. Keyed on
        each item's chunk START, not ``req.in_prefill`` — under async
        scheduling ``num_computed`` lags the in-flight step, and a
        speculative first decode built while the final prefill chunk is in
        flight must produce the SAME batch fields (and jit key) as the
        synchronous loop would."""
        cfg = self.model.cfg
        has_mm = cfg.family == "vlm" and any(
            start < len(r.prompt) for r, _, start in items)
        has_enc = cfg.family == "encdec" and any(
            start == 0 for r, _, start in items)
        return has_mm, has_enc

    def _fresh_state_of(self, seq: SequenceState, start: int
                        ) -> List[Tuple[str, int]]:
        """A request's very first chunk must see zero recurrent state; its
        freshly allocated state pages hold whatever bytes last lived in
        those units (prefix-cache restores land at start > 0, so they are
        never clobbered here). Under async scheduling the chunk START, not
        ``num_computed``, decides — a continuation chunk built while the
        first chunk is still in flight must NOT re-zero the state the
        in-flight chunk is writing."""
        if start != 0:
            return []
        return [(name, seq.state_pages[name])
                for name in self._state_specs if name in seq.state_pages]

    def _fill_mm(self, seq, start, t_real, mm_embeds, mm_mask, row, col0):
        """Route this chunk's vision embeddings: destination is
        (row, col0 + p - start) — padded rows pass (bi, 0), the packed
        stream (0, stream_offset)."""
        d_model = self.model.cfg.d_model
        for it in seq.mm_items:
            for off in range(it.length):
                p = it.start + off
                if start <= p < start + t_real:
                    mm_embeds[row, col0 + p - start] = self.stub_embed_fn(
                        it.mm_hash, off, d_model)
                    mm_mask[row, col0 + p - start] = True

    def _fill_encoder(self, seq, mirror, enc_embeds, enc_write, row):
        """First-chunk encdec prefill: stub encoder embeddings + cross-KV
        write targets for one request, into row ``row`` (batch row when
        padded, segment index when packed)."""
        cfg = self.model.cfg
        total_enc = sum(it.length for it in seq.encoder_items)
        off0 = 0
        for it in seq.encoder_items:
            for off in range(it.length):
                enc_embeds[row, off0 + off] = self.stub_embed_fn(
                    it.mm_hash, off, cfg.d_model)
            off0 += it.length
        ctab = mirror.table.get("cross_attn")
        tpp = self.specs["cross_attn"].tokens_per_page
        for j in range(min(total_enc, cfg.encoder_seq)):
            pg = j // tpp
            if ctab is not None and pg < mirror.n.get(
                    "cross_attn", 0) and ctab[pg] >= 0:
                enc_write[0, 0, row, j] = ctab[pg]

    # ---------------------------------------------------- attention stats
    def _attn_block_stats(self, TT: int, seg_ids_row: np.ndarray,
                          page_seg: Dict[str, np.ndarray]) -> dict:
        """Host mirror of the device segment-block-sparse schedule: per-step
        counts of (q block, KV block) tiles scanned vs skipped over the
        OLD-page self-attention streams (full_attn/swa; fresh-part and
        cross-attn work is small by comparison), plus modeled attention
        FLOPs and HBM bytes for the scanned tiles. Mirrors
        ``blocks_attn.sparse_blocks`` sizing — keep the two in sync."""
        from ..models.blocks_attn import sparse_blocks
        cfg = self.model.cfg
        scanned = skipped = 0
        flops = bytes_ = 0.0
        for name, spec in self._table_specs.items():
            if spec.kind not in ("full_attn", "swa"):
                continue
            ps = page_seg[name][0, 0, 0]
            tpp = spec.tokens_per_page
            slot_seg = np.repeat(ps, tpp)
            s = slot_seg.shape[0]
            qb, kb = sparse_blocks(TT, s)
            q_lo, q_hi = _seg_intervals(seg_ids_row, qb)
            k_lo, k_hi = _seg_intervals(slot_seg, kb)
            hits = int(((k_lo[None, :] <= q_hi[:, None])
                        & (k_hi[None, :] >= q_lo[:, None])).sum())
            pairs = q_lo.shape[0] * k_lo.shape[0]
            L = spec.num_layers
            scanned += hits * L
            skipped += (pairs - hits) * L
            # per scanned tile: QK^T + PV matmuls over all query heads...
            flops += hits * L * 4.0 * qb * kb * cfg.head_dim * cfg.num_heads
            # ...and one read of the tile's K+V slots (bf16)
            bytes_ += hits * L * kb * cfg.num_kv_heads * cfg.head_dim * 2 * 2
        return dict(kv_blocks_scanned=scanned, kv_blocks_skipped=skipped,
                    attn_flops_modeled=flops, attn_bytes_modeled=bytes_)

    # ----------------------------------------------------------- batching
    def prepare(self, items, packed: bool = True, sample: bool = False,
                board_feed: bool = False, board_dst: Optional[List[int]] = None,
                board_src: Optional[List[int]] = None) -> PreparedStep:
        """Phase 1: flatten one scheduler step — ``items`` is
        [(request, num_tokens[, start])] with ragged per-sequence token
        counts — into a HOST-side device batch: token-packed stream
        (default) or padded (B, T) rows.

        ``sample=True`` attaches a fused sampling tail (per-segment
        greedy/temperature pick on device, scattered into the token
        board at ``board_dst[si]`` — default: the request's rid slot).
        ``board_feed=True`` converts pending decode rows into on-device
        board reads from ``board_src[si]`` (default: rid slot) instead
        of requiring a host ``patch_token``."""
        items = _norm_items(items)
        if packed:
            arrs, info = self._build_host_packed(items)
        else:
            arrs, info = self._build_host_padded(items)
        prep = PreparedStep(arrs=arrs, info=info, items=items, packed=packed,
                            pending=info.pop("pending"))
        if sample:
            self._attach_sampling(prep, board_dst)
        if board_feed:
            self._attach_board_feed(prep, board_src)
        return prep

    def _attach_sampling(self, prep: PreparedStep,
                         board_dst: Optional[List[int]] = None) -> None:
        """Per-segment sampling metadata for the fused dispatch tail,
        sized to the segment bucket (padded rows sample garbage that is
        never read). The random key per row is (seed, rid_hash,
        position-of-sampled-token) — layout- and batch-independent."""
        S = prep.arrs["seq_lens"].shape[0]
        samp = dict(temps=np.zeros((S,), np.float32),
                    top_ks=np.zeros((S,), np.int32),
                    rhs=np.zeros((S,), np.uint32),
                    poss=np.zeros((S,), np.int32),
                    seeds=np.zeros((S,), np.int32),
                    dst=np.full((S,), -1, np.int32),
                    need_random=False)
        for si, (r, nt, start) in enumerate(prep.items):
            sp = r.sampling
            samp["temps"][si] = max(0.0, sp.temperature)
            samp["top_ks"][si] = max(0, getattr(sp, "top_k", 0))
            samp["rhs"][si] = rid_hash(r.rid)
            samp["poss"][si] = start + nt
            samp["seeds"][si] = sp.seed
            samp["dst"][si] = (board_dst[si] if board_dst is not None
                               else self.board_slot(r.rid))
            if sp.temperature > 0 and start + nt >= len(r.prompt):
                samp["need_random"] = True
        prep.samp = samp

    def _attach_board_feed(self, prep: PreparedStep,
                           board_src: Optional[List[int]] = None) -> None:
        """Convert pending (speculative, token-not-yet-sampled) decode
        rows into on-device board reads: the dispatch that samples their
        input token was issued earlier, so device execution order makes
        the read see the right value with no host round-trip."""
        if not prep.pending:
            return
        tok_src = np.full(prep.arrs["tokens"].shape, -1, np.int32)
        for si in list(prep.pending):
            r, nt, start = prep.items[si]
            assert nt == 1, (si, nt)
            slot = (board_src[si] if board_src is not None
                    else self.board_slot(r.rid))
            if prep.packed:
                off, _ = prep.info["seg_off"][si]
                tok_src[0, off] = slot
            else:
                tok_src[si, 0] = slot
            prep.pending.remove(si)
            prep.board_fed.append(si)
        prep.tok_src = tok_src

    def build_plan(self, items, packed: bool = True
                   ) -> Tuple[DecodeBatch, dict]:
        """Build one plan's device batch (host build + upload). Kept for
        direct layout inspection; the engine drives prepare/dispatch/fetch
        separately."""
        prep = self.prepare(items, packed=packed)
        return self._to_batch(prep.arrs), prep.info

    @staticmethod
    def _to_batch(arrs: Dict[str, object]) -> DecodeBatch:
        def conv(v):
            if v is None:
                return None
            if isinstance(v, dict):
                return {k: jnp.asarray(x) for k, x in v.items()}
            return jnp.asarray(v)

        return DecodeBatch(**{f: conv(v) for f, v in arrs.items()})

    def _build_host_padded(self, items: Sequence[Tuple[Request, int, int]]
                           ) -> Tuple[Dict[str, object], dict]:
        """PR-1 layout: one row per sequence padded to the (B, T) bucket.
        Padded slots get SENTINEL positions (never attended), padded rows
        get -1 exec ids (writes dropped)."""
        n = len(items)
        assert n > 0
        B = _pow2(n)
        T = _pow2(max(nt for _, nt, _ in items))
        mirrors = [self._mirror(r.seq) for r, _, _ in items]
        p_need: Dict[str, int] = {}
        for name in self._table_specs:
            longest = 1
            for m in mirrors:
                longest = max(longest, m.n.get(name, 0))
            p_need[name] = _pow2(longest, 4)
        tokens = np.zeros((B, T), np.int32)
        positions = np.full((B, T), SENTINEL_POS, np.int32)
        seq_lens = np.ones((B,), np.int32)
        last_idx = np.zeros((B,), np.int32)
        tables = {k: np.full((1, 1, B, p), -1, np.int32)
                  for k, p in p_need.items()}
        page_pos = {k: np.full((1, 1, B, p), SENTINEL_POS, np.int32)
                    for k, p in p_need.items()}
        write_eids = {k: np.full((1, 1, B, T), -1, np.int32)
                      for k in p_need}
        state_eids = {s.name: np.full((1, B), -1, np.int32)
                      for s in self._state_specs.values()}
        cfg = self.model.cfg
        has_mm, has_enc = self._mm_enc_flags(items)
        mm_embeds = mm_mask = mrope = None
        enc_embeds = enc_write = enc_lens = None
        if has_mm:
            mm_embeds = np.zeros((B, T, cfg.d_model), np.float32)
            mm_mask = np.zeros((B, T), bool)
        if cfg.family == "encdec":
            enc_lens = np.zeros((B,), np.int32)
            if has_enc:
                enc_embeds = np.zeros((B, cfg.encoder_seq, cfg.d_model),
                                      np.float32)
                enc_write = np.full((1, 1, B, cfg.encoder_seq), -1, np.int32)

        fresh_state: List[Tuple[str, int]] = []
        pending: List[int] = []
        for bi, ((r, t_real, start), m) in enumerate(zip(items, mirrors)):
            seq = r.seq
            fresh_state.extend(self._fresh_state_of(seq, start))
            toks = seq.tokens[start:start + t_real]
            if len(toks) < t_real:      # speculative decode: token patched in
                pending.append(bi)
            tokens[bi, :len(toks)] = toks
            positions[bi, :t_real] = np.arange(start, start + t_real)
            seq_lens[bi] = start + t_real
            last_idx[bi] = t_real - 1
            for name, spec in self._table_specs.items():
                np_ = p_need[name]
                nm = min(m.n.get(name, 0), np_)
                if nm:
                    tables[name][0, 0, bi, :nm] = m.table[name][:nm]
                    page_pos[name][0, 0, bi, :nm] = m.pos[name][:nm]
                if spec.kind in ("full_attn", "swa"):
                    tpp = spec.tokens_per_page
                    pgs = (start + np.arange(t_real)) // tpp
                    write_eids[name][0, 0, bi, :t_real] = \
                        m.table[name][pgs] if m.n.get(name, 0) else -1
            for name in state_eids:
                if name in seq.state_pages:
                    state_eids[name][0, bi] = seq.state_pages[name]
            if has_mm and self.stub_embed_fn:
                self._fill_mm(seq, start, t_real, mm_embeds, mm_mask, bi, 0)
            if cfg.family == "encdec":
                enc_lens[bi] = sum(it.length for it in seq.encoder_items)
                if has_enc and start == 0 and r.in_prefill \
                        and self.stub_embed_fn:
                    self._fill_encoder(seq, m, enc_embeds, enc_write, bi)
        if has_mm:
            mrope = np.broadcast_to(positions[None], (3, B, T)).copy()

        arrs = dict(
            tokens=tokens, positions=positions, seq_lens=seq_lens,
            tables=tables, page_pos=page_pos, write_eids=write_eids,
            state_eids=state_eids, mm_embeds=mm_embeds, mm_mask=mm_mask,
            mrope_pos=mrope, last_idx=last_idx, enc_embeds=enc_embeds,
            enc_write_eids=enc_write, enc_lens=enc_lens,
            seg_ids=None, chunk_start=None, seg_start_tok=None,
            seg_last_tok=None, page_seg=None)
        # T==1 buckets take the cheap materialized decode path; any larger
        # bucket (or an encoder run) uses the chunked prefill path. Both are
        # exact for every row thanks to position-based masking.
        prefill = T > 1 or has_enc
        key = (prefill, B, T, tuple(sorted(p_need.items())), has_mm, has_enc)
        return arrs, {"key": key, "n": n, "prefill": prefill,
                      "fresh_state": fresh_state, "pending": pending,
                      "tokens": sum(nt for _, nt, _ in items),
                      "slots": B * T}

    def _build_host_packed(self, items: Sequence[Tuple[Request, int, int]]
                           ) -> Tuple[Dict[str, object], dict]:
        """Token-packed layout: flatten the whole step into ONE
        ``(TT,)`` token stream (TT = ``_tok_bucket(total_tokens)``) with
        per-token segment ids / positions / chunk starts / KV write
        targets, per-segment ``(start, last_tok)`` row metadata, and ONE
        flat page stream per KV type tagged with per-page owning segments.
        Pad tokens carry segment id -1 and SENTINEL positions; pad pages
        carry segment id -2 — pads never match anything."""
        n = len(items)
        assert n > 0
        total = sum(nt for _, nt, _ in items)
        TT = _tok_bucket(total)
        S = _pow2(n)                                  # segment bucket
        mirrors = [self._mirror(r.seq) for r, _, _ in items]
        p_need: Dict[str, int] = {}                   # flat page-stream cap
        for name in self._table_specs:
            p_need[name] = _pow2(
                max(1, sum(m.n.get(name, 0) for m in mirrors)), 4)
        tokens = np.zeros((1, TT), np.int32)
        positions = np.full((1, TT), SENTINEL_POS, np.int32)
        seg_ids = np.full((1, TT), -1, np.int32)
        chunk_start = np.full((1, TT), SENTINEL_POS, np.int32)
        seg_start_tok = np.zeros((1, TT), np.int32)
        seg_last_tok = np.zeros((S,), np.int32)
        seq_lens = np.ones((S,), np.int32)
        tables = {k: np.full((1, 1, 1, p), -1, np.int32)
                  for k, p in p_need.items()}
        page_pos = {k: np.full((1, 1, 1, p), SENTINEL_POS, np.int32)
                    for k, p in p_need.items()}
        page_seg = {k: np.full((1, 1, 1, p), -2, np.int32)
                    for k, p in p_need.items()}
        write_eids = {k: np.full((1, 1, 1, TT), -1, np.int32)
                      for k in p_need}
        state_eids = {s.name: np.full((1, S), -1, np.int32)
                      for s in self._state_specs.values()}
        cfg = self.model.cfg
        has_mm, has_enc = self._mm_enc_flags(items)
        mm_embeds = mm_mask = mrope = None
        enc_embeds = enc_write = enc_lens = None
        if has_mm:
            mm_embeds = np.zeros((1, TT, cfg.d_model), np.float32)
            mm_mask = np.zeros((1, TT), bool)
        if cfg.family == "encdec":
            enc_lens = np.zeros((1, TT), np.int32)    # per TOKEN when packed
            if has_enc:
                enc_embeds = np.zeros((S, cfg.encoder_seq, cfg.d_model),
                                      np.float32)
                enc_write = np.full((1, 1, S, cfg.encoder_seq), -1, np.int32)

        fresh_state: List[Tuple[str, int]] = []
        pending: List[int] = []
        seg_off: List[Tuple[int, int]] = []
        page_cursor = {name: 0 for name in p_need}
        off = 0
        for si, ((r, t_real, start), m) in enumerate(zip(items, mirrors)):
            seq = r.seq
            fresh_state.extend(self._fresh_state_of(seq, start))
            seg_off.append((off, t_real))
            toks = seq.tokens[start:start + t_real]
            if len(toks) < t_real:      # speculative decode: token patched in
                pending.append(si)
            tokens[0, off:off + len(toks)] = toks
            positions[0, off:off + t_real] = np.arange(start, start + t_real)
            seg_ids[0, off:off + t_real] = si
            chunk_start[0, off:off + t_real] = start
            seg_start_tok[0, off:off + t_real] = off
            seg_last_tok[si] = off + t_real - 1
            seq_lens[si] = start + t_real
            for name, spec in self._table_specs.items():
                nm = m.n.get(name, 0)
                pc = page_cursor[name]
                if nm:
                    tables[name][0, 0, 0, pc:pc + nm] = m.table[name][:nm]
                    page_pos[name][0, 0, 0, pc:pc + nm] = m.pos[name][:nm]
                    page_seg[name][0, 0, 0, pc:pc + nm] = si
                    page_cursor[name] = pc + nm
                if spec.kind in ("full_attn", "swa"):
                    tpp = spec.tokens_per_page
                    pgs = (start + np.arange(t_real)) // tpp
                    write_eids[name][0, 0, 0, off:off + t_real] = \
                        m.table[name][pgs] if nm else -1
            for name in state_eids:
                if name in seq.state_pages:
                    state_eids[name][0, si] = seq.state_pages[name]
            if has_mm and self.stub_embed_fn:
                self._fill_mm(seq, start, t_real, mm_embeds, mm_mask, 0, off)
            if cfg.family == "encdec":
                enc_lens[0, off:off + t_real] = \
                    sum(it.length for it in seq.encoder_items)
                if has_enc and start == 0 and r.in_prefill \
                        and self.stub_embed_fn:
                    self._fill_encoder(seq, m, enc_embeds, enc_write, si)
            off += t_real
        if has_mm:
            mrope = np.broadcast_to(positions[None], (3, 1, TT)).copy()

        arrs = dict(
            tokens=tokens, positions=positions, seq_lens=seq_lens,
            tables=tables, page_pos=page_pos, write_eids=write_eids,
            state_eids=state_eids, mm_embeds=mm_embeds, mm_mask=mm_mask,
            mrope_pos=mrope, last_idx=None, enc_embeds=enc_embeds,
            enc_write_eids=enc_write, enc_lens=enc_lens,
            seg_ids=seg_ids, chunk_start=chunk_start,
            seg_start_tok=seg_start_tok, seg_last_tok=seg_last_tok,
            page_seg=page_seg)
        key = ("packed", S, TT, tuple(sorted(p_need.items())),
               has_mm, has_enc)
        return arrs, {"key": key, "n": n, "prefill": True,
                      "fresh_state": fresh_state, "pending": pending,
                      "seg_off": seg_off, "tokens": total, "slots": TT,
                      "attn_work": self._attn_block_stats(
                          TT, seg_ids[0], page_seg)}

    # ----------------------------------------------------------------- run
    def dispatch(self, params, prep: PreparedStep):
        """Phase 2: upload the prepared batch, zero freshly allocated pages,
        and issue the jitted ``serve_step``. Returns the device logits
        handle WITHOUT blocking (JAX async dispatch) — the device computes
        while the host schedules and builds the next plan."""
        info = prep.info
        assert not prep.pending, \
            f"segments {prep.pending} still await their decode token"
        san = self.mgr.sanitizer
        if san is not None:
            # gather-from-freed: every page this step reads or writes must
            # be live RIGHT NOW (killed segments are masked out via
            # page_seg/-1 sentinels and excluded from the check)
            san.check_dispatch(prep.arrs)
        # killed segments' tokens are pads now — count their slots as paid
        # (slots) but not as useful work (tokens): they ARE dispatch waste
        dead_tokens = sum(prep.items[si][1] for si in prep.dead)
        self.tokens_dispatched += info["tokens"] - dead_tokens
        self.slots_dispatched += info["slots"]
        self.dispatch_count += 1
        aw = info.get("attn_work")
        if aw is not None:
            self.kv_blocks_scanned += aw["kv_blocks_scanned"]
            self.kv_blocks_skipped += aw["kv_blocks_skipped"]
            self.attn_flops_modeled += aw["attn_flops_modeled"]
            self.attn_bytes_modeled += aw["attn_bytes_modeled"]
        self.zero_pages(self.mgr.drain_fresh_pages())
        for name, eid in info["fresh_state"]:
            self.zero_page(name, eid)
        key = info["key"] + (self.attention_impl,)
        fn = self._steps.get(key)
        if fn is None:
            fn = jax.jit(partial(self.model.serve_step,
                                 prefill=info["prefill"],
                                 attention_impl=self.attention_impl),
                         donate_argnums=(1,))
            self._steps[key] = fn
        batch = self._to_batch(prep.arrs)
        if prep.tok_src is not None and prep.board_fed:
            # feed still-in-flight decode tokens from the board, on device
            batch = dataclasses.replace(
                batch, tokens=inject_tokens(batch.tokens,
                                            jnp.asarray(prep.tok_src),
                                            self._board))
        logits, self.buffer = fn(params, self.buffer, batch)
        tokens_h = None
        if prep.samp is not None:
            sm = prep.samp
            self._ensure_board(int(sm["dst"].max(initial=-1)) + 1)
            sfn = get_sample_fn(sm["need_random"])
            tokens_h, self._board = sfn(
                logits, self._board, jnp.asarray(sm["dst"]),
                jnp.asarray(sm["temps"]), jnp.asarray(sm["top_ks"]),
                jnp.asarray(sm["rhs"]), jnp.asarray(sm["poss"]),
                jnp.asarray(sm["seeds"]))
        return StepHandle(logits=logits, tokens=tokens_h, n=info["n"])

    def fetch(self, handle, n: int) -> np.ndarray:
        """Phase 3: block on a dispatched step's logits; one row per
        segment, in plan order."""
        h = handle.logits if isinstance(handle, StepHandle) else handle
        # jengalint: allow[host-sync] fetch phase: this IS the intended blocking point
        out = np.asarray(h[:n], np.float32)
        self.bytes_fetched += out.nbytes
        return out

    def fetch_tokens(self, handle: StepHandle,
                     n: Optional[int] = None) -> np.ndarray:
        """Block on a dispatched step's device-sampled tokens: 4 bytes
        per segment instead of the full vocab row."""
        assert handle.tokens is not None, "dispatch had no sampling tail"
        n = handle.n if n is None else n
        # jengalint: allow[host-sync] fetch phase: 4-byte/segment token fetch is the design
        out = np.asarray(handle.tokens[:n], np.int32)
        self.bytes_fetched += out.nbytes
        return out

    def run_plan(self, params, items, packed: bool = True) -> np.ndarray:
        """Execute one mixed step plan in a single jitted dispatch
        (prepare + dispatch + fetch back to back — the synchronous path).
        Returns last-token logits, one row per item, in plan order."""
        prep = self.prepare(items, packed=packed)
        return self.fetch(self.dispatch(params, prep), prep.n)

    # ------------------------------------------------------------- copies
    def apply_copies(self, ops: Sequence[StateCopyOp]) -> None:
        """Execute all StateCopyOps of one step phase. Ops are grouped by KV
        type and each group runs as ONE device dispatch (gather over src
        exec ids, scatter over dst exec ids) instead of one jit call per op.
        Within a phase all sources are read before any destination is
        written, which matches sequential execution because a phase never
        copies out of a page it also copies into."""
        if not ops:
            return
        by_type: Dict[str, List[StateCopyOp]] = {}
        for op in ops:
            by_type.setdefault(op.type_name, []).append(op)
        total = self.buffer.shape[-1]
        for name, group in by_type.items():
            size = self.specs[name].page_units
            if total % size:            # misaligned pool: per-op fallback
                for op in group:
                    self.copy_page(name, op.src_page, op.dst_page)
                continue
            cap = _pow2(len(group))
            srcs = np.zeros((cap,), np.int32)
            dsts = np.full((cap,), total // size, np.int32)   # pad -> OOB drop
            for i, op in enumerate(group):
                srcs[i] = op.src_page
                dsts[i] = op.dst_page
            fn = self._batch_copy_fns.get((size, cap))
            if fn is None:
                def cp(buf, srcs, dsts, size_s):
                    rows = buf.reshape(-1, size_s)
                    blk = jnp.take(rows, srcs, axis=0)
                    rows = rows.at[dsts].set(blk, mode="drop",
                                             unique_indices=False)
                    return rows.reshape(buf.shape)
                fn = jax.jit(cp, static_argnums=(3,), donate_argnums=(0,))
                self._batch_copy_fns[(size, cap)] = fn
            self.buffer = fn(self.buffer, jnp.asarray(srcs),
                             jnp.asarray(dsts), size)

    def zero_pages(self, pages: Sequence[Tuple[str, int]]) -> None:
        """Zero freshly allocated pages (one batched dispatch per type):
        recycled large pages carry other types' stale bytes, which can
        decode as NaN when gathered as K/V — and NaN survives even fully
        masked softmax accumulation."""
        if not pages:
            return
        by_type: Dict[str, List[int]] = {}
        for name, eid in pages:
            by_type.setdefault(name, []).append(eid)
        total = self.buffer.shape[-1]
        for name, eids in by_type.items():
            # manager spec table, not self.specs: with several models
            # sharing one pool (spec decode) a drain can surface pages of
            # types this runner's model does not own
            size = self.mgr.spec(name).page_units
            if total % size:
                for eid in eids:
                    self.zero_page(name, eid)
                continue
            cap = _pow2(len(eids))
            dsts = np.full((cap,), total // size, np.int32)  # pad: OOB drop
            dsts[:len(eids)] = eids
            fn = self._batch_zero_fns.get((size, cap))
            if fn is None:
                def z(buf, dsts, size_s, cap_s):
                    rows = buf.reshape(-1, size_s)
                    zero = jnp.zeros((cap_s, size_s), buf.dtype)
                    rows = rows.at[dsts].set(zero, mode="drop",
                                             unique_indices=False)
                    return rows.reshape(buf.shape)
                fn = jax.jit(z, static_argnums=(2, 3), donate_argnums=(0,))
                self._batch_zero_fns[(size, cap)] = fn
            self.buffer = fn(self.buffer, jnp.asarray(dsts), size, cap)

    def zero_page(self, type_name: str, eid: int) -> None:
        """Zero one small page (fresh recurrent-state initialisation)."""
        size = self.specs[type_name].page_units
        if self._zero_fn is None:
            def z(buf, off, size_s):
                flat = buf.reshape(-1)
                flat = jax.lax.dynamic_update_slice(
                    flat, jnp.zeros((size_s,), flat.dtype), (off,))
                return flat.reshape(buf.shape)
            self._zero_fn = jax.jit(z, static_argnums=(2,),
                                    donate_argnums=(0,))
        self.buffer = self._zero_fn(self.buffer, jnp.int32(eid * size), size)

    def adopt_pages(self, src_runner: "ModelRunner",
                    pairs: Sequence[Tuple[str, int, int]]) -> None:
        """Prefill->decode handoff copy stream: install exported pages from
        ANOTHER runner's unified buffer into this one, one batched
        gather/scatter dispatch per KV type. The source buffer is captured
        as a plain jit input — JAX arrays are immutable, so later
        source-side dispatches rebind new arrays and cannot race this read
        — and only the DESTINATION buffer is donated. Adopted pages are
        deliberately kept out of the fresh-page zeroing queue: they carry
        transferred content a later zeroing pass would destroy."""
        if not pairs:
            return
        by_type: Dict[str, List[Tuple[int, int]]] = {}
        for name, src, dst in pairs:
            by_type.setdefault(name, []).append((src, dst))
        s_total = src_runner.buffer.shape[-1]
        d_total = self.buffer.shape[-1]
        for name, group in by_type.items():
            size = self.mgr.spec(name).page_units
            if s_total % size or d_total % size:
                for src, dst in group:   # misaligned pool: per-op fallback
                    self._adopt_one(src_runner, name, src, dst)
                continue
            cap = _pow2(len(group))
            srcs = np.zeros((cap,), np.int32)
            dsts = np.full((cap,), d_total // size, np.int32)  # pad: OOB drop
            for i, (src, dst) in enumerate(group):
                srcs[i] = src
                dsts[i] = dst
            fn = self._xfer_fns.get((size, cap))
            if fn is None:
                def xf(dst_buf, src_buf, srcs, dsts, size_s):
                    blk = jnp.take(src_buf.reshape(-1, size_s), srcs, axis=0)
                    rows = dst_buf.reshape(-1, size_s)
                    rows = rows.at[dsts].set(blk, mode="drop",
                                             unique_indices=False)
                    return rows.reshape(dst_buf.shape)
                fn = jax.jit(xf, static_argnums=(4,), donate_argnums=(0,))
                self._xfer_fns[(size, cap)] = fn
            self.buffer = fn(self.buffer, src_runner.buffer,
                             jnp.asarray(srcs), jnp.asarray(dsts), size)

    def _adopt_one(self, src_runner: "ModelRunner", type_name: str,
                   src: int, dst: int) -> None:
        """Misaligned-pool fallback: one cross-buffer page copy."""
        size = self.mgr.spec(type_name).page_units
        if self._xfer1_fn is None:
            def xf1(dst_buf, src_buf, off_src, off_dst, size_s):
                blk = jax.lax.dynamic_slice(
                    src_buf.reshape(-1), (off_src,), (size_s,))
                flat = jax.lax.dynamic_update_slice(
                    dst_buf.reshape(-1), blk, (off_dst,))
                return flat.reshape(dst_buf.shape)
            self._xfer1_fn = jax.jit(xf1, static_argnums=(4,),
                                     donate_argnums=(0,))
        self.buffer = self._xfer1_fn(
            self.buffer, src_runner.buffer,
            jnp.int32(src * size), jnp.int32(dst * size), size)

    def copy_page(self, type_name: str, src: int, dst: int) -> None:
        """Device copy of one whole small page (state checkpoint/restore)."""
        spec = self.specs[type_name]
        size = spec.page_units
        if self._copy_fn is None:
            def cp(buf, off_src, off_dst, size_s):
                flat = buf.reshape(-1)
                blk = jax.lax.dynamic_slice(flat, (off_src,), (size_s,))
                flat = jax.lax.dynamic_update_slice(flat, blk, (off_dst,))
                return flat.reshape(buf.shape)
            self._copy_fn = jax.jit(cp, static_argnums=(3,),
                                    donate_argnums=(0,))
        self.buffer = self._copy_fn(
            self.buffer, jnp.int32(src * size), jnp.int32(dst * size), size)
