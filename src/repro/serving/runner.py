"""ModelRunner: builds padded device batches from Jenga manager state and
runs bucketed jitted serve steps (no retrace across allocator changes —
exec page ids are plain i32 data, the paper's §4.2 property)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.manager import JengaKVCacheManager
from ..core.request import SequenceState
from ..core.spec import lcm as _lcm
from ..models.lm import DecodeBatch
from .request import Request

SENTINEL_POS = np.int32(1 << 29)


def _pow2(n: int, lo: int = 1) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


class ModelRunner:
    def __init__(self, model, manager: JengaKVCacheManager,
                 stub_embed_fn=None):
        self.model = model
        self.mgr = manager
        self.specs = {s.name: s for s in model.kv_specs()}
        self.stub_embed_fn = stub_embed_fn
        big = _lcm([s.page_units for s in self.specs.values()])
        units = manager.geometry.total_units + big   # + scratch page
        self.buffer = jnp.zeros((1, 1, units), jnp.bfloat16)
        self._steps: Dict = {}
        self._copy_fn = None

    # ----------------------------------------------------------- batching
    def _attn_table(self, seq: SequenceState, name: str, p_max: int):
        spec = self.specs[name]
        tpp = spec.tokens_per_page
        table = np.full((p_max,), -1, np.int32)
        pos = np.full((p_max,), SENTINEL_POS, np.int32)
        entries = seq.page_tables.get(name, [])
        for i, e in enumerate(entries[:p_max]):
            if e != SequenceState.FREED:
                table[i] = e
                pos[i] = i * tpp
        return table, pos

    def _mm_table(self, seq: SequenceState, name: str, p_max: int):
        table = np.full((p_max,), -1, np.int32)
        pos = np.full((p_max,), SENTINEL_POS, np.int32)
        spec = self.specs[name]
        entries = seq.page_tables.get(name, [])
        for i, e in enumerate(entries[:p_max]):
            if e != SequenceState.FREED:
                table[i] = e
                pos[i] = i * spec.tokens_per_page
        return table, pos

    def build_batch(self, reqs: List[Request], *, prefill: bool,
                    chunk: int = 0) -> Tuple[DecodeBatch, dict]:
        """Pad to bucketed shapes; returns (batch, bucket_info)."""
        mgr, specs = self.mgr, self.specs
        n = len(reqs)
        B = _pow2(n)
        T = _pow2(chunk) if prefill else 1
        p_need: Dict[str, int] = {}
        for name, s in specs.items():
            if s.kind in ("mamba", "rwkv"):
                continue
            longest = 1
            for r in reqs:
                longest = max(longest, len(r.seq.page_tables.get(name, [])))
            p_need[name] = _pow2(longest, 4)
        tokens = np.zeros((B, T), np.int32)
        positions = np.zeros((B, T), np.int32)
        seq_lens = np.ones((B,), np.int32)
        last_idx = np.zeros((B,), np.int32)
        tables = {k: np.full((1, 1, B, p), -1, np.int32)
                  for k, p in p_need.items()}
        page_pos = {k: np.full((1, 1, B, p), SENTINEL_POS, np.int32)
                    for k, p in p_need.items()}
        write_eids = {k: np.full((1, 1, B, T), -1, np.int32)
                      for k in p_need}
        state_eids = {s.name: np.full((1, B), -1, np.int32)
                      for s in specs.values() if s.kind in ("mamba", "rwkv")}
        mm_embeds = mm_mask = mrope = None
        enc_embeds = enc_write = enc_lens = None
        cfg = self.model.cfg
        if cfg.family == "vlm" and prefill:
            mm_embeds = np.zeros((B, T, cfg.d_model), np.float32)
            mm_mask = np.zeros((B, T), bool)
            mrope = np.zeros((3, B, T), np.int32)
        if cfg.family == "encdec":
            enc_lens = np.zeros((B,), np.int32)
            if prefill:
                enc_embeds = np.zeros((B, cfg.encoder_seq, cfg.d_model),
                                      np.float32)
                enc_write = np.full((1, 1, B, cfg.encoder_seq), -1, np.int32)

        for bi, r in enumerate(reqs):
            seq = r.seq
            start = seq.num_computed
            t_real = chunk if prefill else 1
            toks = seq.tokens[start:start + t_real]
            tokens[bi, :len(toks)] = toks
            positions[bi, :t_real] = np.arange(start, start + t_real)
            positions[bi, t_real:] = 0
            seq_lens[bi] = start + t_real
            last_idx[bi] = t_real - 1
            for name in p_need:
                spec = specs[name]
                if spec.kind in ("full_attn", "swa"):
                    tb, pp = self._attn_table(seq, name, p_need[name])
                    tables[name][0, 0, bi] = tb
                    page_pos[name][0, 0, bi] = pp
                    tpp = spec.tokens_per_page
                    for j in range(t_real):
                        pg = (start + j) // tpp
                        write_eids[name][0, 0, bi, j] = tb[pg]
                else:  # mm kinds
                    tb, pp = self._mm_table(seq, name, p_need[name])
                    tables[name][0, 0, bi] = tb
                    page_pos[name][0, 0, bi] = pp
            for name in state_eids:
                if name in seq.state_pages:
                    state_eids[name][0, bi] = seq.state_pages[name]
            if cfg.family == "vlm" and prefill and self.stub_embed_fn:
                for it in seq.mm_items:
                    for off in range(it.length):
                        p = it.start + off
                        if start <= p < start + t_real:
                            mm_embeds[bi, p - start] = self.stub_embed_fn(
                                it.mm_hash, off, cfg.d_model)
                            mm_mask[bi, p - start] = True
                mrope[:, bi] = positions[bi][None]
            if cfg.family == "encdec":
                total_enc = sum(it.length for it in seq.encoder_items)
                enc_lens[bi] = total_enc
                if prefill and start == 0 and self.stub_embed_fn:
                    off0 = 0
                    for it in seq.encoder_items:
                        for off in range(it.length):
                            enc_embeds[bi, off0 + off] = self.stub_embed_fn(
                                it.mm_hash, off, cfg.d_model)
                        off0 += it.length
                    ctab = seq.page_tables.get("cross_attn", [])
                    tpp = specs["cross_attn"].tokens_per_page
                    for j in range(min(total_enc, cfg.encoder_seq)):
                        pg = j // tpp
                        if pg < len(ctab) and ctab[pg] >= 0:
                            enc_write[0, 0, bi, j] = ctab[pg]

        batch = DecodeBatch(
            tokens=jnp.asarray(tokens), positions=jnp.asarray(positions),
            seq_lens=jnp.asarray(seq_lens),
            tables={k: jnp.asarray(v) for k, v in tables.items()},
            page_pos={k: jnp.asarray(v) for k, v in page_pos.items()},
            write_eids={k: jnp.asarray(v) for k, v in write_eids.items()},
            state_eids={k: jnp.asarray(v) for k, v in state_eids.items()},
            mm_embeds=None if mm_embeds is None else jnp.asarray(mm_embeds),
            mm_mask=None if mm_mask is None else jnp.asarray(mm_mask),
            mrope_pos=None if mrope is None else jnp.asarray(mrope),
            last_idx=jnp.asarray(last_idx) if prefill else None,
            enc_embeds=None if enc_embeds is None else jnp.asarray(enc_embeds),
            enc_write_eids=None if enc_write is None else jnp.asarray(enc_write),
            enc_lens=None if enc_lens is None else jnp.asarray(enc_lens),
        )
        key = (prefill, B, T, tuple(sorted(p_need.items())),
               mm_embeds is not None, enc_embeds is not None)
        return batch, {"key": key, "n": n}

    # ----------------------------------------------------------------- run
    def run(self, params, reqs: List[Request], *, prefill: bool,
            chunk: int = 0) -> np.ndarray:
        batch, info = self.build_batch(reqs, prefill=prefill, chunk=chunk)
        key = info["key"]
        fn = self._steps.get(key)
        if fn is None:
            fn = jax.jit(partial(self.model.serve_step, prefill=prefill),
                         donate_argnums=(1,))
            self._steps[key] = fn
        logits, self.buffer = fn(params, self.buffer, batch)
        return np.asarray(logits[:info["n"]], np.float32)

    # ------------------------------------------------------------- copies
    def copy_page(self, type_name: str, src: int, dst: int) -> None:
        """Device copy of one whole small page (state checkpoint/restore)."""
        spec = self.specs[type_name]
        size = spec.page_units
        if self._copy_fn is None:
            def cp(buf, off_src, off_dst, size_s):
                flat = buf.reshape(-1)
                blk = jax.lax.dynamic_slice(flat, (off_src,), (size_s,))
                flat = jax.lax.dynamic_update_slice(flat, blk, (off_dst,))
                return flat.reshape(buf.shape)
            self._copy_fn = jax.jit(cp, static_argnums=(3,),
                                    donate_argnums=(0,))
        self.buffer = self._copy_fn(
            self.buffer, jnp.int32(src * size), jnp.int32(dst * size), size)
