"""ModelRunner: builds device batches from Jenga manager state and runs
bucketed jitted serve steps (no retrace across allocator changes — exec
page ids are plain i32 data, the paper's §4.2 property).

One ``run_plan`` call executes a whole scheduler step — any number of
concurrent prefill chunks plus all decodes — as a single dispatch, in one
of two layouts:

* PACKED (default, vLLM-style): the step is flattened into ONE
  ``(total_tokens_bucket,)`` token stream with per-token ``segment_ids``,
  absolute ``positions``, per-token KV write targets, and per-segment
  ``(start, last_tok)`` metadata; per-type page tables are likewise
  flattened into one page stream with per-page owning segments. Per-step
  FLOPs in the dense layers are proportional to the scheduler's token
  budget — a decode row co-scheduled with a 512-token prefill chunk no
  longer pays 512 tokens of padding. Token buckets are pow2 up to 16 then
  multiples of 16 (see ``_tok_bucket``), so jit retraces stay bounded while
  stream padding waste stays under ~10% on decode-heavy mixed steps.

* PADDED (the PR-1 layout, kept for A/Bs): one row per sequence, padded to
  the ``(B=_pow2(n), T=_pow2(max_chunk))`` bucket with SENTINEL positions
  at pads — per-step FLOPs scale with B*T, not with the token budget.

Host-side cost model: per-request block tables are kept as persistent
numpy mirrors updated incrementally from the manager's append/free deltas
(``SequenceState.freed_events`` + table length), instead of re-walking
O(pages) python lists per request per step. All ``StateCopyOp``s of a step
phase execute as one batched gather/scatter dispatch per KV type instead of
one jit call per op.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.manager import JengaKVCacheManager, StateCopyOp
from ..core.request import SequenceState
from ..core.spec import lcm as _lcm
from ..models.lm import DecodeBatch
from .request import Request

SENTINEL_POS = np.int32(1 << 29)


def _pow2(n: int, lo: int = 1) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


def _tok_bucket(n: int) -> int:
    """Packed-stream token bucket: pow2 below 16 (decode-only steps hit
    exact small buckets), then multiples of 16 — bounded retraces with
    <= 15 pad slots per dispatch instead of pow2's up-to-50% waste."""
    if n <= 16:
        return _pow2(n)
    return 16 * (-(-n // 16))


class _SeqMirror:
    """Persistent per-request device-batch state: block-table + slot-position
    arrays per KV type, grown geometrically and patched from manager deltas."""

    __slots__ = ("epoch", "evt_cursor", "table", "pos", "n")

    def __init__(self, epoch: int):
        self.epoch = epoch
        self.evt_cursor = 0
        self.table: Dict[str, np.ndarray] = {}
        self.pos: Dict[str, np.ndarray] = {}
        self.n: Dict[str, int] = {}

    def _ensure(self, name: str, cap: int) -> None:
        cur = self.table.get(name)
        if cur is not None and cur.shape[0] >= cap:
            return
        new_cap = _pow2(cap, 8)
        table = np.full((new_cap,), -1, np.int32)
        pos = np.full((new_cap,), SENTINEL_POS, np.int32)
        if cur is not None:
            table[: cur.shape[0]] = cur
            pos[: cur.shape[0]] = self.pos[name]
        self.table[name] = table
        self.pos[name] = pos


class ModelRunner:
    def __init__(self, model, manager: JengaKVCacheManager,
                 stub_embed_fn=None):
        self.model = model
        self.mgr = manager
        self.specs = {s.name: s for s in model.kv_specs()}
        self.stub_embed_fn = stub_embed_fn
        big = _lcm([s.page_units for s in self.specs.values()])
        units = manager.geometry.total_units + big   # + scratch page
        self.buffer = jnp.zeros((1, 1, units), jnp.bfloat16)
        self._steps: Dict = {}
        self._copy_fn = None
        self._zero_fn = None
        self._batch_copy_fns: Dict = {}
        self._batch_zero_fns: Dict = {}
        self._mirrors: Dict[str, _SeqMirror] = {}
        self._table_specs = {n: s for n, s in self.specs.items()
                             if s.kind not in ("mamba", "rwkv")}
        self._state_specs = {n: s for n, s in self.specs.items()
                             if s.kind in ("mamba", "rwkv")}
        # dispatch-efficiency counters (padding-waste A/B in benchmarks):
        # real tokens vs. stream/row slots actually dispatched
        self.tokens_dispatched = 0
        self.slots_dispatched = 0
        self.dispatch_count = 0

    # -------------------------------------------------------------- mirrors
    def _mirror(self, seq: SequenceState) -> _SeqMirror:
        """Sync this sequence's mirror from the manager's deltas: new table
        entries are appended, freed entries patched from ``freed_events``,
        and a stale ``epoch`` (free/preemption) forces a rebuild."""
        m = self._mirrors.get(seq.rid)
        if m is None or m.epoch != seq.epoch:
            m = _SeqMirror(seq.epoch)
            self._mirrors[seq.rid] = m
        for name, idx in seq.freed_events[m.evt_cursor:]:
            if idx < m.n.get(name, 0):
                m.table[name][idx] = -1
                m.pos[name][idx] = SENTINEL_POS
        m.evt_cursor = len(seq.freed_events)
        for name, spec in self._table_specs.items():
            entries = seq.page_tables.get(name)
            if not entries:
                continue
            n0 = m.n.get(name, 0)
            if len(entries) <= n0:
                continue
            m._ensure(name, len(entries))
            new = np.fromiter(entries[n0:], np.int32, len(entries) - n0)
            m.table[name][n0:len(entries)] = new
            tpp = spec.tokens_per_page
            m.pos[name][n0:len(entries)] = np.where(
                new == SequenceState.FREED, SENTINEL_POS,
                np.arange(n0, len(entries), dtype=np.int32) * tpp)
            m.n[name] = len(entries)
        return m

    def forget(self, rid: str) -> None:
        """Drop the mirror of a finished request."""
        self._mirrors.pop(rid, None)

    # ------------------------------------------- shared per-item builders
    def _mm_enc_flags(self, items) -> Tuple[bool, bool]:
        cfg = self.model.cfg
        has_mm = cfg.family == "vlm" and any(
            r.in_prefill for r, _ in items)
        has_enc = cfg.family == "encdec" and any(
            r.in_prefill and r.seq.num_computed == 0 for r, _ in items)
        return has_mm, has_enc

    def _fresh_state_of(self, seq: SequenceState) -> List[Tuple[str, int]]:
        """A request's very first chunk must see zero recurrent state; its
        freshly allocated state pages hold whatever bytes last lived in
        those units (prefix-cache restores land at start > 0, so they are
        never clobbered here)."""
        if seq.num_computed != 0:
            return []
        return [(name, seq.state_pages[name])
                for name in self._state_specs if name in seq.state_pages]

    def _fill_mm(self, seq, start, t_real, mm_embeds, mm_mask, row, col0):
        """Route this chunk's vision embeddings: destination is
        (row, col0 + p - start) — padded rows pass (bi, 0), the packed
        stream (0, stream_offset)."""
        d_model = self.model.cfg.d_model
        for it in seq.mm_items:
            for off in range(it.length):
                p = it.start + off
                if start <= p < start + t_real:
                    mm_embeds[row, col0 + p - start] = self.stub_embed_fn(
                        it.mm_hash, off, d_model)
                    mm_mask[row, col0 + p - start] = True

    def _fill_encoder(self, seq, mirror, enc_embeds, enc_write, row):
        """First-chunk encdec prefill: stub encoder embeddings + cross-KV
        write targets for one request, into row ``row`` (batch row when
        padded, segment index when packed)."""
        cfg = self.model.cfg
        total_enc = sum(it.length for it in seq.encoder_items)
        off0 = 0
        for it in seq.encoder_items:
            for off in range(it.length):
                enc_embeds[row, off0 + off] = self.stub_embed_fn(
                    it.mm_hash, off, cfg.d_model)
            off0 += it.length
        ctab = mirror.table.get("cross_attn")
        tpp = self.specs["cross_attn"].tokens_per_page
        for j in range(min(total_enc, cfg.encoder_seq)):
            pg = j // tpp
            if ctab is not None and pg < mirror.n.get(
                    "cross_attn", 0) and ctab[pg] >= 0:
                enc_write[0, 0, row, j] = ctab[pg]

    # ----------------------------------------------------------- batching
    def build_plan(self, items: Sequence[Tuple[Request, int]],
                   packed: bool = True) -> Tuple[DecodeBatch, dict]:
        """Flatten one scheduler step — ``items`` is [(request, num_tokens)]
        with ragged per-sequence token counts — into a device batch:
        token-packed stream (default) or padded (B, T) rows.
        Returns (batch, info)."""
        if packed:
            return self._build_plan_packed(items)
        return self._build_plan_padded(items)

    def _build_plan_padded(self, items: Sequence[Tuple[Request, int]]
                           ) -> Tuple[DecodeBatch, dict]:
        """PR-1 layout: one row per sequence padded to the (B, T) bucket.
        Padded slots get SENTINEL positions (never attended), padded rows
        get -1 exec ids (writes dropped)."""
        n = len(items)
        assert n > 0
        B = _pow2(n)
        T = _pow2(max(nt for _, nt in items))
        mirrors = [self._mirror(r.seq) for r, _ in items]
        p_need: Dict[str, int] = {}
        for name in self._table_specs:
            longest = 1
            for m in mirrors:
                longest = max(longest, m.n.get(name, 0))
            p_need[name] = _pow2(longest, 4)
        tokens = np.zeros((B, T), np.int32)
        positions = np.full((B, T), SENTINEL_POS, np.int32)
        seq_lens = np.ones((B,), np.int32)
        last_idx = np.zeros((B,), np.int32)
        tables = {k: np.full((1, 1, B, p), -1, np.int32)
                  for k, p in p_need.items()}
        page_pos = {k: np.full((1, 1, B, p), SENTINEL_POS, np.int32)
                    for k, p in p_need.items()}
        write_eids = {k: np.full((1, 1, B, T), -1, np.int32)
                      for k in p_need}
        state_eids = {s.name: np.full((1, B), -1, np.int32)
                      for s in self._state_specs.values()}
        cfg = self.model.cfg
        has_mm, has_enc = self._mm_enc_flags(items)
        mm_embeds = mm_mask = mrope = None
        enc_embeds = enc_write = enc_lens = None
        if has_mm:
            mm_embeds = np.zeros((B, T, cfg.d_model), np.float32)
            mm_mask = np.zeros((B, T), bool)
        if cfg.family == "encdec":
            enc_lens = np.zeros((B,), np.int32)
            if has_enc:
                enc_embeds = np.zeros((B, cfg.encoder_seq, cfg.d_model),
                                      np.float32)
                enc_write = np.full((1, 1, B, cfg.encoder_seq), -1, np.int32)

        fresh_state: List[Tuple[str, int]] = []
        for bi, ((r, t_real), m) in enumerate(zip(items, mirrors)):
            seq = r.seq
            start = seq.num_computed
            fresh_state.extend(self._fresh_state_of(seq))
            toks = seq.tokens[start:start + t_real]
            tokens[bi, :len(toks)] = toks
            positions[bi, :t_real] = np.arange(start, start + t_real)
            seq_lens[bi] = start + t_real
            last_idx[bi] = t_real - 1
            for name, spec in self._table_specs.items():
                np_ = p_need[name]
                nm = min(m.n.get(name, 0), np_)
                if nm:
                    tables[name][0, 0, bi, :nm] = m.table[name][:nm]
                    page_pos[name][0, 0, bi, :nm] = m.pos[name][:nm]
                if spec.kind in ("full_attn", "swa"):
                    tpp = spec.tokens_per_page
                    pgs = (start + np.arange(t_real)) // tpp
                    write_eids[name][0, 0, bi, :t_real] = \
                        m.table[name][pgs] if m.n.get(name, 0) else -1
            for name in state_eids:
                if name in seq.state_pages:
                    state_eids[name][0, bi] = seq.state_pages[name]
            if has_mm and self.stub_embed_fn:
                self._fill_mm(seq, start, t_real, mm_embeds, mm_mask, bi, 0)
            if cfg.family == "encdec":
                enc_lens[bi] = sum(it.length for it in seq.encoder_items)
                if has_enc and start == 0 and r.in_prefill \
                        and self.stub_embed_fn:
                    self._fill_encoder(seq, m, enc_embeds, enc_write, bi)
        if has_mm:
            mrope = np.broadcast_to(positions[None], (3, B, T)).copy()

        batch = DecodeBatch(
            tokens=jnp.asarray(tokens), positions=jnp.asarray(positions),
            seq_lens=jnp.asarray(seq_lens),
            tables={k: jnp.asarray(v) for k, v in tables.items()},
            page_pos={k: jnp.asarray(v) for k, v in page_pos.items()},
            write_eids={k: jnp.asarray(v) for k, v in write_eids.items()},
            state_eids={k: jnp.asarray(v) for k, v in state_eids.items()},
            mm_embeds=None if mm_embeds is None else jnp.asarray(mm_embeds),
            mm_mask=None if mm_mask is None else jnp.asarray(mm_mask),
            mrope_pos=None if mrope is None else jnp.asarray(mrope),
            last_idx=jnp.asarray(last_idx),
            enc_embeds=None if enc_embeds is None else jnp.asarray(enc_embeds),
            enc_write_eids=None if enc_write is None else jnp.asarray(enc_write),
            enc_lens=None if enc_lens is None else jnp.asarray(enc_lens),
        )
        # T==1 buckets take the cheap materialized decode path; any larger
        # bucket (or an encoder run) uses the chunked prefill path. Both are
        # exact for every row thanks to position-based masking.
        prefill = T > 1 or has_enc
        key = (prefill, B, T, tuple(sorted(p_need.items())), has_mm, has_enc)
        return batch, {"key": key, "n": n, "prefill": prefill,
                       "fresh_state": fresh_state,
                       "tokens": sum(nt for _, nt in items), "slots": B * T}

    def _build_plan_packed(self, items: Sequence[Tuple[Request, int]]
                           ) -> Tuple[DecodeBatch, dict]:
        """Token-packed layout: flatten the whole step into ONE
        ``(TT,)`` token stream (TT = ``_tok_bucket(total_tokens)``) with
        per-token segment ids / positions / chunk starts / KV write
        targets, per-segment ``(start, last_tok)`` row metadata, and ONE
        flat page stream per KV type tagged with per-page owning segments.
        Pad tokens carry segment id -1 and SENTINEL positions; pad pages
        carry segment id -2 — pads never match anything."""
        n = len(items)
        assert n > 0
        total = sum(nt for _, nt in items)
        TT = _tok_bucket(total)
        S = _pow2(n)                                  # segment bucket
        mirrors = [self._mirror(r.seq) for r, _ in items]
        p_need: Dict[str, int] = {}                   # flat page-stream cap
        for name in self._table_specs:
            p_need[name] = _pow2(
                max(1, sum(m.n.get(name, 0) for m in mirrors)), 4)
        tokens = np.zeros((TT,), np.int32)
        positions = np.full((TT,), SENTINEL_POS, np.int32)
        seg_ids = np.full((TT,), -1, np.int32)
        chunk_start = np.full((TT,), SENTINEL_POS, np.int32)
        seg_start_tok = np.zeros((TT,), np.int32)
        seg_last_tok = np.zeros((S,), np.int32)
        seq_lens = np.ones((S,), np.int32)
        tables = {k: np.full((1, 1, 1, p), -1, np.int32)
                  for k, p in p_need.items()}
        page_pos = {k: np.full((1, 1, 1, p), SENTINEL_POS, np.int32)
                    for k, p in p_need.items()}
        page_seg = {k: np.full((1, 1, 1, p), -2, np.int32)
                    for k, p in p_need.items()}
        write_eids = {k: np.full((1, 1, 1, TT), -1, np.int32)
                      for k in p_need}
        state_eids = {s.name: np.full((1, S), -1, np.int32)
                      for s in self._state_specs.values()}
        cfg = self.model.cfg
        has_mm, has_enc = self._mm_enc_flags(items)
        mm_embeds = mm_mask = mrope = None
        enc_embeds = enc_write = enc_lens = None
        if has_mm:
            mm_embeds = np.zeros((1, TT, cfg.d_model), np.float32)
            mm_mask = np.zeros((1, TT), bool)
        if cfg.family == "encdec":
            enc_lens = np.zeros((1, TT), np.int32)    # per TOKEN when packed
            if has_enc:
                enc_embeds = np.zeros((S, cfg.encoder_seq, cfg.d_model),
                                      np.float32)
                enc_write = np.full((1, 1, S, cfg.encoder_seq), -1, np.int32)

        fresh_state: List[Tuple[str, int]] = []
        page_cursor = {name: 0 for name in p_need}
        off = 0
        for si, ((r, t_real), m) in enumerate(zip(items, mirrors)):
            seq = r.seq
            start = seq.num_computed
            fresh_state.extend(self._fresh_state_of(seq))
            toks = seq.tokens[start:start + t_real]
            tokens[off:off + len(toks)] = toks
            positions[off:off + t_real] = np.arange(start, start + t_real)
            seg_ids[off:off + t_real] = si
            chunk_start[off:off + t_real] = start
            seg_start_tok[off:off + t_real] = off
            seg_last_tok[si] = off + t_real - 1
            seq_lens[si] = start + t_real
            for name, spec in self._table_specs.items():
                nm = m.n.get(name, 0)
                pc = page_cursor[name]
                if nm:
                    tables[name][0, 0, 0, pc:pc + nm] = m.table[name][:nm]
                    page_pos[name][0, 0, 0, pc:pc + nm] = m.pos[name][:nm]
                    page_seg[name][0, 0, 0, pc:pc + nm] = si
                    page_cursor[name] = pc + nm
                if spec.kind in ("full_attn", "swa"):
                    tpp = spec.tokens_per_page
                    pgs = (start + np.arange(t_real)) // tpp
                    write_eids[name][0, 0, 0, off:off + t_real] = \
                        m.table[name][pgs] if nm else -1
            for name in state_eids:
                if name in seq.state_pages:
                    state_eids[name][0, si] = seq.state_pages[name]
            if has_mm and self.stub_embed_fn:
                self._fill_mm(seq, start, t_real, mm_embeds, mm_mask, 0, off)
            if cfg.family == "encdec":
                enc_lens[0, off:off + t_real] = \
                    sum(it.length for it in seq.encoder_items)
                if has_enc and start == 0 and r.in_prefill \
                        and self.stub_embed_fn:
                    self._fill_encoder(seq, m, enc_embeds, enc_write, si)
            off += t_real
        if has_mm:
            mrope = np.broadcast_to(positions[None, None], (3, 1, TT)).copy()

        batch = DecodeBatch(
            tokens=jnp.asarray(tokens[None]),
            positions=jnp.asarray(positions[None]),
            seq_lens=jnp.asarray(seq_lens),
            tables={k: jnp.asarray(v) for k, v in tables.items()},
            page_pos={k: jnp.asarray(v) for k, v in page_pos.items()},
            write_eids={k: jnp.asarray(v) for k, v in write_eids.items()},
            state_eids={k: jnp.asarray(v) for k, v in state_eids.items()},
            mm_embeds=None if mm_embeds is None else jnp.asarray(mm_embeds),
            mm_mask=None if mm_mask is None else jnp.asarray(mm_mask),
            mrope_pos=None if mrope is None else jnp.asarray(mrope),
            last_idx=None,
            enc_embeds=None if enc_embeds is None else jnp.asarray(enc_embeds),
            enc_write_eids=None if enc_write is None else jnp.asarray(enc_write),
            enc_lens=None if enc_lens is None else jnp.asarray(enc_lens),
            seg_ids=jnp.asarray(seg_ids[None]),
            chunk_start=jnp.asarray(chunk_start[None]),
            seg_start_tok=jnp.asarray(seg_start_tok[None]),
            seg_last_tok=jnp.asarray(seg_last_tok),
            page_seg={k: jnp.asarray(v) for k, v in page_seg.items()},
        )
        key = ("packed", S, TT, tuple(sorted(p_need.items())),
               has_mm, has_enc)
        return batch, {"key": key, "n": n, "prefill": True,
                       "fresh_state": fresh_state,
                       "tokens": total, "slots": TT}

    # ----------------------------------------------------------------- run
    def run_plan(self, params, items: Sequence[Tuple[Request, int]],
                 packed: bool = True) -> np.ndarray:
        """Execute one mixed step plan in a single jitted dispatch. Returns
        last-token logits, one row per item, in plan order."""
        batch, info = self.build_plan(items, packed=packed)
        self.tokens_dispatched += info["tokens"]
        self.slots_dispatched += info["slots"]
        self.dispatch_count += 1
        self.zero_pages(self.mgr.drain_fresh_pages())
        for name, eid in info["fresh_state"]:
            self.zero_page(name, eid)
        key = info["key"]
        fn = self._steps.get(key)
        if fn is None:
            fn = jax.jit(partial(self.model.serve_step,
                                 prefill=info["prefill"]),
                         donate_argnums=(1,))
            self._steps[key] = fn
        logits, self.buffer = fn(params, self.buffer, batch)
        return np.asarray(logits[:info["n"]], np.float32)

    # ------------------------------------------------------------- copies
    def apply_copies(self, ops: Sequence[StateCopyOp]) -> None:
        """Execute all StateCopyOps of one step phase. Ops are grouped by KV
        type and each group runs as ONE device dispatch (gather over src
        exec ids, scatter over dst exec ids) instead of one jit call per op.
        Within a phase all sources are read before any destination is
        written, which matches sequential execution because a phase never
        copies out of a page it also copies into."""
        if not ops:
            return
        by_type: Dict[str, List[StateCopyOp]] = {}
        for op in ops:
            by_type.setdefault(op.type_name, []).append(op)
        total = self.buffer.shape[-1]
        for name, group in by_type.items():
            size = self.specs[name].page_units
            if total % size:            # misaligned pool: per-op fallback
                for op in group:
                    self.copy_page(name, op.src_page, op.dst_page)
                continue
            cap = _pow2(len(group))
            srcs = np.zeros((cap,), np.int32)
            dsts = np.full((cap,), total // size, np.int32)   # pad -> OOB drop
            for i, op in enumerate(group):
                srcs[i] = op.src_page
                dsts[i] = op.dst_page
            fn = self._batch_copy_fns.get((size, cap))
            if fn is None:
                def cp(buf, srcs, dsts, size_s):
                    rows = buf.reshape(-1, size_s)
                    blk = jnp.take(rows, srcs, axis=0)
                    rows = rows.at[dsts].set(blk, mode="drop",
                                             unique_indices=False)
                    return rows.reshape(buf.shape)
                fn = jax.jit(cp, static_argnums=(3,), donate_argnums=(0,))
                self._batch_copy_fns[(size, cap)] = fn
            self.buffer = fn(self.buffer, jnp.asarray(srcs),
                             jnp.asarray(dsts), size)

    def zero_pages(self, pages: Sequence[Tuple[str, int]]) -> None:
        """Zero freshly allocated pages (one batched dispatch per type):
        recycled large pages carry other types' stale bytes, which can
        decode as NaN when gathered as K/V — and NaN survives even fully
        masked softmax accumulation."""
        if not pages:
            return
        by_type: Dict[str, List[int]] = {}
        for name, eid in pages:
            by_type.setdefault(name, []).append(eid)
        total = self.buffer.shape[-1]
        for name, eids in by_type.items():
            # manager spec table, not self.specs: with several models
            # sharing one pool (spec decode) a drain can surface pages of
            # types this runner's model does not own
            size = self.mgr.spec(name).page_units
            if total % size:
                for eid in eids:
                    self.zero_page(name, eid)
                continue
            cap = _pow2(len(eids))
            dsts = np.full((cap,), total // size, np.int32)  # pad: OOB drop
            dsts[:len(eids)] = eids
            fn = self._batch_zero_fns.get((size, cap))
            if fn is None:
                def z(buf, dsts, size_s, cap_s):
                    rows = buf.reshape(-1, size_s)
                    zero = jnp.zeros((cap_s, size_s), buf.dtype)
                    rows = rows.at[dsts].set(zero, mode="drop",
                                             unique_indices=False)
                    return rows.reshape(buf.shape)
                fn = jax.jit(z, static_argnums=(2, 3), donate_argnums=(0,))
                self._batch_zero_fns[(size, cap)] = fn
            self.buffer = fn(self.buffer, jnp.asarray(dsts), size, cap)

    def zero_page(self, type_name: str, eid: int) -> None:
        """Zero one small page (fresh recurrent-state initialisation)."""
        size = self.specs[type_name].page_units
        if self._zero_fn is None:
            def z(buf, off, size_s):
                flat = buf.reshape(-1)
                flat = jax.lax.dynamic_update_slice(
                    flat, jnp.zeros((size_s,), flat.dtype), (off,))
                return flat.reshape(buf.shape)
            self._zero_fn = jax.jit(z, static_argnums=(2,),
                                    donate_argnums=(0,))
        self.buffer = self._zero_fn(self.buffer, jnp.int32(eid * size), size)

    def copy_page(self, type_name: str, src: int, dst: int) -> None:
        """Device copy of one whole small page (state checkpoint/restore)."""
        spec = self.specs[type_name]
        size = spec.page_units
        if self._copy_fn is None:
            def cp(buf, off_src, off_dst, size_s):
                flat = buf.reshape(-1)
                blk = jax.lax.dynamic_slice(flat, (off_src,), (size_s,))
                flat = jax.lax.dynamic_update_slice(flat, blk, (off_dst,))
                return flat.reshape(buf.shape)
            self._copy_fn = jax.jit(cp, static_argnums=(3,),
                                    donate_argnums=(0,))
        self.buffer = self._copy_fn(
            self.buffer, jnp.int32(src * size), jnp.int32(dst * size), size)
