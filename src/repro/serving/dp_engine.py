"""Multi-engine data-parallel serving: N engine shards behind a router.

``DPEngine`` owns ``num_shards`` fully independent ``Engine`` instances —
each with its own ``JengaKVCacheManager``, scheduler, in-flight ring and
(optionally) budget autotuner — and drives them round-robin, one engine
step per shard per fleet tick. Requests enter through the front-end
``submit``, which places them with the cache-aware ``Router``
(``serving.router``); results, metrics and health aggregate back up.
The model object (and its jitted serve-step cache) is shared — shards
differ only in cache/scheduler state, which is what data parallelism
means here. ``run_plan`` being a pure function of (plan, mirrors) is what
makes this an orchestration problem rather than a model one: nothing
below the engine knows the fleet exists, and the per-shard
``prepare``/``dispatch``/``fetch`` phases are the natural RPC boundary
when the shards move out of process.

Fault handling (exercised by the multi-engine fuzz harness):

  * ``inject_stall(i, resume_after=k)`` — the shard stops stepping and
    accepting; its queued-but-unstarted requests (never part of a
    dispatched plan) are drained and re-admitted elsewhere, while started
    work stays put and resumes with the shard after ``k`` ticks. An
    indefinite stall (``resume_after=None``) escalates to a crash after
    ``stall_escalate_ticks`` so started work is not stranded forever.
  * ``inject_crash(i)`` — the shard is dead: its in-flight ring is
    dropped, EVERY unfinished request is reset (partial outputs
    discarded, pages freed uncached) and re-admitted elsewhere. Greedy
    and the seeded temperature draws are deterministic in (rid,
    position), so the recompute reproduces the same tokens — failover is
    exactly-once with bit-identical outputs.

When every accepting shard is down, re-admissions park at the front end
and are re-placed as soon as a shard accepts again.

Determinism: shards are stepped in id order, placement is a deterministic
function of (config, arrival order, shard state), and each shard is a
plain ``Engine`` — so a fleet run is reproducible tick for tick, and any
single shard's execution can be replayed on a standalone engine by
re-submitting the same requests at the same shard-local steps
(``tests/test_router.py`` asserts both).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence

from ..core.request import SequenceState
from .autotune import BudgetAutotuner, shard_pool_bytes
from .engine import Engine, EngineConfig, ShardHealth, StepMetrics
from .request import Request, Status
from .router import Router, RouterConfig


def _default_shards() -> int:
    return int(os.environ.get("REPRO_ROUTER_SHARDS", "2") or 2)


class EngineShard:
    """One engine replica plus its fleet-side liveness bookkeeping."""

    def __init__(self, sid: int, engine: Engine):
        self.sid = sid
        self.engine = engine
        self.alive = True           # False: crashed, permanently out
        self.accepting = True       # False: not a placement candidate
        # not-None: stalled. Fleet tick to resume at, or None-sentinel -1
        # for an indefinite stall (candidate for crash escalation).
        self.stalled_until: Optional[int] = None
        self.stalled_since: Optional[int] = None
        self.finished_seen = 0      # finish-tick stamping cursor

    @property
    def stalled(self) -> bool:
        return self.stalled_until is not None

    def has_work(self) -> bool:
        return self.engine.scheduler.has_work() or self.engine.has_inflight


class DPEngine:
    """Front end of a data-parallel engine fleet (see module docstring).

    ``cfg.kv_pool_bytes`` is the FLEET-wide pool by default, split evenly
    across shards (``split_pool=False`` makes it per-shard — tests use
    that to force tiny shard pools). With ``cfg.autotune_budgets``, each
    shard gets its own shard-aware ``BudgetAutotuner`` (per-device
    roofline seed, observation window scaled by the fleet size)."""

    def __init__(self, model, cfg: EngineConfig,
                 router_cfg: Optional[RouterConfig] = None, *,
                 num_shards: Optional[int] = None,
                 policy: Optional[str] = None,
                 roles: Optional[Sequence[str]] = None,
                 params=None, split_pool: bool = True,
                 stall_escalate_ticks: int = 0, seed: int = 0):
        if router_cfg is None:
            router_cfg = RouterConfig(
                num_shards=num_shards or _default_shards())
        if num_shards is not None:
            router_cfg = dataclasses.replace(router_cfg,
                                             num_shards=num_shards)
        if policy is not None:
            router_cfg = dataclasses.replace(router_cfg, policy=policy)
        n = router_cfg.num_shards
        self.router = Router(router_cfg)
        self.stall_escalate_ticks = stall_escalate_ticks
        shard_cfg = cfg
        if split_pool and n > 1:
            shard_cfg = dataclasses.replace(
                cfg, kv_pool_bytes=shard_pool_bytes(cfg.kv_pool_bytes, n))
        params = params if params is not None else model.init(seed)
        if roles is not None:
            assert len(roles) == n, (len(roles), n)
            assert all(r in ("both", "prefill", "decode") for r in roles), \
                roles
        self.shards: List[EngineShard] = []
        for sid in range(n):
            cfg_i = shard_cfg
            if roles is not None:
                cfg_i = dataclasses.replace(shard_cfg, role=roles[sid])
            eng = Engine(model, cfg_i, params=params, seed=seed)
            if shard_cfg.autotune_budgets:
                eng.autotuner = BudgetAutotuner(model.cfg, num_shards=n)
                eng.scheduler.set_budgets(eng.autotuner.budget,
                                          eng.autotuner.prefill_cap)
            self.shards.append(EngineShard(sid, eng))
        self.tick = 0
        self.submit_tick: Dict[str, int] = {}
        self.finish_tick: Dict[str, int] = {}
        self._parked: List[Request] = []    # re-admissions with no shard up
        # prefill->decode handoff log (one dict per completed handoff) and
        # the count of colocated failovers (prefill shards flipped to
        # "both" because no decode-capable shard was left)
        self.handoffs: List[dict] = []
        self.role_failovers = 0

    # -------------------------------------------------------------- submit
    def submit(self, req: Request, readmitted: bool = False) -> int:
        """Route and enqueue one request; returns the shard id (-1 when
        parked because no shard is accepting)."""
        if not any(sh.accepting for sh in self.shards):
            self._parked.append(req)
            self.submit_tick.setdefault(req.rid, self.tick)
            return -1
        # fresh arrivals need a prefill-capable shard; decode-only shards
        # receive work through the handoff path only
        sid = self.router.place(req, self.shards, readmitted=readmitted,
                                want="prefill")
        self.shards[sid].engine.submit(req)
        self.submit_tick.setdefault(req.rid, self.tick)
        return sid

    def _readmit(self, reqs: List[Request]) -> None:
        for req in reqs:
            self.submit(req, readmitted=True)

    # ------------------------------------------------------ fault injection
    def inject_stall(self, sid: int, resume_after: Optional[int] = None
                     ) -> List[Request]:
        """Stall shard ``sid``: it stops stepping and accepting; its
        never-started requests move elsewhere. Transient stalls resume
        after ``resume_after`` ticks; indefinite ones escalate to a crash
        after ``stall_escalate_ticks`` (if configured) so started work is
        not stranded. Returns the drained (now re-admitted) requests."""
        sh = self.shards[sid]
        assert sh.alive, f"shard {sid} already crashed"
        sh.accepting = False
        sh.stalled_until = (-1 if resume_after is None
                            else self.tick + resume_after)
        sh.stalled_since = self.tick
        drained = sh.engine.drain_requests(unstarted_only=True, cache=True)
        self._readmit(drained)
        return drained

    def inject_crash(self, sid: int) -> List[Request]:
        """Kill shard ``sid``: drop its in-flight ring, free every page
        uncached, reset and re-admit every unfinished request. Returns the
        failed-over requests."""
        sh = self.shards[sid]
        sh.alive = False
        sh.accepting = False
        sh.stalled_until = None
        drained = sh.engine.drain_requests(unstarted_only=False)
        self._readmit(drained)
        return drained

    # ---------------------------------------------------------------- step
    def step(self) -> List[StepMetrics]:
        """One fleet tick: step every live, unstalled shard once (in shard
        id order — determinism), poll health into the router, handle stall
        resume/escalation, re-place parked requests, stamp finishes."""
        self.tick += 1
        out: List[StepMetrics] = []
        for sh in self.shards:
            if not sh.alive:
                continue
            if sh.stalled:
                if 0 <= sh.stalled_until <= self.tick:
                    sh.stalled_until = None     # stall over: resume
                    sh.stalled_since = None
                    sh.accepting = True
                elif (sh.stalled_until < 0 and self.stall_escalate_ticks
                        and self.tick - sh.stalled_since
                        >= self.stall_escalate_ticks):
                    self.inject_crash(sh.sid)   # stranded started work
                    continue
                else:
                    continue
            m = sh.engine.step()
            if m is not None:
                out.append(m)
            self.router.observe(sh.sid, sh.engine.health_snapshot())
        self._do_handoffs()
        if self._parked and any(sh.accepting for sh in self.shards):
            parked, self._parked = self._parked, []
            self._readmit(parked)
        for sh in self.shards:
            fin = sh.engine.finished
            for req in fin[sh.finished_seen:]:
                self.finish_tick.setdefault(req.rid, self.tick)
            sh.finished_seen = len(fin)
        return out

    # ------------------------------------------ prefill->decode handoffs
    def _do_handoffs(self) -> None:
        """Move every handoff-ready request (prompt complete + first token
        sampled on a prefill shard, nothing in flight) to a decode-capable
        shard: export the typed page set, place with the router
        (``want="decode"``), adopt into the destination's pools + prefix
        cache, device-copy the pages across runners, and re-admit the
        request as a whole-prompt prefix hit — ``num_computed`` set to the
        prompt length, ``started`` reset, ZERO prefill tokens recomputed.

        Failure handling: adoption failure (destination pool pressure)
        cancels the export and retries next tick; a fleet with no live
        decode-capable shard flips its prefill shards to colocated "both"
        so requests finish where they are (degraded, but serving)."""
        srcs = [sh for sh in self.shards
                if sh.alive and not sh.stalled and sh.engine.role == "prefill"
                and sh.engine.handoff_ready()]
        if not srcs:
            return
        can_decode = any(
            sh.alive and sh.accepting and sh.engine.role in ("both", "decode")
            for sh in self.shards)
        if not can_decode:
            # colocated failover: no decode-capable shard left — prefill
            # shards take their parked requests through decode themselves
            for sh in self.shards:
                if sh.alive and sh.engine.role == "prefill":
                    sh.engine.set_role("both")
            self.role_failovers += 1
            return
        for sh in srcs:
            for req in sh.engine.handoff_ready():
                export = sh.engine.begin_handoff(req)
                dst_sid = self.router.place(req, self.shards, want="decode")
                dst = self.shards[dst_sid]
                if dst is sh:       # filter fell back to the source itself
                    sh.engine.cancel_handoff(req, export)
                    continue
                src_seq = req.seq
                dst_seq = SequenceState(
                    rid=req.rid, tokens=list(src_seq.tokens),
                    mm_items=src_seq.mm_items,
                    encoder_items=src_seq.encoder_items)
                ok, pairs = dst.engine.mgr.adopt_request(dst_seq, export)
                if not ok:
                    sh.engine.cancel_handoff(req, export)
                    continue
                # copy stream: exported pages -> the destination's buffer
                dst.engine.runner.adopt_pages(sh.engine.runner, pairs)
                rows = sh.engine.sample_log.pop(req.rid, None)
                sh.engine.complete_handoff(req, export)
                req.seq = dst_seq
                req.status = Status.WAITING
                req.started = False
                dst.engine.submit(req)      # admits as a whole-prompt hit
                if rows is not None:        # keep recorded rows aligned
                    dst.engine.sample_log[req.rid] = rows
                self.handoffs.append(dict(
                    rid=req.rid, src=sh.sid, dst=dst_sid,
                    tokens=export.num_tokens, pages=len(pairs),
                    tick=self.tick))

    @property
    def has_work(self) -> bool:
        """Unfinished work the fleet can still make progress on. An
        indefinitely stalled shard with no escalation configured does NOT
        count — its started requests are genuinely stranded (a hung device
        holding work forever), which callers observe as missing finishes."""
        if self._parked:
            return True
        for sh in self.shards:
            if not sh.alive or not sh.has_work():
                continue
            if not sh.stalled:
                return True
            if sh.stalled_until >= 0 or self.stall_escalate_ticks:
                return True     # will resume, or will escalate to failover
        return False

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        while self.has_work and self.tick < max_ticks:
            self.step()
        return self.finished

    # ---------------------------------------------------------- aggregation
    @property
    def finished(self) -> List[Request]:
        """Every finished request fleet-wide (crashed shards' pre-crash
        finishes included — those responses already left the building)."""
        return [r for sh in self.shards for r in sh.engine.finished]

    @property
    def sample_log(self):
        """Per-request recorded sample rows, taken from the shard that
        FINISHED each request (a failed-over request has a partial, stale
        log on the shard it was drained from)."""
        out = {}
        for sh in self.shards:
            log = sh.engine.sample_log
            for r in sh.engine.finished:
                if r.rid in log:
                    out[r.rid] = log[r.rid]
        return out

    def health(self) -> List[ShardHealth]:
        return [sh.engine.health_snapshot() for sh in self.shards]

    def check_invariants(self) -> None:
        for sh in self.shards:
            sh.engine.mgr.check_invariants()

    def fleet_stats(self) -> dict:
        """Aggregate counters for benches/tests: per-shard steps and
        placement mix, fleet-wide prefix hit rate, failover counts."""
        hit = sum(sh.engine.mgr.prefix_hit_tokens_total
                  for sh in self.shards)
        query = sum(sh.engine.mgr.prefix_query_tokens_total
                    for sh in self.shards)
        placed: Dict[int, int] = {}
        readmitted = 0
        for p in self.router.placements:
            placed[p.shard] = placed.get(p.shard, 0) + 1
            readmitted += int(p.readmitted)
        return dict(
            ticks=self.tick,
            finished=len(self.finished),
            steps_per_shard=[sh.engine.step_count for sh in self.shards],
            requests_per_shard=[placed.get(sh.sid, 0)
                                for sh in self.shards],
            readmissions=readmitted,
            prefix_hit_tokens=hit,
            prefix_query_tokens=query,
            prefix_hit_rate=hit / max(1, query),
            preemptions=[sh.engine.scheduler.preemption_count
                         for sh in self.shards],
            defers=[sh.engine.scheduler.defer_count for sh in self.shards],
            routing_costs=list(self.router.costs),
            handoffs=len(self.handoffs),
            handoff_pages=sum(h["pages"] for h in self.handoffs),
            role_failovers=self.role_failovers,
        )
