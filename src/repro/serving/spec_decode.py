"""Speculative decoding with a shared Jenga pool (paper §6.1, Fig. 19).

Draft and target models register their KV types ("draft_*" / "tgt_*") in ONE
JengaKVCacheManager: the LCM geometry automatically accommodates the two
page sizes with negligible fragmentation — the paper's multi-model case.

Greedy speculative decoding: the draft proposes k tokens; the target scores
them in a single T=k+1 step; the longest agreeing prefix is accepted plus
one bonus token; rejected tokens roll back (pages stay, content is
overwritten later).

Both runners dispatch through the default token-packed plan layout
(``ModelRunner.run_plan(..., packed=True)``): each draft/verify call is a
packed stream whose segments are the participating sequences, and logits
come back one row per segment."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.manager import JengaKVCacheManager
from ..core.request import SequenceState
from .engine import greedy_token
from .request import Request, SamplingParams
from .runner import ModelRunner


@dataclasses.dataclass
class SpecDecodeConfig:
    k: int = 3                      # proposals per round
    kv_pool_bytes: int = 64 << 20
    chunk_size: int = 32
    geometry_mode: str = "lcm"      # "max" reproduces vLLM-max (Fig. 19)
    # Accepted for config parity with EngineConfig; speculative decoding
    # EXPLICITLY FALLS BACK TO SYNC (see SpecDecodeEngine.async_fallback):
    # the draft->verify loop is a hard lockstep data dependency — each
    # draft token feeds the next draft step and the verify batch consumes
    # all k of them — so a one-step-delayed sample would need a delayed
    # verify queue with rollback across ROUNDS, not just steps. The engine
    # records the fallback instead of silently ignoring the flag.
    async_scheduling: bool = False


class SpecDecodeEngine:
    """Single-sequence-at-a-time speculative decoding (functional case
    study; the throughput comparison in benchmarks uses allocator replay).

    ``cfg.async_scheduling`` is accepted but runs synchronously
    (``async_fallback=True``): outputs are identical either way — the
    flag only ever changes scheduling overlap, never semantics."""

    def __init__(self, target_model, draft_model, cfg: SpecDecodeConfig,
                 target_params=None, draft_params=None, seed=0):
        assert target_model.cfg.family in ("dense", "moe")
        assert draft_model.cfg.family == "dense"
        self.async_fallback = bool(cfg.async_scheduling)
        target_model.kv_prefix = "tgt_"
        draft_model.kv_prefix = "draft_"
        self.tm, self.dm = target_model, draft_model
        self.cfg = cfg
        specs = tuple(target_model.kv_specs()) + tuple(draft_model.kv_specs())
        self.mgr = JengaKVCacheManager(
            specs, total_memory_bytes=cfg.kv_pool_bytes,
            mode=cfg.geometry_mode,
            enable_prefix_caching=False)   # rollback requires caching off
        self.t_runner = ModelRunner(target_model, self.mgr)
        self.d_runner = ModelRunner(draft_model, self.mgr)
        self.d_runner.buffer = self.t_runner.buffer   # shared pool...
        self._shared_buffer()
        self.tp = target_params if target_params is not None \
            else target_model.init(seed)
        self.dp = draft_params if draft_params is not None \
            else draft_model.init(seed + 1)
        self.accept_lengths: List[int] = []

    def _shared_buffer(self):
        # both runners must see the same device buffer object; wrap the
        # plan-based dispatch so each call picks up the other's buffer
        t, d = self.t_runner, self.d_runner

        class _Shared:
            buffer = t.buffer
        self._buf = _Shared

        def make_run(runner):
            orig = runner.run_plan

            def run_plan(params, items):
                runner.buffer = self._buf.buffer
                out = orig(params, items)
                self._buf.buffer = runner.buffer
                return out
            return run_plan

        t.run_plan_shared = make_run(t)
        d.run_plan_shared = make_run(d)

    # ------------------------------------------------------------ generate
    def generate(self, prompt: List[int], max_new_tokens: int = 16,
                 rid: str = "s0") -> List[int]:
        k = self.cfg.k
        # two SequenceStates share the same request id & token history
        tseq = SequenceState(rid=rid + "_t", tokens=list(prompt))
        dseq = SequenceState(rid=rid + "_d", tokens=list(prompt))
        for seq in (tseq, dseq):
            ok, _ = self.mgr.begin_request(seq)
            assert ok
        treq = Request(rid=rid + "_t", prompt=list(prompt)); treq.seq = tseq
        dreq = Request(rid=rid + "_d", prompt=list(prompt)); dreq.seq = dseq

        # prefill both (chunked); keep the TARGET's last logits
        t_last = None
        for seq, runner, params, req in ((tseq, self.t_runner, self.tp, treq),
                                         (dseq, self.d_runner, self.dp, dreq)):
            while seq.num_computed < len(prompt):
                n = min(self.cfg.chunk_size,
                        len(prompt) - seq.num_computed)
                assert self.mgr.allocate_for_tokens(
                    seq, seq.num_computed + n)
                logits = runner.run_plan_shared(params, [(req, n)])
                self.mgr.advance(seq, n)
            if seq is tseq:
                t_last = logits
        first = greedy_token(t_last[0][: self.tm.cfg.vocab_size])
        out = [first]
        tseq.append_token(first)
        dseq.append_token(first)

        while len(out) < max_new_tokens:
            # ---- draft proposes k tokens
            proposals = []
            for _ in range(k):
                assert self.mgr.allocate_for_tokens(dseq, dseq.num_tokens)
                logits = self.d_runner.run_plan_shared(self.dp, [(dreq, 1)])
                self.mgr.advance(dseq, 1)
                tok = greedy_token(logits[0][: self.dm.cfg.vocab_size])
                proposals.append(tok)
                dseq.append_token(tok)
            # ---- target verifies k+1 positions in one step
            base = tseq.num_computed          # first unverified position
            tseq.tokens = dseq.tokens[: base + k + 1]
            assert self.mgr.allocate_for_tokens(tseq, base + k + 1)
            t_logits = self._target_multi(treq, base, k + 1)
            greedy = [greedy_token(row)
                      for row in t_logits[:, : self.tm.cfg.vocab_size]]
            n_accept = 0
            while n_accept < k and proposals[n_accept] == int(greedy[n_accept]):
                n_accept += 1
            bonus = int(greedy[n_accept])
            accepted = proposals[:n_accept] + [bonus]
            self.accept_lengths.append(n_accept)
            out.extend(accepted)
            new_tokens = dseq.tokens[: base + n_accept + 1] + [bonus]
            self.mgr.advance(tseq, n_accept + 1)
            self.mgr.rollback(tseq, base + n_accept + 1, new_tokens)
            self.mgr.rollback(dseq, base + n_accept, new_tokens)
        self.mgr.free_request(tseq, cache=False)
        self.mgr.free_request(dseq, cache=False)
        return out[:max_new_tokens]

    def _target_multi(self, treq: Request, base: int, t: int) -> np.ndarray:
        """Target logits for positions [base, base+t): t bucketed decode
        calls (each reads the KV written by the previous — the strict
        `slot_pos < position` old-page mask makes this exact)."""
        seq = treq.seq
        logits_all = np.zeros((t, self.t_runner.model.v_pad), np.float32)
        saved = seq.num_computed
        for j in range(t):
            lg = self.t_runner.run_plan_shared(self.tp, [(treq, 1)])
            logits_all[j] = lg[0]
            seq.num_computed += 1
        seq.num_computed = saved
        return logits_all
