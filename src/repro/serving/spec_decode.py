"""Speculative decoding with a shared Jenga pool (paper §6.1, Fig. 19).

Draft and target models register their KV types ("draft_*" / "tgt_*") in ONE
JengaKVCacheManager: the LCM geometry automatically accommodates the two
page sizes with negligible fragmentation — the paper's multi-model case.

Greedy speculative decoding: the draft proposes k tokens; the target scores
them; the longest agreeing prefix is accepted plus one bonus token;
rejected tokens roll back (pages stay, content is overwritten later).

PIPELINED ROUNDS (device sampling, no host round-trip inside a round):
every draft/verify step carries the fused sampling tail of
``ModelRunner.dispatch`` and lands its greedy pick in the shared token
board (``serving.sampler``), where the NEXT step's dispatch reads it back
on device (``board_feed``). One round issues the k-step draft chain, the
(k+1)-step verify chain, and — before fetching anything — the NEXT
round's draft chain speculated on full acceptance (its first token fed
from the bonus board slot). Only then does the host sync, on 2k+1 tiny
int32 token handles (4 bytes each, not vocab-wide logits rows). On full
accept the pre-issued chain is reused (``overlapped_rounds``); otherwise
it is discarded and its trailing page allocations popped in one
round-level ``mgr.rollback_tokens`` (the dead dispatches still execute on
device, but they only write pages that are zeroed/overwritten by every
later owner — dispatch order makes that safe).

Board slot layout per round (draft and verify runners share one board):
draft step j writes slot j (0..k-1); verify step j writes slot k+j
(k..2k); slot 2k is the bonus-on-full-accept the speculated next chain
consumes.

Both runners dispatch through the default token-packed plan layout:
each draft/verify call is a packed stream whose segments are the
participating sequences, and samples come back one per segment."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..core.manager import JengaKVCacheManager
from ..core.request import SequenceState
from .request import Request
from .runner import ModelRunner
from .sampler import greedy_token


@dataclasses.dataclass
class SpecDecodeConfig:
    k: int = 3                      # proposals per round
    kv_pool_bytes: int = 64 << 20
    chunk_size: int = 32
    geometry_mode: str = "lcm"      # "max" reproduces vLLM-max (Fig. 19)


class SpecDecodeEngine:
    """Single-sequence-at-a-time speculative decoding (functional case
    study; the throughput comparison in benchmarks uses allocator replay).

    Rounds are pipelined through the device token board — the host syncs
    once per round on sampled-token handles, and the next round's draft
    chain is already in flight when it does (see module docstring).
    Outputs are exactly the target model's tie-banded greedy trajectory
    regardless of draft quality: a proposal is only kept when it equals
    the target's own greedy pick at that position."""

    def __init__(self, target_model, draft_model, cfg: SpecDecodeConfig,
                 target_params=None, draft_params=None, seed=0):
        assert target_model.cfg.family in ("dense", "moe")
        assert draft_model.cfg.family == "dense"
        target_model.kv_prefix = "tgt_"
        draft_model.kv_prefix = "draft_"
        self.tm, self.dm = target_model, draft_model
        self.cfg = cfg
        specs = tuple(target_model.kv_specs()) + tuple(draft_model.kv_specs())
        self.mgr = JengaKVCacheManager(
            specs, total_memory_bytes=cfg.kv_pool_bytes,
            mode=cfg.geometry_mode,
            enable_prefix_caching=False)   # rollback requires caching off
        self.t_runner = ModelRunner(target_model, self.mgr)
        self.d_runner = ModelRunner(draft_model, self.mgr)
        self.d_runner.buffer = self.t_runner.buffer   # shared pool...
        self._shared_state()
        self.tp = target_params if target_params is not None \
            else target_model.init(seed)
        self.dp = draft_params if draft_params is not None \
            else draft_model.init(seed + 1)
        self.accept_lengths: List[int] = []
        # rounds whose draft chain was already in flight before the
        # previous round's accept decision reached the host
        self.overlapped_rounds = 0
        self.spec_rollback_pages = 0

    def _shared_state(self):
        """Both runners must see the same device buffer AND token board;
        wrap their dispatch entry points so each call picks up whatever
        the other runner last produced (the board is how a verify step
        consumes a draft step's sample without a host round-trip)."""
        t, d = self.t_runner, self.d_runner

        class _Shared:
            buffer = t.buffer
            board = t._board
        self._buf = _Shared

        def make_run(runner):
            orig = runner.run_plan

            def run_plan(params, items):
                runner.buffer = self._buf.buffer
                runner._board = self._buf.board
                out = orig(params, items)
                self._buf.buffer = runner.buffer
                self._buf.board = runner._board
                return out
            return run_plan

        def make_dispatch(runner):
            def dispatch_shared(params, items, **prep_kw):
                runner.buffer = self._buf.buffer
                runner._board = self._buf.board
                prep = runner.prepare(items, **prep_kw)
                handle = runner.dispatch(params, prep)
                self._buf.buffer = runner.buffer
                self._buf.board = runner._board
                return handle
            return dispatch_shared

        t.run_plan_shared = make_run(t)
        d.run_plan_shared = make_run(d)
        t.dispatch_shared = make_dispatch(t)
        d.dispatch_shared = make_dispatch(d)

    # ------------------------------------------------------------- chains
    def _draft_chain(self, dreq: Request, n0: int, k: int,
                     first_src: Optional[int] = None,
                     require: bool = True) -> Optional[list]:
        """Issue the k-step draft chain with no host sync: step j computes
        position ``n0 + j``; its input token is host-known (j == 0 with no
        ``first_src``), fed from board slot ``first_src`` (cross-round
        bonus), or fed from the previous step's sample slot; its own
        greedy sample lands in slot j. With ``require=False`` (speculative
        next-round chain) an allocation failure abandons the chain and
        pops what it already allocated."""
        dseq = dreq.seq
        handles = []
        for j in range(k):
            start = n0 + j
            if not self.mgr.allocate_for_tokens(dseq, start + 1):
                assert not require, ("draft chain allocation failed", start)
                self.spec_rollback_pages += self.mgr.rollback_tokens(
                    dseq, n0)
                return None
            src = first_src if j == 0 else j - 1
            handles.append(self.d_runner.dispatch_shared(
                self.dp, [(dreq, 1, start)],
                sample=True, board_feed=True, board_dst=[j],
                board_src=None if src is None else [src]))
        return handles

    def _verify_chain(self, treq: Request, base: int, k: int) -> list:
        """Issue the (k+1)-step verify chain: step j computes position
        ``base + j`` — token host-known for j == 0, fed from draft slot
        j-1 otherwise — and lands the target's greedy pick for position
        base+j+1 in slot k+j."""
        handles = []
        for j in range(k + 1):
            start = base + j
            assert self.mgr.allocate_for_tokens(treq.seq, start + 1)
            src = None if j == 0 else j - 1
            handles.append(self.t_runner.dispatch_shared(
                self.tp, [(treq, 1, start)],
                sample=True, board_feed=True, board_dst=[k + j],
                board_src=None if src is None else [src]))
        return handles

    # ------------------------------------------------------------ generate
    def generate(self, prompt: List[int], max_new_tokens: int = 16,
                 rid: str = "s0") -> List[int]:
        k = self.cfg.k
        # two SequenceStates share the same request id & token history
        tseq = SequenceState(rid=rid + "_t", tokens=list(prompt))
        dseq = SequenceState(rid=rid + "_d", tokens=list(prompt))
        for seq in (tseq, dseq):
            ok, _ = self.mgr.begin_request(seq)
            assert ok
        treq = Request(rid=rid + "_t", prompt=list(prompt)); treq.seq = tseq
        dreq = Request(rid=rid + "_d", prompt=list(prompt)); dreq.seq = dseq

        # prefill both (chunked); keep the TARGET's last logits
        t_last = None
        for seq, runner, params, req in ((tseq, self.t_runner, self.tp, treq),
                                         (dseq, self.d_runner, self.dp, dreq)):
            while seq.num_computed < len(prompt):
                n = min(self.cfg.chunk_size,
                        len(prompt) - seq.num_computed)
                assert self.mgr.allocate_for_tokens(
                    seq, seq.num_computed + n)
                logits = runner.run_plan_shared(params, [(req, n)])
                self.mgr.advance(seq, n)
            if seq is tseq:
                t_last = logits
        first = greedy_token(t_last[0][: self.tm.cfg.vocab_size])
        out = [first]
        tseq.append_token(first)
        dseq.append_token(first)

        # (pre-issued next-round draft chain, its n0) — valid only if the
        # current round fully accepts so the base lands where it assumed
        pending: Optional[Tuple[list, int]] = None
        while len(out) < max_new_tokens:
            # invariant at round start: tseq.tokens == dseq.tokens ==
            # prompt + accepted output, base = len(tokens) - 1 is the
            # position of the first unverified token
            base = tseq.num_computed
            assert len(dseq.tokens) == base + 1
            if pending is not None and pending[1] == base:
                d_handles = pending[0]
                self.overlapped_rounds += 1
            else:
                if pending is not None:     # reject made the guess stale
                    self.spec_rollback_pages += self.mgr.rollback_tokens(
                        dseq, base + 1)
                d_handles = self._draft_chain(dreq, base, k)
            pending = None
            v_handles = self._verify_chain(treq, base, k)
            # speculate full acceptance: issue round R+1's draft chain fed
            # from the bonus slot BEFORE the host learns round R's outcome
            base_next = base + k + 1
            if len(out) + k + 1 < max_new_tokens:
                nxt = self._draft_chain(dreq, base_next, k,
                                        first_src=2 * k, require=False)
                if nxt is not None:
                    pending = (nxt, base_next)

            # ---- single host sync for the round: 2k+1 int32 handles
            proposals = [int(self.d_runner.fetch_tokens(h)[0])
                         for h in d_handles]
            greedy = [int(self.t_runner.fetch_tokens(h)[0])
                      for h in v_handles]
            # materialize the draft chain the host never saw, then advance
            # both sequences to where their dispatched chains computed
            dseq.tokens = dseq.tokens[: base + 1] + proposals
            self.mgr.advance(dseq, base + k - dseq.num_computed)
            tseq.tokens = list(dseq.tokens[: base + k + 1])
            n_accept = 0
            while n_accept < k and proposals[n_accept] == greedy[n_accept]:
                n_accept += 1
            bonus = greedy[n_accept]
            accepted = proposals[:n_accept] + [bonus]
            self.accept_lengths.append(n_accept)
            out.extend(accepted)
            new_tokens = dseq.tokens[: base + n_accept + 1] + [bonus]
            self.mgr.advance(tseq, n_accept + 1)
            self.mgr.rollback(tseq, base + n_accept + 1, new_tokens)
            self.mgr.rollback(dseq, base + n_accept, new_tokens)
        if pending is not None:    # drained mid-speculation: pop its pages
            self.spec_rollback_pages += self.mgr.rollback_tokens(
                dseq, tseq.num_computed + 1)
        self.mgr.free_request(tseq, cache=False)
        self.mgr.free_request(dseq, cache=False)
        return out[:max_new_tokens]
