"""Device-side sampling: the single source of truth for token selection.

Both the host path (``Engine._sample``) and the fused dispatch tail
(``ModelRunner.dispatch``) pick tokens through the functions here, so
greedy is bit-identical across them and seeded temperature/top-k draws
are reproducible and LAYOUT-INDEPENDENT: the random key is derived from
``(seed, rid_hash, position)``, never from batch shape or slot index.

Greedy tie handling
-------------------
bf16 reduction-order noise (chunked vs whole prefill, MoE expert tiling,
ref vs kernel attention) perturbs fp32 logits by ~1e-4, enough to flip
an argmax between two near-equal candidates depending on batch layout.
Greedy therefore resolves WITHIN A TIE BAND: any token whose fp32 logit
is within ``TIE_EPS`` of the row max is tie-eligible, and the lowest
token id in the band wins. On device this is ``argmax(x >= max - eps)``
— boolean argmax returns the first True, i.e. the lowest id in the band
— which is bit-identical to the host ``np.flatnonzero`` form because
max/compare are exact fp32 ops on the same values. No fixed band is
fully layout-independent (band-edge flips measured at ~1e-3..3e-2), so
cross-layout tests remain fork-aware (``assert_greedy_equiv``).

Temperature / top-k
-------------------
``logits/T`` -> fp32 log-softmax -> top-k truncation (kth-value
threshold; ``top_k <= 0`` keeps everything) -> Gumbel-max draw, with the
winning index picked through the same tie band so an exactly-replayed
row reproduces exactly. Pad vocab columns never need masking here: the
serve heads emit them at ``NEG`` (see ``models.tp.mask_pad_vocab``), so
they carry zero probability and sort last.

The token board
---------------
The sampler scatters each segment's sampled token into a persistent
device-resident int32 "board" at a per-request slot. A later dispatch
whose input token is still in flight reads it back on device
(``inject_tokens``), which is what lets the engine keep >1 step in
flight without a host round-trip. Host-side arrays use -1 for "no
write"/"no read"; the scatter converts -1 to ``board.size`` and relies
on ``mode="drop"`` (a raw -1 index would WRAP in a JAX scatter).
"""
import zlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Greedy tie band over fp32 logits; see module docstring.
TIE_EPS = 5e-3
# Matches the pad-vocab mask value in models.tp.mask_pad_vocab: large
# enough that exp() underflows to exactly 0.0, small enough to stay
# finite in fp32 arithmetic.
NEG = -1e30


def greedy_token(logits) -> int:
    """Host greedy pick: lowest token id within TIE_EPS of the row max."""
    # jengalint: allow[host-sync] fetch phase: row was already fetched by runner.fetch
    logits = np.asarray(logits, np.float32)
    return int(np.flatnonzero(logits >= logits.max() - TIE_EPS)[0])


def rid_hash(rid: str) -> int:
    """Stable 32-bit request-id hash (Python ``hash`` is process-salted)."""
    return zlib.crc32(rid.encode()) & 0xFFFFFFFF


# ----------------------------------------------------------- device pieces
def _band_pick(x):
    """Lowest index within TIE_EPS of the row max (trailing axis)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    return jnp.argmax(x >= m - TIE_EPS, axis=-1).astype(jnp.int32)


def _derive_key(seed, rh, pos):
    """(seed, rid_hash, position) -> PRNG key; layout-independent."""
    k = jax.random.PRNGKey(seed)
    k = jax.random.fold_in(k, rh)
    return jax.random.fold_in(k, pos)


def _perturbed_scores(logits, temp, top_k, key):
    """fp32 log-softmax of logits/T, top-k truncated, Gumbel-perturbed.

    The band-argmax of the result is a draw from the truncated softmax
    (Gumbel-max trick); temp <= 0 rows never read these scores."""
    s = logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
    z = jax.nn.log_softmax(s, axis=-1)
    v = s.shape[-1]
    kth = jnp.sort(s)[::-1][jnp.clip(top_k - 1, 0, v - 1)]
    keep = (top_k <= 0) | (s >= kth)
    z = jnp.where(keep, z, NEG)
    # clamp strictly inside (0, 1): u == 1.0 (possible after float32
    # rounding) would give -log(-log(u)) == +inf for EVERY column,
    # including truncated ones
    u = jax.random.uniform(key, s.shape, jnp.float32, 1e-7, 1.0 - 1e-7)
    return z - jnp.log(-jnp.log(u))


def _sample_batch(logits, board, dst, temps, top_ks, rhs, poss, seeds, *,
                  need_random):
    x = logits.astype(jnp.float32)
    toks = _band_pick(x)
    if need_random:
        keys = jax.vmap(_derive_key)(seeds, rhs, poss)
        g = jax.vmap(_perturbed_scores)(x, temps, top_ks, keys)
        toks = jnp.where(temps > 0, _band_pick(g), toks)
    # -1 == "no write": redirect out of bounds and let the scatter drop it.
    dstc = jnp.where(dst < 0, board.shape[0], dst).astype(jnp.int32)
    board = board.at[dstc].set(toks, mode="drop")
    return toks, board


# jit caches are module-level so every engine/runner in the process (and
# the draft+target runners of a spec-decode pair) shares the compiled
# sampler; jit retraces per shape, so the only explicit key is the
# static need_random flag. The board is donated (it is threaded through
# dispatches exactly like the KV buffer); the logits are NOT — the
# handle stays fetchable for record_sample_logits.
_SAMPLE_FNS = {}
_INJECT_FN = None
_HOST_FN = None


def get_sample_fn(need_random: bool):
    fn = _SAMPLE_FNS.get(bool(need_random))
    if fn is None:
        fn = jax.jit(partial(_sample_batch, need_random=bool(need_random)),
                     donate_argnums=(1,))
        _SAMPLE_FNS[bool(need_random)] = fn
    return fn


def inject_tokens(tokens, src, board):
    """Replace tokens at positions where ``src >= 0`` with board[src]."""
    global _INJECT_FN
    if _INJECT_FN is None:
        def _inject(tokens, src, board):
            fed = jnp.take(board, jnp.clip(src, 0, board.shape[0] - 1),
                           axis=0)
            return jnp.where(src >= 0, fed.astype(tokens.dtype), tokens)
        _INJECT_FN = jax.jit(_inject)
    return _INJECT_FN(tokens, src, board)


def host_sample(row, temperature, top_k, rh, pos, seed) -> int:
    """Temperature/top-k draw for one FULL-WIDTH (v_pad) logits row.

    Runs the exact device computation (same jitted graph shape as one
    vmap lane) so the sync host path and the fused dispatch tail draw
    identical tokens for identical rows. The row must be the full padded
    vocab width as emitted by the serve heads — Gumbel noise shape
    depends on it."""
    global _HOST_FN
    if _HOST_FN is None:
        def _one(logits, temp, tk, rh, pos, seed):
            key = _derive_key(seed, rh, pos)
            return _band_pick(_perturbed_scores(logits, temp, tk, key))
        _HOST_FN = jax.jit(_one)
    return int(_HOST_FN(jnp.asarray(row, jnp.float32),
                        jnp.float32(temperature), jnp.int32(top_k),
                        jnp.uint32(rh), jnp.int32(pos), jnp.int32(seed)))
