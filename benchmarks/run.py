"""Benchmark harness — one entry per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_alloc_overhead, bench_batchsize,
                   bench_fragmentation, bench_prefix_cache,
                   bench_spec_decode, bench_throughput, bench_vision_cache)
    benches = [
        ("fragmentation (paper §3.2 + Fig.16)", bench_fragmentation),
        ("decode batch size (Fig.15)", bench_batchsize),
        ("prefix caching (Fig.17)", bench_prefix_cache),
        ("alloc overhead / Llama parity (Fig.13)", bench_alloc_overhead),
        ("spec decode (Fig.19)", bench_spec_decode),
        ("vision cache (Fig.18)", bench_vision_cache),
        ("end-to-end engine throughput (Fig.13/14)", bench_throughput),
    ]
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for title, mod in benches:
        print(f"# --- {title}")
        try:
            mod.main(report=print)
        except Exception as e:  # keep the harness going; report the failure
            failures += 1
            print(f"{mod.__name__},-1,FAILED: {e!r}")
    print(f"# total_wall_s={time.time()-t0:.1f} failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
