"""Synthetic workload generators mirroring the paper's datasets."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.request import MMItem


@dataclasses.dataclass
class SimRequest:
    rid: str
    prompt_len: int
    output_len: int
    mm_items: Tuple[MMItem, ...] = ()
    arrival: int = 0
    shared_prefix: int = 0          # id of shared document (prefix caching)
    prefix_len: int = 0


def mmmu_pro_like(n: int, seed=0) -> List[SimRequest]:
    """MMMU-pro (paper §3.2): ~6193 image tokens + ~43 text tokens/request."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        img = int(rng.normal(6193, 300))
        txt = int(max(8, rng.normal(43, 10)))
        out.append(SimRequest(
            rid=f"mmmu{i}", prompt_len=img + txt,
            output_len=int(rng.integers(8, 64)),
            mm_items=(MMItem(0, img, mm_hash=1000 + i),)))
    return out


def mmlu_pro_like(n: int, seed=0) -> List[SimRequest]:
    """MMLU-pro: short text prompts (max 3076)."""
    rng = np.random.default_rng(seed)
    return [SimRequest(rid=f"mmlu{i}",
                       prompt_len=int(rng.integers(256, 3076)),
                       output_len=int(rng.integers(16, 128)))
            for i in range(n)]


def long_doc_qa(n: int = 20, seed=0, lo=55_000, hi=110_000) -> List[SimRequest]:
    """Fig. 15 workload: 20 requests at once, inputs 55-110k, outputs 50-100."""
    rng = np.random.default_rng(seed)
    return [SimRequest(rid=f"doc{i}",
                       prompt_len=int(rng.integers(lo, hi)),
                       output_len=int(rng.integers(50, 100)))
            for i in range(n)]


def arxiv_qa_like(n_articles: int, questions_per: int, article_len=8192,
                  q_len=64, out_len=64, seed=0,
                  shuffle=True) -> List[SimRequest]:
    """Fig. 17: multiple questions at the end of each shared article.
    shuffle=False keeps each article's questions consecutive (the paper's
    doc-QA session pattern)."""
    rng = np.random.default_rng(seed)
    out = []
    k = 0
    order = []
    for a in range(n_articles):
        for q in range(questions_per):
            order.append((a, q))
    if shuffle:
        rng.shuffle(order)
    for a, q in order:
        out.append(SimRequest(
            rid=f"art{a}q{q}", prompt_len=article_len + q_len,
            output_len=out_len, shared_prefix=a, prefix_len=article_len,
            arrival=k))
        k += 1
    return out


def sharegpt_like(n: int, seed=0) -> List[SimRequest]:
    """ShareGPT-ish lengths (paper cites mean 1085)."""
    rng = np.random.default_rng(seed)
    return [SimRequest(rid=f"sg{i}",
                       prompt_len=max(16, int(rng.lognormal(6.5, 0.8))),
                       output_len=int(rng.integers(32, 256)))
            for i in range(n)]
