"""Fig. 13 / Fig. 14: end-to-end engine throughput & latency — real engine
runs on reduced heterogeneous models, Jenga vs the PagedAttention baseline
under an identical pool budget. CPU wall-clock is not the roofline story;
the apples-to-apples signals are steps-to-finish and tokens/step (batch
capacity), exactly what the paper's speedups come from.

``run_async_ab`` A/Bs the double-buffered engine against the synchronous
loop on the decode-heavy staggered workload: same dispatches, same tokens,
host batch-build time overlapped with the in-flight device step. Writes
``BENCH_async.json`` (repo root) so the perf trajectory is recorded
per-PR."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import (ROUTE_CACHE_AWARE, ROUTE_ROUND_ROBIN, DPEngine,
                           Engine, EngineConfig, Request, SamplingParams)


ARCH_SET = ("h2o-danube-3-4b", "zamba2-1.2b", "granite-3-2b")


def run_engine(arch: str, mode: str, n_req=6, prompt=192, out=8,
               pool=None, batching: str = "packed"):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    if pool is None:
        # size the pool to ~2.5 requests of IDEAL (jenga) need, so the
        # baseline's waste forces smaller batches / preemption (the paper's
        # regime: memory capacity is the binding constraint)
        per_tok = 0
        from repro.models.registry import build_model as _bm
        for sp in model.kv_specs():
            if sp.kind in ("mamba", "rwkv"):
                per_tok += sp.page_units // max(1, prompt)
            elif sp.kind == "swa":
                per_tok += sp.units_per_token * min(
                    1.0, (cfg.sliding_window + out) / (prompt + out))
            else:
                per_tok += sp.units_per_token
        pool = int(2.5 * (prompt + out) * per_tok * 2)
        from repro.core.spec import lcm as _lcm
        big = _lcm([sp.page_units for sp in model.kv_specs()])
        pool = max(pool, 8 * big * 2)   # >= 8 LCM large pages
    eng = Engine(model, EngineConfig(kv_pool_bytes=pool, max_running=8,
                                     chunk_size=32, memory_mode=mode,
                                     batching_mode=batching,
                                     max_num_batched_tokens=256,
                                     enable_prefix_caching=False))
    for i in range(n_req):
        eng.submit(Request(rid=f"r{i}", prompt=[(7 * i + j) % 101
                                                for j in range(prompt)],
                           sampling=SamplingParams(max_new_tokens=out)))
    t0 = time.perf_counter()
    done = eng.run_until_done(max_steps=4000)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    return dict(steps=eng.step_count, finished=len(done),
                tokens=total_tokens, wall_s=dt,
                tok_per_step=total_tokens / max(1, eng.step_count),
                preemptions=eng.scheduler.preemption_count)


def run_waste_ab(arch: str, batching: str, n_req=16, prompt=96, out=24,
                 budget=128):
    """Decode-heavy mixed workload for the padding-waste A/B: requests
    arrive staggered so most steps co-schedule one prefill chunk with a
    growing decode batch — exactly the regime where the padded layout's
    decode rows pay the prefill chunk's (B, T) padding."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    eng = Engine(model, EngineConfig(
        kv_pool_bytes=96 << 20, max_running=n_req, chunk_size=32,
        memory_mode="jenga", batching_mode=batching,
        max_num_batched_tokens=budget, enable_prefix_caching=False))
    for i in range(n_req):
        eng.submit(Request(rid=f"r{i}", prompt=[(7 * i + j) % 101
                                                for j in range(prompt)],
                           sampling=SamplingParams(max_new_tokens=out)))
        eng.step()          # staggered arrivals: prefills ride with decodes
    eng.run_until_done(max_steps=4000)
    r = eng.runner
    waste = 1.0 - r.tokens_dispatched / max(1, r.slots_dispatched)
    return dict(waste=waste,
                tok_per_dispatch=r.tokens_dispatched / max(1, r.dispatch_count),
                slots=r.slots_dispatched, tokens=r.tokens_dispatched,
                finished=len(eng.finished))


def run_async_ab(arch: str, n_req=16, prompt=96, out=24, budget=128):
    """Async-vs-sync A/B on the decode-heavy staggered workload (the
    ``run_waste_ab`` regime). The semantic invariants come first — greedy
    outputs and dispatch counts identical — then the overlap accounting:
    per-step host batch-build ms (what double buffering hides behind the
    in-flight dispatch), device-wait ms, and wall-clock per step."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    params = model.init(0)
    rows = {}
    # warmup pass populates the model-shared serve-step jit cache so both
    # timed runs are compile-free (sync would otherwise pay every trace)
    for tag, async_ in (("warmup", False), ("sync", False),
                        ("async", True)):
        eng = Engine(model, EngineConfig(
            kv_pool_bytes=96 << 20, max_running=n_req, chunk_size=32,
            batching_mode="packed", async_scheduling=async_,
            max_num_batched_tokens=budget, enable_prefix_caching=False),
            params=params)
        for i in range(n_req):
            eng.submit(Request(rid=f"r{i}", prompt=[(7 * i + j) % 101
                                                    for j in range(prompt)],
                               sampling=SamplingParams(max_new_tokens=out)))
            eng.step()      # staggered arrivals: prefills ride with decodes
        t0 = time.perf_counter()
        eng.run_until_done(max_steps=4000)
        wall = time.perf_counter() - t0
        if tag == "warmup":
            continue
        ms = eng.metrics
        rows[tag] = dict(
            outputs={r.rid: list(r.output) for r in eng.finished},
            dispatches=eng.runner.dispatch_count,
            steps=eng.step_count,
            tokens=eng.runner.tokens_dispatched,
            wall_s=wall,
            host_build_ms_total=sum(m.host_build_ms for m in ms),
            dispatch_wait_ms_total=sum(m.dispatch_ms for m in ms),
            us_per_step=wall * 1e6 / max(1, eng.step_count),
        )
    assert rows["sync"]["outputs"] == rows["async"]["outputs"], \
        "async changed greedy outputs"
    assert rows["sync"]["dispatches"] == rows["async"]["dispatches"], \
        (rows["sync"]["dispatches"], rows["async"]["dispatches"])
    for r in rows.values():
        del r["outputs"]        # equality asserted; keep the JSON small
    # host build time the async loop issues while a dispatch is in flight —
    # the overlap claim is structural (phase order), measured here
    sync_b, async_b = (rows[t]["host_build_ms_total"]
                       for t in ("sync", "async"))
    return dict(arch=arch, n_req=n_req, prompt=prompt, out=out,
                budget=budget, sync=rows["sync"], async_=rows["async"],
                overlapped_host_build_ms=async_b,
                sync_host_build_ms=sync_b)


def run_pipeline_ab(arch: str = "granite-3-2b", n_req=16, prompt=96, out=24,
                    budget=128):
    """Pipeline-depth / device-sampling A/B on the decode-heavy staggered
    workload. Three timed legs: depth-2 host-sampled (the PR-3 double
    buffer), depth-2 device-sampled (same ring, completion blocks on 4
    bytes/segment instead of a vocab-wide fp32 row), and depth-4
    device-sampled (up to 3 steps queued on device). Semantic gates:
    greedy outputs bitwise identical across all legs (the device sampler
    shares the host tie-band rule), depth 4 finishes in no more engine
    steps than depth 2, and the drained pool leaks nothing. The recorded
    signals are the tentpole's: per-step fetched bytes, host sampling ms
    (0 on device legs), generated tokens/s, and the issue/queue/compute
    timing split."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    params = model.init(0)
    rows = {}
    legs = (("warmup", 2, False), ("depth2_host", 2, False),
            ("depth2_device", 2, True), ("depth4_device", 4, True))
    for tag, depth, device in legs:
        eng = Engine(model, EngineConfig(
            kv_pool_bytes=96 << 20, max_running=n_req, chunk_size=32,
            batching_mode="packed", async_scheduling=True,
            pipeline_depth=depth, device_sampling=device,
            max_num_batched_tokens=budget, enable_prefix_caching=False),
            params=params)
        for i in range(n_req):
            eng.submit(Request(rid=f"r{i}", prompt=[(7 * i + j) % 101
                                                    for j in range(prompt)],
                               sampling=SamplingParams(max_new_tokens=out)))
            eng.step()      # staggered arrivals: prefills ride with decodes
        t0 = time.perf_counter()
        eng.run_until_done(max_steps=4000)
        wall = time.perf_counter() - t0
        if tag == "warmup":
            continue
        ms = eng.metrics
        stats = eng.mgr.memory_stats()
        assert stats.used_units == 0 and \
            stats.free_units == stats.total_units, (tag, stats)
        gen = sum(len(r.output) for r in eng.finished)
        rows[tag] = dict(
            outputs={r.rid: list(r.output) for r in eng.finished},
            steps=eng.step_count,
            wall_s=wall,
            gen_tok_per_s=gen / max(1e-9, wall),
            fetched_bytes_total=eng.runner.bytes_fetched,
            fetched_bytes_per_step=eng.runner.bytes_fetched
            / max(1, eng.step_count),
            host_sample_ms_total=sum(m.host_sample_ms for m in ms),
            host_build_ms_total=sum(m.host_build_ms for m in ms),
            dispatch_wait_ms_total=sum(m.dispatch_ms for m in ms),
            dispatch_issue_ms_total=sum(m.dispatch_issue_ms for m in ms),
            dispatch_queue_ms_total=sum(m.dispatch_queue_ms for m in ms),
            dispatch_compute_ms_total=sum(m.dispatch_compute_ms for m in ms),
            spec_kills=eng.spec_kills,
        )
    base = rows["depth2_host"]
    for tag in ("depth2_device", "depth4_device"):
        assert rows[tag]["outputs"] == base["outputs"], \
            f"{tag} changed greedy outputs"
    assert rows["depth4_device"]["steps"] <= base["steps"], \
        (rows["depth4_device"]["steps"], base["steps"])
    # the round-trip kill: vocab-wide fp32 rows -> (segments,) int32
    assert rows["depth2_device"]["fetched_bytes_total"] * 10 \
        <= base["fetched_bytes_total"], rows["depth2_device"]
    assert rows["depth2_device"]["host_sample_ms_total"] == 0.0
    for r in rows.values():
        del r["outputs"]        # equality asserted; keep the JSON small
    return dict(arch=arch, n_req=n_req, prompt=prompt, out=out,
                budget=budget,
                fetch_bytes_ratio=base["fetched_bytes_total"]
                / max(1, rows["depth2_device"]["fetched_bytes_total"]),
                **rows)


def run_kernel_ab(arch: str = "granite-3-2b", n_req=32, prompt=96, out=24,
                  budget=128):
    """Kernel-vs-ref + autotune A/B on the decode-heavy staggered workload.

    Three timed legs over one workload: ref attention with the constant
    budgets above, the Pallas varlen kernel path, and ref attention with
    roofline-seeded autotuned budgets. Greedy outputs must match between
    ref and kernel; the block-sparse accounting (host-side mirror of the
    kernel's segment-interval skip test, identical for both impls since it
    depends only on the schedule) must show a majority of KV blocks
    skipped; autotuned budgets must finish in no more steps than the
    hand-picked constants. ``n_req`` is sized so the packed stream spans
    several query blocks — the skip fraction is bounded by 1 - 1/n_qblocks,
    so a decode batch of ~32 segments is what makes >50% reachable."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    params = model.init(0)
    from repro.serving.autotune import roofline_token_budget
    rows = {}
    for tag, impl, autotune in (("warmup", "ref", False),
                                ("ref", "ref", False),
                                ("kernel", "kernel", False),
                                ("autotuned", "ref", True)):
        eng = Engine(model, EngineConfig(
            kv_pool_bytes=96 << 20, max_running=n_req, chunk_size=32,
            batching_mode="packed", attention_impl=impl,
            autotune_budgets=autotune, max_num_batched_tokens=budget,
            enable_prefix_caching=False), params=params)
        for i in range(n_req):
            eng.submit(Request(rid=f"r{i}", prompt=[(7 * i + j) % 101
                                                    for j in range(prompt)],
                               sampling=SamplingParams(max_new_tokens=out)))
            eng.step()      # staggered arrivals: prefills ride with decodes
        t0 = time.perf_counter()
        eng.run_until_done(max_steps=4000)
        wall = time.perf_counter() - t0
        if tag == "warmup":
            continue
        r = eng.runner
        total = r.kv_blocks_scanned + r.kv_blocks_skipped
        rows[tag] = dict(
            outputs={q.rid: list(q.output) for q in eng.finished},
            steps=eng.step_count, wall_s=wall,
            kv_blocks_scanned=r.kv_blocks_scanned,
            kv_blocks_skipped=r.kv_blocks_skipped,
            kv_block_skip_frac=r.kv_blocks_skipped / max(1, total),
            attn_gflops_modeled=r.attn_flops_modeled / 1e9,
            attn_gbytes_modeled=r.attn_bytes_modeled / 1e9,
            budget_final=eng.scheduler.cfg.max_num_batched_tokens,
            prefill_cap_final=eng.scheduler.cfg.max_prefill_tokens_per_step,
        )
    assert rows["ref"]["outputs"] == rows["kernel"]["outputs"], \
        "kernel changed greedy outputs"
    assert rows["ref"]["kv_block_skip_frac"] > 0.5, rows["ref"]
    assert rows["autotuned"]["steps"] <= rows["ref"]["steps"], \
        (rows["autotuned"]["steps"], rows["ref"]["steps"])
    for r in rows.values():
        del r["outputs"]        # equality asserted; keep the JSON small
    return dict(arch=arch, n_req=n_req, prompt=prompt, out=out,
                budget_constant=budget,
                budget_roofline_seed=roofline_token_budget(cfg),
                ref=rows["ref"], kernel=rows["kernel"],
                autotuned=rows["autotuned"])


def _router_workload(groups=4, members=4, shared=56, unique=12, out=8):
    """Shared-prefix fleet workload: ``groups`` families of requests, each
    sharing a ``shared``-token prompt prefix (same system prompt / few-shot
    header) plus a short unique tail. Group LEADERS arrive first; followers
    arrive staggered a few ticks later, after the leaders' prefix pages
    have been computed and registered (cache-while-running) — so a
    cache-aware router can see where each family's prefix lives. Returns
    (arrival_tick, request-factory) pairs; factories, because every leg
    needs fresh Request objects."""
    out_specs = []
    for g in range(groups):
        pre = [(31 * g + j) % 101 for j in range(shared)]
        for m in range(members):
            tail = [(17 * g + 7 * m + j + 3) % 101 for j in range(unique)]
            arrival = 0 if m == 0 else 6 + 2 * m
            rid, prompt = f"g{g}m{m}", pre + tail
            out_specs.append((arrival, rid, prompt))
    def mk(rid, prompt):
        return lambda: Request(rid=rid, prompt=list(prompt),
                               sampling=SamplingParams(max_new_tokens=out))
    return sorted(((a, mk(r, p)) for a, r, p in out_specs),
                  key=lambda t: t[0])


def run_router_ab(arch: str = "granite-3-2b", shards: int = 3):
    """Data-parallel router A/B on the shared-prefix workload.

    Four legs, identical requests and arrival ticks: a solo engine (the
    1-device reference), a 1-shard fleet (must match the solo run BITWISE
    — the router layer adds no compute), and an N-shard fleet under
    round-robin vs cache-aware placement. The signal is the fleet-wide
    prefix-cache hit rate: round-robin scatters a prefix family across
    shards (each shard recomputes the shared prefix), cache-aware follows
    the boundary-hash chains to the shard that already holds it. Steps
    and per-request latency (submit->finish ticks) ride along."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    params = model.init(0)
    ecfg = EngineConfig(kv_pool_bytes=24 << 20, max_running=8,
                        chunk_size=32, batching_mode="packed",
                        max_num_batched_tokens=128,
                        enable_prefix_caching=True)
    wl = _router_workload()
    rows = {}
    legs = (("warmup", None, None), ("solo", None, None),
            ("router1", 1, ROUTE_CACHE_AWARE),
            (f"rr{shards}", shards, ROUTE_ROUND_ROBIN),
            (f"aware{shards}", shards, ROUTE_CACHE_AWARE))
    for tag, n, policy in legs:
        if n is None:
            eng = Engine(model, ecfg, params=params)
            submit, clock, stepf = eng.submit, lambda: eng.step_count, \
                eng.step
            busy = lambda: eng.scheduler.has_work() or eng.has_inflight
        else:
            eng = DPEngine(model, ecfg, params=params, num_shards=n,
                           policy=policy)
            submit, clock, stepf = eng.submit, lambda: eng.tick, eng.step
            busy = lambda: eng.has_work
        pending = list(wl)
        t0 = time.perf_counter()
        guard = 0
        while pending or busy():
            while pending and pending[0][0] <= clock():
                submit(pending.pop(0)[1]())
            stepf()
            guard += 1
            assert guard < 4000, tag
        wall = time.perf_counter() - t0
        if tag == "warmup":
            continue
        if n is None:
            hit = eng.mgr.prefix_hit_tokens_total
            query = eng.mgr.prefix_query_tokens_total
            steps, lat = eng.step_count, None
        else:
            fs = eng.fleet_stats()
            hit, query = fs["prefix_hit_tokens"], fs["prefix_query_tokens"]
            steps = max(fs["steps_per_shard"])
            lat = sum(eng.finish_tick[r] - eng.submit_tick[r]
                      for r in eng.finish_tick) / max(1, len(eng.finish_tick))
        rows[tag] = dict(
            outputs={r.rid: list(r.output) for r in eng.finished},
            finished=len(eng.finished), steps=steps, wall_s=wall,
            prefix_hit_tokens=hit, prefix_query_tokens=query,
            hit_rate=hit / max(1, query), mean_latency_ticks=lat,
            requests_per_shard=None if n is None
            else eng.fleet_stats()["requests_per_shard"])
    # the router in front of ONE engine is a pass-through: bitwise equal
    assert rows["router1"]["outputs"] == rows["solo"]["outputs"], \
        "1-shard fleet changed greedy outputs vs solo engine"
    aware, rr = rows[f"aware{shards}"], rows[f"rr{shards}"]
    assert sorted(aware["outputs"]) == sorted(rr["outputs"])
    assert aware["hit_rate"] > rr["hit_rate"], \
        (aware["hit_rate"], rr["hit_rate"])
    for r in rows.values():
        del r["outputs"]        # equality asserted; keep the JSON small
    return dict(arch=arch, shards=shards, **rows)


def run_disagg_ab(arch: str = "granite-3-2b", n_req=12, prompt=96, out=24,
                  budget=128):
    """Prefill/decode disaggregation A/B on a long-prompt + decode-heavy
    mix (the regime the split exists for: huge prompts competing with
    decode latency). Two timed legs over identical requests and arrival
    ticks on a 2-shard fleet: COLOCATED (both shards prefill+decode,
    the PR-8 default) and DISAGG (shard 0 prefill-only, shard 1
    decode-only, typed-page handoff at the prompt boundary). Gates:
    every request finishes exactly once in both legs, the split leg
    hands off every request, and its decode shard computes ZERO prefill
    tokens — the handoff replaced recompute entirely. Recorded per leg:
    handoff count and pages moved, per-shard prefill/decode token mix
    (the phase isolation the A/B is about), mean request latency in
    fleet ticks, and the dispatch issue/queue/compute timing split per
    shard."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, single_device_dist())
    params = model.init(0)
    ecfg = EngineConfig(kv_pool_bytes=48 << 20, max_running=8,
                        chunk_size=32, batching_mode="packed",
                        max_num_batched_tokens=budget,
                        enable_prefix_caching=True)
    rows = {}
    legs = (("warmup", None), ("colocated", None),
            ("disagg", ["prefill", "decode"]))
    for tag, roles in legs:
        dp = DPEngine(model, ecfg, params=params, num_shards=2,
                      roles=roles)
        t0 = time.perf_counter()
        for i in range(n_req):
            dp.submit(Request(rid=f"r{i}", prompt=[(7 * i + j) % 101
                                                   for j in range(prompt)],
                              sampling=SamplingParams(max_new_tokens=out)))
            dp.step()       # staggered arrivals: decodes run under prefills
        guard = 0
        while dp.has_work:
            dp.step()
            guard += 1
            assert guard < 4000, tag
        wall = time.perf_counter() - t0
        if tag == "warmup":
            continue
        rids = [r.rid for r in dp.finished]
        assert len(rids) == n_req and len(set(rids)) == n_req, (tag, rids)
        fs = dp.fleet_stats()
        shards = []
        for sh in dp.shards:
            ms = sh.engine.metrics
            pf = sum(m.prefill_tokens for m in ms)
            tot = sum(m.batched_tokens for m in ms)
            shards.append(dict(
                role=sh.engine.role,
                steps=sh.engine.step_count,
                prefill_tokens=pf,
                decode_tokens=tot - pf,
                dispatch_issue_ms=sum(m.dispatch_issue_ms for m in ms),
                dispatch_queue_ms=sum(m.dispatch_queue_ms for m in ms),
                dispatch_compute_ms=sum(m.dispatch_compute_ms for m in ms),
                host_build_ms=sum(m.host_build_ms for m in ms),
            ))
        lat = sum(dp.finish_tick[r] - dp.submit_tick[r]
                  for r in dp.finish_tick) / max(1, len(dp.finish_tick))
        rows[tag] = dict(
            shards=shards, wall_s=wall, mean_latency_ticks=lat,
            handoffs=fs.get("handoffs", 0),
            handoff_pages=fs.get("handoff_pages", 0),
            role_failovers=fs.get("role_failovers", 0))
    d = rows["disagg"]
    # the handoff contract: every request moved, none recomputed prefill
    assert d["handoffs"] == n_req, (d["handoffs"], n_req)
    assert d["role_failovers"] == 0, d
    assert d["shards"][1]["prefill_tokens"] == 0, d["shards"][1]
    assert d["shards"][0]["decode_tokens"] == 0, d["shards"][0]
    assert rows["colocated"]["handoffs"] == 0
    return dict(arch=arch, n_req=n_req, prompt=prompt, out=out,
                budget=budget, **rows)


def main(report=print, only: str = None):
    if only == "disagg":
        db = run_disagg_ab()
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_disagg.json")
        with open(path, "w") as f:
            json.dump(db, f, indent=2, sort_keys=True)
        d, c = db["disagg"], db["colocated"]
        report(f"disagg_ab,0,"
               f"handoffs={d['handoffs']} pages={d['handoff_pages']} "
               f"decode_shard_prefill_tok={d['shards'][1]['prefill_tokens']} "
               f"lat_disagg={d['mean_latency_ticks']:.1f} "
               f"lat_coloc={c['mean_latency_ticks']:.1f} "
               f"-> {path}")
        return
    if only == "router":
        rb = run_router_ab()
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_router.json")
        with open(path, "w") as f:
            json.dump(rb, f, indent=2, sort_keys=True)
        n = rb["shards"]
        report(f"router_ab,0,"
               f"hit_aware={100 * rb[f'aware{n}']['hit_rate']:.1f}% "
               f"hit_rr={100 * rb[f'rr{n}']['hit_rate']:.1f}% "
               f"steps_solo={rb['solo']['steps']} "
               f"steps_aware={rb[f'aware{n}']['steps']} "
               f"lat_aware={rb[f'aware{n}']['mean_latency_ticks']:.1f} "
               f"lat_rr={rb[f'rr{n}']['mean_latency_ticks']:.1f} "
               f"-> {path}")
        return
    for arch in ARCH_SET:
        rows = {}
        # memory-mode A/B (paper Fig. 13/14) + batching-mode A/B: the
        # token-packed engine vs the PR-1 padded layout vs the legacy
        # one-prefill-per-step schedule, identical pool budget.
        for tag, mode, batching in (
                ("jenga", "jenga", "packed"),
                ("jenga-padded", "jenga", "padded"),
                ("jenga-serial", "jenga", "serial"),
                ("paged-baseline", "paged-baseline", "packed")):
            r = run_engine(arch, mode, batching=batching)
            rows[tag] = r
            report(f"e2e_{arch}_{tag},{r['wall_s']*1e6/max(1,r['steps']):.0f},"
                   f"steps={r['steps']} tok/step={r['tok_per_step']:.2f} "
                   f"finished={r['finished']} preempt={r['preemptions']}")
        sp = rows["paged-baseline"]["steps"] / max(1, rows["jenga"]["steps"])
        report(f"e2e_{arch}_speedup,0,steps_ratio={sp:.2f}x")
        sb = rows["jenga-serial"]["steps"] / max(1, rows["jenga"]["steps"])
        report(f"e2e_{arch}_batching_speedup,0,steps_ratio={sb:.2f}x")
    # padding-waste A/B (the token-packed dispatch win): pad slots per
    # dispatched slot and tokens per dispatch, padded vs packed layout on
    # a decode-heavy mixed workload.
    for batching in ("padded", "packed"):
        r = run_waste_ab("granite-3-2b", batching)
        report(f"dispatch_waste_{batching},0,"
               f"waste={100 * r['waste']:.1f}% "
               f"tok/dispatch={r['tok_per_dispatch']:.1f} "
               f"slots={r['slots']} tokens={r['tokens']} "
               f"finished={r['finished']}")
    # async double-buffering A/B: identical dispatches/outputs, host batch
    # build overlapped with the in-flight device step; JSON'd per-PR.
    ab = run_async_ab("granite-3-2b")
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_async.json")
    with open(path, "w") as f:
        json.dump(ab, f, indent=2, sort_keys=True)
    report(f"async_ab,{ab['async_']['us_per_step']:.0f},"
           f"sync_us/step={ab['sync']['us_per_step']:.0f} "
           f"dispatches={ab['async_']['dispatches']} "
           f"overlapped_build_ms={ab['overlapped_host_build_ms']:.1f} "
           f"-> {path}")
    # pipeline-depth / device-sampling A/B: fetched-bytes collapse, depth-4
    # ring vs the depth-2 double buffer, identical greedy outputs; JSON'd.
    pb = run_pipeline_ab("granite-3-2b")
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_pipeline.json")
    with open(path, "w") as f:
        json.dump(pb, f, indent=2, sort_keys=True)
    report(f"pipeline_ab,0,"
           f"fetch_bytes_ratio={pb['fetch_bytes_ratio']:.0f}x "
           f"steps_d2={pb['depth2_host']['steps']} "
           f"steps_d4={pb['depth4_device']['steps']} "
           f"bytes/step_d2host={pb['depth2_host']['fetched_bytes_per_step']:.0f} "
           f"bytes/step_d4dev={pb['depth4_device']['fetched_bytes_per_step']:.0f} "
           f"-> {path}")
    # kernel + autotune A/B: block-sparse skip accounting, kernel==ref
    # greedy outputs, autotuned-vs-constant step counts; JSON'd per-PR.
    kb = run_kernel_ab()
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_kernel.json")
    with open(path, "w") as f:
        json.dump(kb, f, indent=2, sort_keys=True)
    report(f"kernel_ab,0,"
           f"skip={100 * kb['ref']['kv_block_skip_frac']:.1f}% "
           f"steps_const={kb['ref']['steps']} "
           f"steps_autotuned={kb['autotuned']['steps']} "
           f"roofline_seed={kb['budget_roofline_seed']} -> {path}")
    # data-parallel router A/B: cache-aware vs round-robin placement over
    # an N-shard fleet, 1-shard fleet bitwise == solo engine; JSON'd.
    main(report, only="router")
    # prefill/decode disaggregation A/B: typed-page handoff vs colocated,
    # zero prefill recompute on the decode shard; JSON'd.
    main(report, only="disagg")


if __name__ == "__main__":
    import sys
    main(only=sys.argv[1] if len(sys.argv) > 1 else None)
