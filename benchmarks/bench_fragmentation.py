"""Paper §3.2 + Fig. 16: memory waste of PagedAttention on heterogeneous
models vs Jenga.

Part A (analytic, §3.2): exact waste formulas the paper states —
  * Llama-3.2-Vision on MMMU-pro: (T+I)(32+8) vs T*32+I*8  -> 79.6 %
  * Gemma-2 / Ministral: full+SWA mixes at their eval lengths.
Part B (allocator replay, Fig. 16): run the REAL two-level allocator on a
Ministral-like trace and measure waste fraction jenga vs paged baseline.
"""
from __future__ import annotations

import time

from . import model_specs as M
from .sim import run_sim
from .workloads import long_doc_qa, mmmu_pro_like


def analytic_waste():
    rows = []
    # Llama 3.2 Vision on MMMU-pro: I=6193 image, T=43 text tokens
    T, I = 43, 6193
    paged = (T + I) * (32 + 8)
    ideal = T * 32 + I * 8
    rows.append(("llama-vision/MMMU-pro", 1 - ideal / paged, 0.796))
    # Gemma-2: 23 full + 23 swa(4096); eval seq ~8192 (arXiv-QA chunks)
    L, W, nf, ns = 8192, 4096, 23, 23
    paged = L * (nf + ns)
    ideal = L * nf + min(L, W) * ns
    rows.append(("gemma2/len8192", 1 - ideal / paged, 0.25))
    # Ministral: paper's 56.25% = (27/36 swa share) * (1 - W/L) at the
    # model's 128k context (L = 4W): 0.75 * 0.75 = 0.5625 exactly.
    L, W, nf, ns = 131072, 32768, 9, 27
    paged = L * (nf + ns)
    ideal = L * nf + min(L, W) * ns
    rows.append(("ministral/len128k", 1 - ideal / paged, 0.5625))
    return rows


def replay_waste(mode: str, pool_gb: float = 4.0):
    specs = M.danube3_4b()
    reqs = long_doc_qa(8, lo=12_000, hi=24_000)
    res = run_sim(specs, reqs, pool_bytes=int(pool_gb * (1 << 30)),
                  chunk=4096, mode=mode)
    denom = [u + w for u, w in zip(res.used_units, res.waste_units)]
    peak_i = max(range(len(denom)), key=lambda i: denom[i])
    waste_frac = res.waste_units[peak_i] / max(1, denom[peak_i])
    return res, waste_frac


def main(report=print):
    t0 = time.perf_counter()
    for name, got, paper in analytic_waste():
        report(f"frag_analytic_{name},0,waste={got:.3f} paper={paper:.3f}")
        assert abs(got - paper) < 0.08, (name, got, paper)
    for mode in ("jenga", "paged"):
        t1 = time.perf_counter()
        res, waste = replay_waste(mode)
        us = (time.perf_counter() - t1) * 1e6 / max(1, res.steps)
        report(f"frag_replay_{mode},{us:.0f},"
               f"waste_frac={waste:.3f} steps={res.steps} "
               f"finished={res.finished}")
    report(f"frag_total_s,{(time.perf_counter()-t0)*1e6:.0f},")


if __name__ == "__main__":
    main()
