"""Fig. 19: speculative decoding memory — vLLM-max (uniform MAX page) vs
vLLM-manual (static per-model split) vs Jenga (shared LCM pool).

Part A: capacity analytics at real scale (Gemma-2 27B target + 2B draft):
how many concurrent sequences of length L fit a fixed pool under each
scheme. Part B: functional shared-pool run on reduced models."""
from __future__ import annotations

import time

from repro.core.spec import attention_spec, lcm, make_geometry


def capacity(pool_bytes, seq_len, tgt_units_per_tok, draft_units_per_tok,
             scheme, tpp=16):
    """Sequences of seq_len that fit (target+draft KV both needed)."""
    pool_units = pool_bytes // 2
    per_seq_t = seq_len * tgt_units_per_tok
    per_seq_d = seq_len * draft_units_per_tok
    if scheme == "jenga":        # shared LCM pool: near-zero waste
        return pool_units // (per_seq_t + per_seq_d)
    if scheme == "vllm-max":     # every draft page padded to target size
        return pool_units // (per_seq_t + per_seq_t)  # draft pages cost max
    if scheme == "vllm-manual":  # static split tuned for THIS seq_len
        # manual split is optimal for homogeneous self-attn (paper): equal
        # to jenga here, but fixed at deployment time
        return pool_units // (per_seq_t + per_seq_d)
    raise ValueError(scheme)


def main(report=print):
    # Gemma2-27B-like target (46L, kv16, hd128) + 2B draft (26L, kv4, hd256->
    # use kv4 hd128): per-token units
    tgt = 46 * 2 * 16 * 128
    draft = 26 * 2 * 4 * 128
    pool = 30 << 30
    L = 8192
    caps = {s: capacity(pool, L, tgt, draft, s)
            for s in ("jenga", "vllm-max", "vllm-manual")}
    report(f"specdecode_capacity,0,jenga={caps['jenga']} "
           f"max={caps['vllm-max']} manual={caps['vllm-manual']} "
           f"jenga_vs_max={caps['jenga']/max(1,caps['vllm-max']):.2f}x")
    # LCM geometry sanity at real scale
    specs = [
        attention_spec("tgt_full_attn", num_layers=46, kv_heads=16,
                       head_dim=128, tokens_per_page=16),
        attention_spec("draft_full_attn", num_layers=26, kv_heads=4,
                       head_dim=128, tokens_per_page=16),
    ]
    geom = make_geometry(specs, total_memory_bytes=pool)
    ratio = geom.large_page_units // min(s.page_units for s in specs)
    report(f"specdecode_lcm,0,large/small_ratio={ratio} "
           f"(paper notes up to 84x for Jamba, no degradation)")

    # Part B: functional shared pool (reduced) — reuse the test-path models
    t0 = time.perf_counter()
    from repro.configs import ARCHS, reduced
    from repro.models.registry import build_model
    from repro.models.tp import single_device_dist
    from repro.serving.spec_decode import SpecDecodeConfig, SpecDecodeEngine
    tcfg = reduced(ARCHS["granite-3-2b"])
    dcfg = reduced(ARCHS["internlm2-1.8b"], num_layers=2,
                   vocab_size=tcfg.vocab_size)
    dist = single_device_dist()
    sd = SpecDecodeEngine(build_model(tcfg, dist), build_model(dcfg, dist),
                          SpecDecodeConfig(k=3, kv_pool_bytes=16 << 20))
    out = sd.generate(list(range(16)), max_new_tokens=12)
    acc = (sum(sd.accept_lengths) / max(1, len(sd.accept_lengths)))
    dt = time.perf_counter() - t0
    report(f"specdecode_run,{dt*1e6:.0f},tokens={len(out)} "
           f"mean_accept={acc:.2f} shared_pool_types=2")


if __name__ == "__main__":
    main()
