"""Fig. 18: vision-embedding cache — without it the encoder re-runs for
every chunked-prefill step; with Jenga it runs once per image (and zero
times on an image cache hit). Engine run on the reduced qwen2-vl."""
from __future__ import annotations

import time

from repro.configs import ARCHS, reduced
from repro.core.request import MMItem
from repro.models.registry import build_model
from repro.models.tp import single_device_dist
from repro.serving import Engine, EngineConfig, Request, SamplingParams


def main(report=print):
    cfg = reduced(ARCHS["qwen2-vl-2b"])
    model = build_model(cfg, single_device_dist())
    eng = Engine(model, EngineConfig(kv_pool_bytes=8 << 20, chunk_size=8,
                                     max_running=4))
    # 4 requests, 2 distinct images (2 requests share each image)
    for i in range(4):
        mm = (MMItem(1, 16, mm_hash=100 + i % 2),)
        eng.submit(Request(rid=f"v{i}", prompt=list(range(24)), mm_items=mm,
                           sampling=SamplingParams(max_new_tokens=3)))
    t0 = time.perf_counter()
    eng.run_until_done()
    dt = time.perf_counter() - t0
    # chunked prefill of 24 tokens at chunk 8 = 3 chunks; without the cache
    # the encoder would run per chunk per request: 4*3=12; with per-request
    # caching: 4; with cross-request dedup (Jenga): 2.
    no_cache = 4 * 3
    report(f"vision_cache,{dt*1e6/max(1,eng.step_count):.0f},"
           f"encoder_runs={eng.encoder_runs} per_chunk_baseline={no_cache} "
           f"saving={no_cache / max(1, eng.encoder_runs):.1f}x")
    assert eng.encoder_runs == 2, eng.encoder_runs


if __name__ == "__main__":
    main()
