"""Paper-model KV-spec sets at REAL scale (for allocator replays).

These mirror the paper's evaluated models (Table 1) — Llama-3.2-Vision 11B,
Gemma-2 27B, Ministral 8B, Jamba 52B, plus standard Llama 8B — as layer-type
spec lists with true per-token KV sizes (bf16 units)."""
from repro.core.spec import (attention_spec, cross_attention_spec,
                             mamba_spec, vision_embed_spec)

TPP = 16


def llama_vision_11b(tpp=TPP):
    """32 self-attn + 8 cross-attn layers, GQA kv=8, hd=128 (mllama)."""
    return [
        attention_spec("full_attn", num_layers=32, kv_heads=8, head_dim=128,
                       tokens_per_page=tpp),
        cross_attention_spec("cross_attn", num_layers=8, kv_heads=8,
                             head_dim=128, tokens_per_page=tpp),
    ]


def gemma2_27b(tpp=TPP):
    """46 layers alternating full / SWA(4096), kv=16, hd=128."""
    return [
        attention_spec("full_attn", num_layers=23, kv_heads=16, head_dim=128,
                       tokens_per_page=tpp),
        attention_spec("swa", num_layers=23, kv_heads=16, head_dim=128,
                       tokens_per_page=tpp, kind="swa", sliding_window=4096),
    ]


def ministral_8b(tpp=TPP):
    """36 layers, interleaved sliding window 32k over 128k ctx: model as
    1/4 full + 3/4 SWA(32768), kv=8 hd=128."""
    return [
        attention_spec("full_attn", num_layers=9, kv_heads=8, head_dim=128,
                       tokens_per_page=tpp),
        attention_spec("swa", num_layers=27, kv_heads=8, head_dim=128,
                       tokens_per_page=tpp, kind="swa", sliding_window=32768),
    ]


def jamba_52b(tpp=TPP):
    """4 attn + 24 mamba + 4 moe-attn-ish: 8 attn layers kv=8 hd=128 +
    24 mamba layers (d_state 16, d_inner 8192 -> big states)."""
    return [
        attention_spec("full_attn", num_layers=8, kv_heads=8, head_dim=128,
                       tokens_per_page=tpp),
        mamba_spec("mamba", num_layers=24,
                   conv_units=2 * 3 * (8192 + 2 * 16),
                   ssm_units=2 * 8192 * 16),
    ]


def llama3_8b(tpp=TPP):
    """Standard homogeneous model (overhead parity check)."""
    return [
        attention_spec("full_attn", num_layers=32, kv_heads=8, head_dim=128,
                       tokens_per_page=tpp),
    ]


def vlm_with_vision_cache(tpp=TPP, hidden=4096):
    """LLaVA-OneVision-like: vision embedding cache + LLM KV."""
    return [
        attention_spec("full_attn", num_layers=28, kv_heads=4, head_dim=128,
                       tokens_per_page=tpp),
        vision_embed_spec("vision_embed", hidden_units=hidden,
                          tokens_per_page=tpp),
    ]


def danube3_4b(tpp=TPP):
    """h2o-danube3-like: 12 full + 12 SWA(4096), kv=8 hd=120 -> use hd=128."""
    return [
        attention_spec("full_attn", num_layers=12, kv_heads=8, head_dim=128,
                       tokens_per_page=tpp),
        attention_spec("swa", num_layers=12, kv_heads=8, head_dim=128,
                       tokens_per_page=tpp, kind="swa", sliding_window=4096),
    ]
