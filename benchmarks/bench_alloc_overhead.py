"""Paper Fig. 13 'standard Llama' parity: on a homogeneous model Jenga must
match PagedAttention — here we measure the host allocator's ops/sec and the
waste on a homogeneous trace (expected ~0 for both)."""
from __future__ import annotations

import time

from . import model_specs as M
from .sim import run_sim
from .workloads import sharegpt_like


def main(report=print):
    specs = M.llama3_8b()
    reqs = sharegpt_like(64)
    rows = {}
    for mode in ("jenga", "paged"):
        t0 = time.perf_counter()
        res = run_sim(specs, reqs, pool_bytes=6 << 30, chunk=2048,
                      mode=mode, max_running=64)
        dt = time.perf_counter() - t0
        tokens = sum(r.prompt_len + r.output_len for r in reqs)
        rows[mode] = dt
        peak_waste = max(res.waste_units) / max(1, max(res.used_units))
        report(f"alloc_overhead_{mode},{dt*1e6/max(1,res.steps):.0f},"
               f"alloc_tokens_per_s={tokens/dt:.0f} "
               f"peak_waste_frac={peak_waste:.4f} steps={res.steps}")
    ratio = rows["jenga"] / max(1e-9, rows["paged"])
    report(f"alloc_overhead_ratio,0,jenga_vs_paged_host_time={ratio:.2f}x")


if __name__ == "__main__":
    main()
