"""Allocator-replay simulator: drives the REAL Jenga manager + scheduler at
production scale (real layer-type specs, real page math) without model
execution — the paper's memory/batch-size figures (15, 16) are allocator
properties, so this replays them exactly and fast."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.manager import JengaKVCacheManager
from repro.core.request import SequenceState
from repro.core.spec import KVCacheSpec

from .workloads import SimRequest


@dataclasses.dataclass
class SimResult:
    steps: int
    decode_batch_sizes: List[int]
    used_units: List[int]
    waste_units: List[int]          # allocated-but-unneeded (vs ideal need)
    free_units: List[int]
    total_units: int
    finished: int
    preemptions: int
    prefix_hit_tokens: int = 0
    prefix_query_tokens: int = 0
    prefill_tokens_computed: int = 0   # includes preemption recompute


def ideal_need_units(mgr: JengaKVCacheManager, seq: SequenceState) -> int:
    """What an ideal allocator would hold for this sequence right now:
    full-attn tokens, window-only SWA, state pages, image-only mm tokens."""
    n = 0
    for spec in mgr.specs:
        if spec.kind in ("mamba", "rwkv"):
            n += spec.page_units
        elif spec.kind == "swa":
            w = min(spec.sliding_window, seq.num_computed)
            n += spec.pages_for_tokens(max(1, w)) * spec.page_units
        elif spec.kind in ("vision_embed", "cross_attn"):
            toks = sum(it.length for it in (seq.encoder_items or seq.mm_items)
                       if it.start < seq.num_computed)
            n += spec.pages_for_tokens(toks) * spec.page_units if toks else 0
        else:
            n += spec.pages_for_tokens(max(1, seq.num_computed)) \
                * spec.page_units
    return n


def run_sim(specs: Sequence[KVCacheSpec], requests: List[SimRequest], *,
            pool_bytes: int, chunk: int = 2048, max_running: int = 64,
            mode: str = "jenga", prefix_caching: bool = False,
            seed: int = 0, max_steps: int = 100_000) -> SimResult:
    """mode: 'jenga' | 'paged' (no retirement, full-prefix-only policies,
    mm pages for every token) | 'max' (MAX-page geometry)."""
    baseline = mode in ("paged", "max")
    mgr = JengaKVCacheManager(
        specs, total_memory_bytes=pool_bytes,
        mode="max" if mode == "max" else "lcm",
        enable_prefix_caching=prefix_caching,
        enable_inflight_retirement=not baseline,
        seed=seed)
    if baseline:
        from repro.core.policies import FullAttentionPolicy
        for s in mgr.specs:
            if s.kind in ("swa", "vision_embed", "cross_attn"):
                mgr.policies[s.name] = FullAttentionPolicy(s)
        orig = mgr._mm_storage_upto
        mgr._mm_storage_upto = lambda req, spec, pos: (
            pos if spec.kind in ("vision_embed", "cross_attn")
            and not req.encoder_items else orig(req, spec, pos))

    waiting = sorted(requests, key=lambda r: r.arrival)
    waiting = list(waiting)
    running: List[Tuple[SimRequest, SequenceState]] = []
    res = SimResult(0, [], [], [], [], mgr.geometry.total_units, 0, 0)
    step = 0
    generated: Dict[str, int] = {}

    def make_tokens(r: SimRequest) -> List[int]:
        if r.shared_prefix or r.prefix_len:
            doc = [((r.shared_prefix + 1) * 131 + i) % 50000
                   for i in range(r.prefix_len)]
            rng = np.random.default_rng(hash(r.rid) & 0xFFFF)
            q = rng.integers(0, 50000, r.prompt_len - r.prefix_len).tolist()
            return doc + [int(x) for x in q]
        rng = np.random.default_rng(hash(r.rid) & 0xFFFF)
        return [int(x) for x in rng.integers(0, 50000, r.prompt_len)]

    while (waiting or running) and step < max_steps:
        # admit
        while waiting and len(running) < max_running:
            r = waiting[0]
            seq = SequenceState(rid=r.rid, tokens=make_tokens(r),
                                mm_items=r.mm_items)
            ok, _ = mgr.begin_request(seq)
            if not ok:
                break
            waiting.pop(0)
            generated[r.rid] = 0
            running.append((r, seq))
        # one prefill chunk
        did_prefill = False
        for r, seq in running:
            if seq.num_computed < r.prompt_len:
                target = min(r.prompt_len, seq.num_computed + chunk)
                ok = mgr.allocate_for_tokens(seq, target)
                while not ok and len(running) > 1:
                    vr, vs = running[-1]
                    if vs is seq:
                        break
                    mgr.preempt_request(vs)
                    res.preemptions += 1
                    waiting.insert(0, vr)
                    running.pop()
                    ok = mgr.allocate_for_tokens(seq, target)
                if ok:
                    res.prefill_tokens_computed += target - seq.num_computed
                    mgr.advance(seq, target - seq.num_computed)
                    mgr.consume_mm(seq, seq.num_computed)
                    if prefix_caching:
                        mgr.touch(seq)
                    did_prefill = True
                break
        # decodes
        decode_batch = 0
        finished_now = []
        for r, seq in list(running):
            if seq.num_computed < r.prompt_len:
                continue
            seq.append_token(41000 + generated[r.rid])
            ok = mgr.allocate_for_tokens(seq, seq.num_tokens)
            while not ok:
                victim = None
                for cand in reversed(running):
                    if cand[1] is not seq:
                        victim = cand
                        break
                if victim is None:
                    break
                mgr.preempt_request(victim[1])
                res.preemptions += 1
                running.remove(victim)
                waiting.insert(0, victim[0])
                ok = mgr.allocate_for_tokens(seq, seq.num_tokens)
            if not ok:
                continue
            mgr.advance(seq, 1)
            if prefix_caching and step % 8 == 0:
                mgr.touch(seq)
            decode_batch += 1
            generated[r.rid] += 1
            if generated[r.rid] >= r.output_len:
                finished_now.append((r, seq))
        for r, seq in finished_now:
            mgr.free_request(seq, cache=prefix_caching)
            running.remove((r, seq))
            res.finished += 1
        # metrics
        stats = mgr.memory_stats()
        ideal = sum(ideal_need_units(mgr, seq) for _, seq in running)
        res.decode_batch_sizes.append(decode_batch)
        res.used_units.append(stats.used_units)
        res.waste_units.append(max(0, stats.used_units + stats.empty_units
                                   - ideal))
        res.free_units.append(stats.free_units)
        step += 1
        if not did_prefill and decode_batch == 0 and not waiting and running:
            break  # stuck (pool too small for a single request)
        if res.preemptions > 50 * max(1, len(requests)):
            break  # thrashing: pool can't make progress under this scheme
    res.steps = step
    res.prefix_hit_tokens = mgr.prefix_hit_tokens_total
    res.prefix_query_tokens = mgr.prefix_query_tokens_total
    return res
