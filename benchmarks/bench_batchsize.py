"""Fig. 15: decode batch-size timeline on the long-document QA workload
(20 requests at once, 55-110k input, 50-100 output) — Jenga vs PagedAttention
baseline on a Ministral-like model, pool sized so the difference bites."""
from __future__ import annotations

import time

import numpy as np

from . import model_specs as M
from .sim import run_sim
from .workloads import long_doc_qa


def main(report=print):
    specs = M.danube3_4b()
    reqs = long_doc_qa(20, lo=16_000, hi=32_000)
    results = {}
    for mode in ("jenga", "paged"):
        t0 = time.perf_counter()
        res = run_sim(specs, reqs, pool_bytes=6 << 30, chunk=4096,
                      mode=mode, max_running=32)
        us = (time.perf_counter() - t0) * 1e6 / max(1, res.steps)
        decode_steps = [b for b in res.decode_batch_sizes if b > 0]
        avg_bs = float(np.mean(decode_steps)) if decode_steps else 0.0
        results[mode] = (avg_bs, res)
        report(f"batchsize_{mode},{us:.0f},avg_decode_batch={avg_bs:.2f} "
               f"steps={res.steps} finished={res.finished} "
               f"preempt={res.preemptions}")
    ratio = results["jenga"][0] / max(0.01, results["paged"][0])
    report(f"batchsize_ratio,0,jenga_vs_paged={ratio:.2f}x (paper: 1.95x)")
    return results


if __name__ == "__main__":
    main()
