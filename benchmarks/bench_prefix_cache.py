"""Fig. 17: prefix caching with a varying number of shared articles —
Jenga's SWA-aware hit/eviction rules vs treating every layer as full
attention (vLLM). Metric: hit rate (tokens served from cache)."""
from __future__ import annotations

import time

from . import model_specs as M
from .sim import run_sim
from .workloads import arxiv_qa_like


def main(report=print):
    from repro.core.spec import attention_spec
    # gemma2-like with window (1024) << article (4096): Jenga caches an
    # article at ~23L*full + 23L*window = 0.85 GB; the baseline keeps full
    # KV for the SWA layers too = 1.54 GB. Pool 5 GB holds ~6 articles
    # jenga-style but ~3 paged-style -> the Fig. 17 divergence.
    specs = [
        attention_spec("full_attn", num_layers=23, kv_heads=16, head_dim=128,
                       tokens_per_page=16),
        attention_spec("swa", num_layers=23, kv_heads=16, head_dim=128,
                       tokens_per_page=16, kind="swa", sliding_window=1024),
    ]
    for n_articles in (2, 4, 8):
        reqs = arxiv_qa_like(n_articles, questions_per=4, article_len=4096,
                             shuffle=False)
        rates = {}
        for mode in ("jenga", "paged"):
            t0 = time.perf_counter()
            res = run_sim(specs, reqs, pool_bytes=5 << 30, chunk=2048,
                          mode=mode, prefix_caching=True, max_running=4)
            us = (time.perf_counter() - t0) * 1e6 / max(1, res.steps)
            rate = res.prefix_hit_tokens / max(1, res.prefix_query_tokens)
            ideal = sum(r.prompt_len for r in reqs)
            # the figure's real quantity: prefill compute saved (hits) vs
            # burned (preemption recompute), relative to cold-start cost
            saved = 1.0 - res.prefill_tokens_computed / ideal
            rates[mode] = saved
            report(f"prefix_{mode}_n{n_articles},{us:.0f},"
                   f"hit_rate={rate:.3f} prefill_saved={saved:.3f} "
                   f"steps={res.steps} preempt={res.preemptions}")
        report(f"prefix_saved_delta_n{n_articles},0,"
               f"jenga={rates['jenga']:.3f} paged={rates['paged']:.3f}")
    return None


if __name__ == "__main__":
    main()
